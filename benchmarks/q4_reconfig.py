"""Q4 (§8.4, Fig. 9): reconfiguration cost for provisioning/decommissioning.

The paper's headline: < 40 ms even when provisioning tens of instances,
because nothing is transferred.  We measure the *marginal* cost of a
reconfiguring tick pair vs a plain tick pair (the switch rides inside the
normal tick: control tuple -> gamma barrier -> table swap), plus the state
bytes each scheme ships (VSN: 0; SN baseline: the re-owned sigma rows).
"""

import time

import numpy as np
import jax

from benchmarks.common import emit
from repro.core.aggregate import count_aggregate, fast_init
from repro.core.aggregate import tick_fast as agg_fast
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.runtime import SNPipeline, VSNPipeline
from repro.core.vsn import merge_fast_state
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 1000                      # ScaleJoin's virtual key count
N_MAX = 64
WS = WindowSpec(wa=1000, ws=5000, wt="multi")


def fast_tick(op, st, ready, resp, explicit_w=None):
    return agg_fast(op, "count", st, ready, resp)


def run(pi_from: int, pi_to: int, cls):
    rng = np.random.default_rng(1)
    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=512, extra_slots=2)
    kw = {}
    if cls is VSNPipeline:
        kw = dict(tick_fn=fast_tick, merge_fn=merge_fast_state,
                  init_sigma=lambda: fast_init(op.resolved()))
    pipe = cls(op, n_max=N_MAX, n_active=pi_from, stash_cap=128, **kw)
    if cls is SNPipeline:
        pipe.sigmas = jax.tree.map(
            lambda a: jax.numpy.broadcast_to(a, (N_MAX,) + a.shape),
            fast_init(op.resolved()))
        pipe._tick = fast_tick
        pipe._step = jax.jit(pipe._step_impl)
    batches = list(datagen.tweets(rng, n_ticks=10, tick=128,
                                  words_per_tweet=4, vocab=2000,
                                  k_virt=K_VIRT, rate_per_tick=40))
    rc0 = Reconfiguration(epoch=1, n_active=pi_to,
                          fmu=balanced_fmu(K_VIRT, pi_to, N_MAX),
                          active=active_mask(pi_to, N_MAX))
    for b in batches[:3]:
        pipe.step(b)
    pipe.step(batches[3], reconfig=rc0)     # warm the reconfig path too
    pipe.step(batches[4])
    # plain pair
    t0 = time.perf_counter()
    pipe.step(batches[5]); pipe.step(batches[6])
    t_plain = time.perf_counter() - t0
    # reconfiguring pair
    rc = Reconfiguration(epoch=2, n_active=pi_from,
                         fmu=balanced_fmu(K_VIRT, pi_from, N_MAX),
                         active=active_mask(pi_from, N_MAX))
    t0 = time.perf_counter()
    pipe.step(batches[7], reconfig=rc); pipe.step(batches[8])
    t_rc = time.perf_counter() - t0
    moved = getattr(pipe, "bytes_transferred", 0)
    return max(t_rc - t_plain, 0.0) * 1e3, moved


def main():
    for pi_from, pi_to in [(1, 4), (8, 24), (18, 31), (30, 52), (52, 30)]:
        m_v, _ = run(pi_from, pi_to, VSNPipeline)
        m_s, moved = run(pi_from, pi_to, SNPipeline)
        emit(f"q4_reconfig_{pi_from}to{pi_to}_vsn", m_v * 1e3,
             f"marginal {m_v:.1f}ms, 0 state bytes")
        emit(f"q4_reconfig_{pi_from}to{pi_to}_sn", m_s * 1e3,
             f"marginal {m_s:.1f}ms, {moved} state bytes")


if __name__ == "__main__":
    main()
