"""Q2 (§8.2, Fig. 7): max throughput / min latency of the I=2 forwarding
O+ (Operator 6) — the data sharing+sorting bound — for increasing Pi."""

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import scalegate, tuples as T

TICK = 512


def run(n_inst: int, n_ticks: int = 20):
    """Operator 6 forwards every tuple; its cost is ScaleGate merge + the
    replicated read (VSN: every instance sees the whole ready batch)."""
    rng = np.random.default_rng(0)
    state = scalegate.init_scalegate(2, capacity=TICK, kmax=1,
                                     payload_width=4)

    @jax.jit
    def step(state, batch):
        state, ready = scalegate.push(state, batch)
        # Operator 6 f_U: forward payload unchanged, per instance
        outs = jnp.broadcast_to(ready.payload, (n_inst,) + ready.payload.shape)
        return state, outs.sum()

    tau = 0
    batches = []
    for _ in range(n_ticks):
        taus = np.sort(tau + rng.integers(0, 50, TICK)).astype(np.int32)
        tau = int(taus.max()) + 1
        batches.append(T.make_batch(
            jnp.asarray(taus), jnp.asarray(
                rng.uniform(0, 1, (TICK, 4)).astype(np.float32)),
            source=jnp.asarray(rng.integers(0, 2, TICK), jnp.int32)))
    state, s = step(state, batches[0])
    jax.block_until_ready(s)
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, s = step(state, b)
    jax.block_until_ready(s)
    dt = time.perf_counter() - t0
    return TICK * (n_ticks - 1) / dt, dt / (n_ticks - 1) * 1e3


def main():
    for n in (1, 4, 16, 36):
        tps, lat_ms = run(n)
        emit(f"q2_forward_pi{n}", 1e6 / tps, f"{tps:.0f} t/s, {lat_ms:.2f} ms/tick")


if __name__ == "__main__":
    main()
