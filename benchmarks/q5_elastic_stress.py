"""Q5 (§8.5, Fig. 11): stress reconfigurations under an abruptly-changing
rate trace with the predictive controller; reports reconfig count, thread
trace, sustained throughput, and that outputs stay correct (vs a static
max-width run)."""

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.conftest_shim import collect_outputs
from repro.core.aggregate import count_aggregate
from repro.core.controller import PredictiveController, Reconfiguration
from repro.core.runtime import VSNPipeline
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 256
WS = WindowSpec(wa=500, ws=1000, wt="multi")


def main():
    rng = np.random.default_rng(5)
    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)
    ctl = PredictiveController(n_max=32, k_virt=K_VIRT,
                               comparisons_per_s_per_instance=3e6,
                               ws_seconds=1.0, n_active=2)
    pipe = VSNPipeline(op, n_max=32, n_active=2, stash_cap=256)
    static = VSNPipeline(op, n_max=32, n_active=32, stash_cap=256)

    phases = [500, 4000, 1500, 8000, 800, 6000]
    trace, outs_e, outs_s = [], [], []
    n_reconf = 0
    t0 = time.perf_counter()
    tick_id = 0
    for rate in phases:
        for b in datagen.tweets(rng, n_ticks=3, tick=256,
                                words_per_tweet=3, vocab=1000,
                                k_virt=K_VIRT, rate_per_tick=max(rate // 10, 1)):
            rc = ctl.observe(rate)
            if rc is not None:
                n_reconf += 1
            o1, o2, _ = pipe.step(b, reconfig=rc)
            outs_e += collect_outputs(o1) + collect_outputs(o2)
            o1, o2, _ = static.step(b)
            outs_s += collect_outputs(o1) + collect_outputs(o2)
            trace.append(ctl.n_active)
            tick_id += 1
    dt = time.perf_counter() - t0
    ok = sorted(outs_e) == sorted(outs_s)
    emit("q5_stress_reconfigs", dt / tick_id * 1e6,
         f"{n_reconf} reconfigs, pi trace {min(trace)}..{max(trace)}, "
         f"outputs_match_static={ok}")
    assert ok, "elastic run diverged from static oracle"


if __name__ == "__main__":
    main()
