"""Q5 (§8.5, Fig. 11): stress reconfigurations under an abruptly-changing
rate trace with the predictive controller; reports reconfig count, thread
trace, sustained throughput, and that outputs stay correct (vs a static
max-width run).

``--mesh N``: the elastic pipeline additionally runs on an N-device mesh
(MeshPipeline) under the same reconfiguration trace — every f_mu switch is
a replicated-table swap, zero state rows move between devices, and the
output set must still match the static oracle exactly.

``--async``: the same abrupt rate trace through the live closed loop
(AsyncStreamRuntime + PredictiveController.observe_live): the controller
is fed per-tick MetricsBus snapshots, its reconfigurations are injected
mid-stream through the control-tuple path, and the row reports tick
latency p50/p99, detection→switch latency, and exact output parity with
the static oracle (a FAIL row if the live elastic run diverges)."""

import time

import numpy as np

from benchmarks.common import emit
from benchmarks.conftest_shim import collect_outputs
from repro.core.aggregate import count_aggregate
from repro.core.controller import PredictiveController, Reconfiguration
from repro.core.runtime import MeshPipeline, VSNPipeline
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 256
WS = WindowSpec(wa=500, ws=1000, wt="multi")


def main(mesh: int = 0, async_: bool = False):
    rng = np.random.default_rng(5)
    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)
    ctl = PredictiveController(n_max=32, k_virt=K_VIRT,
                               comparisons_per_s_per_instance=3e6,
                               ws_seconds=1.0, n_active=2)
    pipe = VSNPipeline(op, n_max=32, n_active=2, stash_cap=256)
    static = VSNPipeline(op, n_max=32, n_active=32, stash_cap=256)
    mesh_pipe = None
    outs_m = []
    if mesh:
        import jax
        from repro.launch.mesh import make_stream_mesh
        if len(jax.devices()) < mesh:
            emit("q5_mesh_SKIP", 0.0,
                 f"needs {mesh} devices, have {len(jax.devices())}")
            mesh = 0
        else:
            mesh_pipe = MeshPipeline(op, make_stream_mesh(mesh),
                                     stash_cap=256, mode="general",
                                     n_max=32, n_active=2)

    phases = [500, 4000, 1500, 8000, 800, 6000]
    trace, outs_e, outs_s, replay = [], [], [], []
    n_reconf = 0
    t0 = time.perf_counter()
    tick_id = 0
    for rate in phases:
        for b in datagen.tweets(rng, n_ticks=3, tick=256,
                                words_per_tweet=3, vocab=1000,
                                k_virt=K_VIRT, rate_per_tick=max(rate // 10, 1)):
            rc = ctl.observe(rate)
            if rc is not None:
                n_reconf += 1
            o1, o2, _ = pipe.step(b, reconfig=rc)
            outs_e += collect_outputs(o1) + collect_outputs(o2)
            o1, o2, _ = static.step(b)
            outs_s += collect_outputs(o1) + collect_outputs(o2)
            replay.append((b, rc))
            trace.append(ctl.n_active)
            tick_id += 1
    dt = time.perf_counter() - t0
    # mesh replay outside the timed region so q5_stress stays comparable
    # between --mesh and non---mesh runs
    t0_m = time.perf_counter()
    for b, rc in (replay if mesh_pipe is not None else []):
        o1, o2, _ = mesh_pipe.step(b, reconfig=rc)
        outs_m += collect_outputs(o1) + collect_outputs(o2)
    dt_m = time.perf_counter() - t0_m
    ok = sorted(outs_e) == sorted(outs_s)
    emit("q5_stress_reconfigs", dt / tick_id * 1e6,
         f"{n_reconf} reconfigs, pi trace {min(trace)}..{max(trace)}, "
         f"outputs_match_static={ok}")
    assert ok, "elastic run diverged from static oracle"
    if mesh_pipe is not None:
        ok_m = sorted(outs_m) == sorted(outs_s)
        coll = sum(mesh_pipe.collective_bytes().values())
        emit(f"q5_stress_mesh{mesh}", dt_m / tick_id * 1e6,
             f"outputs_match_static={ok_m}, "
             f"switch_bytes={mesh_pipe.switch_bytes()}, "
             f"collective_bytes={coll}")
        assert ok_m, "mesh elastic run diverged from static oracle"
        assert coll == 0, "mesh step moved state between devices"

    if async_:
        from repro.core.async_runtime import AsyncStreamRuntime
        from repro.io import RateSchedule, ReplaySource

        batches = [b for b, _ in replay]
        sched = RateSchedule(tuple((3, float(r)) for r in phases))
        live_ctl = PredictiveController(n_max=32, k_virt=K_VIRT,
                                        comparisons_per_s_per_instance=3e6,
                                        ws_seconds=1.0, n_active=2)
        live_pipe = VSNPipeline(op, n_max=32, n_active=2, stash_cap=256)
        rt = AsyncStreamRuntime(live_pipe,
                                ReplaySource(batches, schedule=sched),
                                controller=live_ctl, queue_cap=4)
        rep = rt.run()
        ok_l = rt.sink.results() == sorted(outs_s)
        d2s = (float(np.mean(rep.detect_to_switch_ms))
               if rep.detect_to_switch_ms else None)
        pis = [rc.n_active for _, rc in rep.reconfig_trace] or [2]
        emit("q5_live_loop", 1e6 / max(rep.throughput_tps, 1e-9),
             f"{rep.throughput_tps:.0f} t/s, "
             f"{len(rep.reconfig_trace)} live reconfigs "
             f"({rep.switches} switched, pi {min(pis)}..{max(pis)}), "
             f"outputs_match_static={ok_l}",
             p50_ms=rep.p50_ms, p99_ms=rep.p99_ms, detect_switch_ms=d2s)
        assert ok_l, "live elastic run diverged from static oracle"

        # kill-and-restore on the same workload shape: detection→recovered
        # latency lands in the CSV column next to detection→switch, and the
        # row FAILs unless the restored run is exactly-once tuple-for-tuple
        import tempfile

        from benchmarks.common import run_recovery_bench
        from repro import api

        with tempfile.TemporaryDirectory() as ckdir:
            cfg = api.RuntimeConfig(
                op="count", wa=500, ws=1000, wt="multi", k_virt=K_VIRT,
                out_cap=1024, extra_slots=2, n_max=32, n_active=2,
                stash_cap=256, checkpoint_dir=ckdir, checkpoint_every=4)
            rrep = run_recovery_bench("q5_recovery", cfg, batches,
                                      mode="stop", crash_after=10,
                                      crash_mid_save=True)
            assert rrep.parity, "recovery replay lost exactly-once parity"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0)
    ap.add_argument("--async", dest="async_", action="store_true")
    a = ap.parse_args()
    main(mesh=a.mesh, async_=a.async_)
