"""Output-collection helper shared with tests (no pytest dependency)."""

import numpy as np


def collect_outputs(outs):
    res = []
    tau = np.asarray(outs.tau)
    pay = np.asarray(outs.payload)
    val = np.asarray(outs.valid)
    if tau.ndim == 2:
        for j in range(tau.shape[0]):
            res += [(int(t), tuple(np.round(p, 4))) for t, p, ok in
                    zip(tau[j], pay[j], val[j]) if ok]
    else:
        res += [(int(t), tuple(np.round(p, 4))) for t, p, ok in
                zip(tau, pay, val) if ok]
    return sorted(res)
