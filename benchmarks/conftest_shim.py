"""Output-collection helper shared with tests (no pytest dependency)."""

from repro.io.sinks import flatten_outputs


def collect_outputs(outs):
    return sorted(flatten_outputs(outs))
