"""Q7: the elastic LLM serving tier under heavy multi-tenant traffic.

Rows (reduced configs — the shapes are real, the weights random; the
parity gate and the byte accounting are what matter on CPU CI):

* ``q7_decode_parity`` — GATE: continuous-batching engine output is
  token-identical to a straight-line batch-1 reference decode for every
  request (attention arch).
* ``q7_throughput`` — sustained decode tok/s over a diurnal-spike
  arrival trace (``RateSchedule`` baseline -> 3x spike -> baseline)
  through the full async stack, plus tick-latency p50/p99.
* ``q7_reconfig_vsn`` — GATE: mid-decode scale-up via the f_mu rewrite
  moves ZERO KV bytes; reports the reconfig wall latency.
* ``q7_reconfig_sn`` — GATE: the shared-nothing baseline must move >0
  bytes for the same scale-up (it materializes the slot migration);
  reports bytes + latency — the VSN-vs-SN comparison row.
* ``q7_slo_loop`` — GATE: closed loop — the SLO controller, reading the
  windowed p99 of ``span.serve.decode`` off the live registry, provisions
  replicas mid-run (an unmeetably tight target forces the breach, the
  PR-9 drill idiom); the run must show a mid-stream scale-up with zero
  KV moved and all requests served.
"""

import time

import numpy as np
import jax

from benchmarks.common import emit
from repro.configs import canon, get_config, reduced
from repro.models import transformer
from repro.serving import (Request, RequestSource, ServingConfig,
                           ServingEngine, reference_decode)

ARCH = "qwen3-14b"
SLOTS = 4
MAX_SEQ = 48
MAX_NEW = 6


def _engine(n_instances=4):
    cfg = reduced(get_config(canon(ARCH)))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServingEngine(cfg, params, n_slots=SLOTS,
                                      max_seq=MAX_SEQ,
                                      n_instances=n_instances)


def _drive(eng, reqs, reconfigure=None):
    for r in reqs:
        eng.submit(r)
    done, t0 = [], time.perf_counter()
    while len(done) < len(reqs) and eng.steps < 100 * len(reqs):
        done += eng.tick()
        if reconfigure and eng.steps == 3:
            reconfigure()
    return done, time.perf_counter() - t0


def bench_parity():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, 6),
                    max_new=MAX_NEW) for i in range(6)]
    done, dt = _drive(eng, reqs)
    ok = len(done) == len(reqs)
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new, MAX_SEQ)
        ok = ok and list(r.out) == ref
    emit("q7_decode_parity", dt / max(eng.steps, 1) * 1e6,
         f"engine_matches_reference={ok}")


def bench_reconfig(mode):
    cfg, params, eng = _engine()
    eng.pool.reconfigure_vsn(2)
    rng = np.random.default_rng(8)
    reqs = [Request(uid=i, prompt=rng.integers(1, cfg.vocab, 6),
                    max_new=MAX_NEW) for i in range(SLOTS)]
    rec = {}

    def do():
        moved, ms = eng.reconfigure(4, mode=mode)
        rec.update(moved=moved, ms=ms)

    done, _ = _drive(eng, reqs, reconfigure=do)
    # the reconfig must not change a single output token
    ok = len(done) == len(reqs)
    for r in done:
        ref = reference_decode(cfg, params, r.prompt, r.max_new, MAX_SEQ)
        ok = ok and list(r.out) == ref
    moved = rec.get("moved", -1)
    bytes_ok = (moved == 0) if mode == "vsn" else (moved > 0)
    emit(f"q7_reconfig_{mode}", rec.get("ms", 0.0) * 1e3,
         f"kv_bytes_moved={moved},zero_move={'PASS' if bytes_ok else 'FAIL'}"
         f",outputs_invariant={ok}")


def bench_throughput():
    from repro.api import RuntimeConfig, build_runtime
    from repro.io.sources import RateSchedule
    scfg = ServingConfig(arch=ARCH, reduced=True, n_slots=SLOTS,
                         max_seq=MAX_SEQ, n_instances=4)
    cfg = RuntimeConfig(serving=scfg, n_sources=2, n_active=2)
    ticks = 24
    src = RequestSource(schedule=RateSchedule([(0, 40.0), (8, 120.0),
                                               (16, 40.0)]),
                        ticks=ticks, lanes=3, prompt_len=5,
                        max_new=MAX_NEW, seed=9, n_inputs=2,
                        k_virt=SLOTS, tick_ms=50,
                        drain_ticks=ticks * 3 * MAX_NEW // SLOTS + 16)
    rt = build_runtime(cfg, src)
    t0 = time.perf_counter()
    rep = rt.run()
    dt = time.perf_counter() - t0
    pipe = rt.pipeline
    toks = sum(len(r.out) for r in pipe.finished)
    served = len(pipe.finished) == src.total_requests
    emit("q7_throughput", dt / max(rep.ticks, 1) * 1e6,
         f"{toks / max(dt, 1e-9):.0f} t/s,requests={len(pipe.finished)}"
         f",all_served={served}", p50_ms=rep.p50_ms,
         p99_ms=rep.p99_ms)


def bench_slo_loop():
    from repro import obs as _obs
    from repro.api import RuntimeConfig, build_runtime
    from repro.io.sources import RateSchedule
    scfg = ServingConfig(arch=ARCH, reduced=True, n_slots=SLOTS,
                         max_seq=MAX_SEQ, n_instances=4)
    # an unmeetably tight p99 target forces the breach -> scale-up loop
    # (PR-9 drill idiom: decode latency on CPU won't cross a real target)
    cfg = RuntimeConfig(serving=scfg, n_sources=2, n_active=1,
                        controller="slo", slo_target_p99_ms=0.05,
                        obs={"enabled": True, "trace": True})
    ticks = 20
    src = RequestSource(schedule=RateSchedule([(0, 60.0)]), ticks=ticks,
                        lanes=3, prompt_len=5, max_new=MAX_NEW, seed=10,
                        n_inputs=2, k_virt=SLOTS, tick_ms=50,
                        drain_ticks=ticks * 3 * MAX_NEW // SLOTS + 16)
    prev = _obs.get()
    try:
        rt = build_runtime(cfg, src)
        t0 = time.perf_counter()
        rep = rt.run()
        dt = time.perf_counter() - t0
    finally:
        _obs.set_current(prev)
    pipe = rt.pipeline
    scaled = [ev for ev in pipe.reconfig_events if ev["n_active"] > 1]
    moved = sum(ev["kv_bytes_moved"] for ev in pipe.reconfig_events)
    served = len(pipe.finished) == src.total_requests
    ok = bool(scaled) and moved == 0 and served
    end = pipe.engine.pool.n_active
    emit("q7_slo_loop", dt / max(rep.ticks, 1) * 1e6,
         f"scaleups={len(scaled)},n_active_end={end},kv_bytes_moved={moved}"
         f",all_served={served},closed_loop={'PASS' if ok else 'FAIL'}",
         p50_ms=rep.p50_ms, p99_ms=rep.p99_ms)


def main():
    bench_parity()
    bench_throughput()
    bench_reconfig("vsn")
    bench_reconfig("sn")
    bench_slo_loop()
