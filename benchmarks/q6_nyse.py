"""Q6 (§8.6, Fig. 13): NYSE-style hedge self-join under a bursty rate with
threshold-controller elasticity; reports throughput, comparisons, reconfig
count and thread range.

``q6_nyse_kernel_join`` is the dispatched ``window_join`` counting path
(core.join.band_join_counts) over the same trade stream: a band
candidate-prefilter on the ``[id, nd]`` payload executed by the kernel
backend selected via ``--backend`` (xla oracle on CPU, Pallas on TPU) —
the end-to-end accounting row for the TPU-accelerated join."""

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.controller import ThresholdController
from repro.core.join import band_join_counts, fast_join_init, hedge_predicate
from repro.core.join import tick_fast as join_fast
from repro.core.vsn import merge_fast_state, run_tick
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 256
RING = 16
WS = WindowSpec(wa=1, ws=30 * 1000, wt="single")   # 30 s window
FJ = hedge_predicate()


def main():
    rng = np.random.default_rng(11)
    ctl = ThresholdController(n_max=16, k_virt=K_VIRT,
                              capacity_per_instance=2000.0, n_active=2)
    st = fast_join_init(K_VIRT, RING, 2)
    n_active = {"v": 2}

    def tick_fn(op, s, r, resp, explicit_w=None):
        return join_fast(WS, FJ, s, r, resp, out_cap=256, emit=False)

    @jax.jit
    def step(st, batch, fmu, active):
        return run_tick(None, st, batch, fmu, active, tick_fn,
                        merge_fast_state)

    batches = list(datagen.nyse(rng, n_ticks=16, tick=128, k_virt=K_VIRT))
    reconfigs, trace = 0, []
    t0 = time.perf_counter()
    matches = 0
    for b in batches:
        rate = float(rng.uniform(200, 8000))
        rc = ctl.observe(rate)
        if rc is not None:
            reconfigs += 1
        n = ctl.n_active
        fmu = jnp.asarray(np.arange(K_VIRT) % n, jnp.int32)
        active = jnp.asarray(np.arange(16) < n, bool)
        st, outs = step(st, b, fmu, active)
        trace.append(n)
    jax.block_until_ready(st.comparisons)
    dt = time.perf_counter() - t0
    tput = 128 * len(batches) / dt
    emit("q6_nyse_hedge", 1e6 / tput,
         f"{tput:.0f} t/s, {float(st.comparisons):.2e} comps, "
         f"{reconfigs} reconfigs, pi {min(trace)}..{max(trace)}")

    # dispatched window_join kernel: band prefilter counting over the same
    # stream (backend from the kernel dispatcher; run.py --backend sets it)
    stk = fast_join_init(K_VIRT, RING, 2)

    @jax.jit
    def kstep(st, batch):
        counts, comps = band_join_counts(st, batch, WS, band=0.5, n_attrs=2)
        st, _ = join_fast(WS, hedge_predicate(), st, batch,
                          jnp.ones((K_VIRT,), bool), out_cap=64, emit=False)
        return st, comps

    stk, comps = kstep(stk, batches[0])
    jax.block_until_ready(comps)
    total = 0.0
    t0 = time.perf_counter()
    for b in batches[1:]:
        stk, comps = kstep(stk, b)
        total += float(comps)
    dt = time.perf_counter() - t0
    emit("q6_nyse_kernel_join", 1e6 / max(total / dt, 1e-9),
         f"{total / dt:.2e} c/s dispatched window_join, comps={total:.3e}")


if __name__ == "__main__":
    main()
