"""Q3 (§8.3, Fig. 8): ScaleJoin band-join throughput (comparisons/s) for
increasing Pi(J+) in the *sliced* owner-computes layout (vsn.shard_tick's
state partitioning): each instance holds K/Pi key rows and compares each
incoming tuple only against them — total comparisons are Pi-invariant
(perfect work partitioning, the paper's disjoint-parallelism) and the
per-instance share is 1/Pi with <2% imbalance (paper Fig. 9 right).

On this 1-core container the instances execute sequentially (vmap), so
wall-clock is Pi-invariant too; on Pi cores/chips each slice runs in
parallel — the paper's linear scaling comes from the partitioning property
measured here."""

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.join import band_predicate, fast_join_init
from repro.core.join import tick_fast as join_fast
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 512
RING = 32
TICK = 256
WS = WindowSpec(wa=1, ws=5 * 60 * 1000, wt="single")
FJ = band_predicate(10.0, 2)


def run(n_inst: int, n_ticks: int = 8):
    rng = np.random.default_rng(3)
    k_loc = K_VIRT // n_inst
    st = fast_join_init(K_VIRT, RING, 4)
    st = jax.tree.map(
        lambda a: (a.reshape((n_inst, k_loc) + a.shape[1:])
                   if a.ndim and a.shape and a.shape[0] == K_VIRT
                   else jnp.broadcast_to(a, (n_inst,) + a.shape)), st)
    resp = jnp.ones((k_loc,), bool)

    def tick_one(st_j, off, batch):
        return join_fast(WS, FJ, st_j, batch, resp, out_cap=64, emit=False,
                         k_global=K_VIRT, k_offset=off)

    offs = jnp.arange(n_inst) * k_loc

    @jax.jit
    def step(st, batch):
        st, _ = jax.vmap(tick_one, in_axes=(0, 0, None))(st, offs, batch)
        return st

    batches = list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=TICK,
                                     k_virt=1))
    st = step(st, batches[0])
    jax.block_until_ready(st.comparisons)
    t0 = time.perf_counter()
    comps = np.zeros(n_inst)
    for b in batches[1:]:
        st = step(st, b)
        comps += np.asarray(st.comparisons)
    dt = time.perf_counter() - t0
    cv = comps.std() / max(comps.mean(), 1e-9) * 100
    return comps.sum() / dt, comps.sum(), cv, TICK * (n_ticks - 1) / dt


def main():
    base = None
    for n in (1, 2, 4, 8):
        cps, total, cv, tps = run(n)
        base = base or total
        emit(f"q3_scalejoin_pi{n}", 1e6 / tps,
             f"{cps:.2e} c/s, comps={total:.3e} ({total / base:.2f}x of pi1), "
             f"imbalance_cv={cv:.1f}%")


if __name__ == "__main__":
    main()
