"""Q3 (§8.3, Fig. 8): ScaleJoin band-join throughput (comparisons/s) for
increasing Pi(J+) in the *sliced* owner-computes layout (vsn.shard_tick's
state partitioning): each instance holds K/Pi key rows and compares each
incoming tuple only against them — total comparisons are Pi-invariant
(perfect work partitioning, the paper's disjoint-parallelism) and the
per-instance share is 1/Pi with <2% imbalance (paper Fig. 9 right).

On this 1-core container the instances execute sequentially (vmap), so
wall-clock is Pi-invariant too; on Pi cores/chips each slice runs in
parallel — the paper's linear scaling comes from the partitioning property
measured here.

``--mesh N`` runs the same sliced layout on an actual N-device mesh
(vsn.shard_tick + shard_map, batched multi-tick scan) instead of vmap.

``q3_band_kernel`` is the dispatched ``window_join`` path
(core.join.band_join_counts): the counting phase executed by the kernel
backend selected via ``--backend`` (xla oracle on CPU, Pallas on TPU).

``--async`` runs the ScaleJoin fast path inside the full VSN pipeline
under ``AsyncStreamRuntime`` (overlapped ingest of the two-stream q3
workload) vs the synchronous host loop — overlap gain, tick-latency
p50/p99, and exact async-vs-sync output parity.
"""

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.join import band_join_counts, band_predicate, fast_join_init
from repro.core.join import tick_fast as join_fast
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 512
RING = 32
TICK = 256
WS = WindowSpec(wa=1, ws=5 * 60 * 1000, wt="single")
FJ = band_predicate(10.0, 2)
BAND, N_ATTRS = 10.0, 2


def run(n_inst: int, n_ticks: int = 8):
    rng = np.random.default_rng(3)
    k_loc = K_VIRT // n_inst
    st = fast_join_init(K_VIRT, RING, 4)
    st = jax.tree.map(
        lambda a: (a.reshape((n_inst, k_loc) + a.shape[1:])
                   if a.ndim and a.shape and a.shape[0] == K_VIRT
                   else jnp.broadcast_to(a, (n_inst,) + a.shape)), st)
    resp = jnp.ones((k_loc,), bool)

    def tick_one(st_j, off, batch):
        return join_fast(WS, FJ, st_j, batch, resp, out_cap=64, emit=False,
                         k_global=K_VIRT, k_offset=off)

    offs = jnp.arange(n_inst) * k_loc

    @jax.jit
    def step(st, batch):
        st, _ = jax.vmap(tick_one, in_axes=(0, 0, None))(st, offs, batch)
        return st

    batches = list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=TICK,
                                     k_virt=1))
    st = step(st, batches[0])
    jax.block_until_ready(st.comparisons)
    t0 = time.perf_counter()
    comps = np.zeros(n_inst)
    for b in batches[1:]:
        st = step(st, b)
        comps += np.asarray(st.comparisons)
    dt = time.perf_counter() - t0
    cv = comps.std() / max(comps.mean(), 1e-9) * 100
    return comps.sum() / dt, comps.sum(), cv, TICK * (n_ticks - 1) / dt


def run_mesh(n_shards: int, n_ticks: int = 8):
    """The same sliced layout executed on a real device mesh: one
    shard_map-compiled step scans the whole tick stack (batched ingest)."""
    from repro.core import vsn
    from repro.launch.mesh import make_stream_mesh

    rng = np.random.default_rng(3)
    mesh = make_stream_mesh(n_shards)
    sigma = fast_join_init(K_VIRT, RING, 4)
    sigma = dataclasses.replace(
        sigma, comparisons=jnp.zeros((n_shards,), jnp.float32))
    sigma = vsn.mesh_device_put(sigma, mesh, "i", K_VIRT)
    step = jax.jit(vsn.shard_tick(
        mesh, "i", K_VIRT,
        vsn.join_local_tick(WS, FJ, K_VIRT, out_cap=64, emit=False), sigma))

    batches = list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=TICK,
                                     k_virt=1))
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches[1:])
    sigma, _ = step(sigma, jax.tree.map(lambda *xs: jnp.stack(xs),
                                        *batches[:1]))
    sigma0 = sigma
    comps0 = np.asarray(sigma0.comparisons)   # warm-up tick's share
    sigma, _ = step(sigma0, stack)          # compile the batched step
    jax.block_until_ready(sigma.comparisons)
    t0 = time.perf_counter()
    sigma, _ = step(sigma0, stack)
    comps = np.asarray(sigma.comparisons) - comps0
    dt = time.perf_counter() - t0
    cv = comps.std() / max(comps.mean(), 1e-9) * 100
    from repro.launch.mesh import collective_bytes
    coll = collective_bytes(step.lower(sigma0, stack).compile().as_text())
    return comps.sum() / dt, comps.sum(), cv, sum(coll.values())


def run_band_kernel(n_ticks: int = 8):
    """Counting-only band join through the dispatched window_join kernel."""
    rng = np.random.default_rng(3)
    st = fast_join_init(K_VIRT, RING, 4)
    resp = jnp.ones((K_VIRT,), bool)

    @jax.jit
    def step(st, batch):
        counts, comps = band_join_counts(st, batch, WS, band=BAND,
                                         n_attrs=N_ATTRS)
        st, _ = join_fast(WS, FJ, st, batch, resp, out_cap=64, emit=False)
        return st, counts, comps

    batches = list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=TICK,
                                     k_virt=1))
    st, counts, comps = step(st, batches[0])
    jax.block_until_ready(comps)
    total = 0.0
    t0 = time.perf_counter()
    for b in batches[1:]:
        st, counts, comps = step(st, b)
        total += float(comps)
    dt = time.perf_counter() - t0
    return total / dt, total


def run_async(n_ticks: int = 16):
    """The join fast path as a VSNPipeline tick (monolithic layout, resp
    masks per instance) driven by the async runtime vs the sync host loop."""
    from repro.core.async_runtime import AsyncStreamRuntime, run_sync
    from repro.core.join import scalejoin_def
    from repro.core.runtime import VSNPipeline
    from repro.core.vsn import merge_fast_state
    from repro.io import SyntheticSource

    # lighter than the comparisons-only sweep above: the emitting join
    # materializes [B, K, ring, 2P] candidate payloads per instance, so the
    # async variant measures the full pipeline at q3 *shape*, reduced size.
    # the fast path stores one tuple per key per tick, so the ready batch
    # (stash 32 + tick 64 + pad) must stay <= k
    n_inst, k, ring, tick, out_cap = 4, 128, 8, 64, 256
    op = scalejoin_def(WS, k, FJ, payload_width=4, ring=ring,
                       out_cap=out_cap)

    def join_tick(op_, st, ready, resp, explicit_w=None):
        return join_fast(WS, FJ, st, ready, resp, out_cap=out_cap)

    def make_pipe():
        return VSNPipeline(op, n_max=n_inst, n_active=n_inst, stash_cap=32,
                           tick_fn=join_tick, merge_fn=merge_fast_state,
                           init_sigma=lambda: fast_join_init(k, ring, 4))

    def gen():
        rng = np.random.default_rng(3)
        return datagen.scalejoin(rng, n_ticks=n_ticks, tick=tick, k_virt=1)

    warm = next(iter(gen()))
    async_pipe = make_pipe()
    async_pipe.step(warm)
    rt = AsyncStreamRuntime(async_pipe, SyntheticSource(gen(), n_inputs=2),
                            queue_cap=4)
    rep_a = rt.run()

    sync_pipe = make_pipe()
    sync_pipe.step(warm)
    rep_s, sink_s = run_sync(sync_pipe, SyntheticSource(gen(), n_inputs=2))
    ok = rt.sink.results() == sink_s.results()
    return rep_a, rep_s, ok


def run_device_resident(n_hosts: int, n_ticks: int = 96,
                        super_batch: int = 8):
    """Device-resident hot path vs per-tick host-merge baseline on the
    two-stream q3 workload through the join fast path (reduced shape,
    small ticks — see q1.run_device_resident; the fast path stores one
    tuple per key per tick, so the ready batch — stash 32 + the
    device-merged round's cap+chunks lanes — must stay <= k)."""
    from benchmarks.common import run_device_resident_bench
    from repro.core.join import scalejoin_def
    from repro.core.runtime import VSNPipeline
    from repro.core.vsn import merge_fast_state

    n_inst, k, ring, tick, out_cap = 4, 256, 4, 16, 64
    n_sources = 2                # the q3 workload is two-stream by contract
    n_leaves = min(n_hosts, n_sources)
    op = scalejoin_def(WS, k, FJ, payload_width=4, ring=ring,
                       out_cap=out_cap)

    def join_tick(op_, st, ready, resp, explicit_w=None):
        return join_fast(WS, FJ, st, ready, resp, out_cap=out_cap)

    def make_stream():
        rng = np.random.default_rng(3)
        return datagen.scalejoin(rng, n_ticks=n_ticks, tick=tick, k_virt=1)

    def make_pipe():
        return VSNPipeline(op, n_max=n_inst, n_active=n_inst, stash_cap=32,
                           tick_fn=join_tick, merge_fn=merge_fast_state,
                           init_sigma=lambda: fast_join_init(k, ring, 4))

    res, parity = run_device_resident_bench(make_stream, n_sources,
                                            n_leaves, make_pipe, tick=tick,
                                            super_batch=super_batch)
    return res, parity


def run_ingest(n_leaves: int, n_ticks: int = 12):
    """Multihost ingest over the two-stream q3 workload: one leaf gate per
    physical stream (L/R source ids double as ingest source ids), root-merge
    throughput scaling vs leaf count, tier-vs-flat-gate parity."""
    from benchmarks.common import run_ingest_bench

    n_sources = 2                # the q3 workload is two-stream by contract
    n_leaves = min(n_leaves, n_sources)
    batches = list(datagen.scalejoin(np.random.default_rng(3),
                                     n_ticks=n_ticks, tick=TICK, k_virt=1))
    tput, _, ok = run_ingest_bench(batches, n_sources, n_leaves, tick=TICK)
    return tput, ok, n_leaves


def main(mesh: int = 0, async_: bool = False, ingest_hosts: int = 0):
    base = None
    for n in (1, 2, 4, 8):
        cps, total, cv, tps = run(n)
        base = base or total
        emit(f"q3_scalejoin_pi{n}", 1e6 / tps,
             f"{cps:.2e} c/s, comps={total:.3e} ({total / base:.2f}x of pi1), "
             f"imbalance_cv={cv:.1f}%")
    kcps, ktotal = run_band_kernel()
    emit("q3_band_kernel", 1e6 / max(kcps, 1e-9),
         f"{kcps:.2e} c/s dispatched window_join, comps={ktotal:.3e}")
    if async_:
        rep_a, rep_s, ok = run_async()
        gain = rep_a.throughput_tps / max(rep_s.throughput_tps, 1e-9)
        emit("q3_scalejoin_async", 1e6 / max(rep_a.throughput_tps, 1e-9),
             f"{rep_a.throughput_tps:.0f} t/s async vs "
             f"{rep_s.throughput_tps:.0f} t/s sync host loop "
             f"(overlap {gain:.2f}x), outputs_match_sync={ok}",
             p50_ms=rep_a.p50_ms, p99_ms=rep_a.p99_ms)
    if ingest_hosts:
        tput, ok, leaves_used = run_ingest(ingest_hosts)
        for leaves, tps in sorted(tput.items()):
            emit(f"q3_ingest_root_tput_leaves{leaves}",
                 1e6 / max(tps, 1e-9),
                 f"{tps:.0f} t/s root merge, {leaves} leaf workers")
        scale = tput[leaves_used] / max(tput[1], 1e-9)
        emit(f"q3_scalejoin_ingest{leaves_used}",
             1e6 / max(tput[leaves_used], 1e-9),
             f"{leaves_used}-leaf/1-leaf root tput {scale:.2f}x, "
             f"outputs_match_oracle={ok}")
    if async_ and ingest_hosts:
        from benchmarks.q1_wordcount import emit_device_resident
        res, parity = run_device_resident(ingest_hosts)
        emit_device_resident("q3_scalejoin", res, parity)
    if mesh:
        if len(jax.devices()) < mesh:
            emit("q3_mesh_SKIP", 0.0,
                 f"needs {mesh} devices, have {len(jax.devices())}")
            return
        cps, total, cv, coll = run_mesh(mesh)
        emit(f"q3_scalejoin_mesh{mesh}", 1e6 / max(cps, 1e-9),
             f"{cps:.2e} c/s on {mesh}-device mesh, comps={total:.3e} "
             f"({total / base:.2f}x of pi1), imbalance_cv={cv:.1f}%, "
             f"collective_bytes={coll}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0)
    ap.add_argument("--async", dest="async_", action="store_true")
    ap.add_argument("--ingest-hosts", type=int, default=0)
    a = ap.parse_args()
    main(mesh=a.mesh, async_=a.async_, ingest_hosts=a.ingest_hosts)
