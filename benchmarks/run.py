"""Benchmark harness: one module per paper table/figure (Q1-Q6) + kernels.

``python -m benchmarks.run [--backend xla|pallas|pallas-interpret]`` prints
``name,us_per_call,derived`` CSV rows (plus the §Roofline pointer — the
roofline table itself is produced by repro.launch.roofline against the
dry-run artifacts).  ``--backend`` sets the kernel dispatch default for the
whole run; unset, it resolves to ``xla`` on CPU hosts and ``pallas`` on TPU
(see ``repro.kernels.dispatch``).
"""

import argparse
import os
import sys
import traceback

# plain `python -m benchmarks.run` from a checkout: put src/ on the path
# (pytest gets this from pyproject's pythonpath; bare python does not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def main(argv=None) -> None:
    import inspect

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="kernel dispatch backend (default: xla on CPU, "
                         "pallas on TPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run "
                         "(e.g. kernels_bench,q1_wordcount)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="run mesh variants over N devices where a bench "
                         "supports it (emulate with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="run live-runtime async variants where a bench "
                         "supports them (q1/q3/q5: AsyncStreamRuntime "
                         "overlap gain, tick-latency quantiles, "
                         "detection→switch latency, async-vs-sync parity)")
    ap.add_argument("--ingest-hosts", type=int, default=0,
                    help="run multihost-ingest variants where a bench "
                         "supports them (q1/q3: N-leaf hierarchical "
                         "ScaleGate root-merge throughput scaling + "
                         "parity vs the single-gate oracle; combine with "
                         "--mesh for the mesh-pipeline parity gate)")
    ap.add_argument("--csv", default=None,
                    help="also write the result rows to this CSV file "
                         "(CI uploads it as a workflow artifact)")
    ap.add_argument("--bench-dir", default=None,
                    help="also write one BENCH_<module>.json "
                         "perf-trajectory artifact per q-module into this "
                         "directory (run config + that module's rows; "
                         "q1_wordcount -> BENCH_q1.json)")
    ap.add_argument("--obs-export", default=None, metavar="DIR",
                    help="install the observability layer (metrics + "
                         "flight recorder + tracing) for the whole bench "
                         "run and export metrics.json/metrics.prom/"
                         "flight.json into DIR at the end — informational "
                         "(instrumentation is live, so rows are not "
                         "comparable to an uninstrumented run)")
    args = ap.parse_args(argv)

    if args.obs_export:
        from repro import obs as _obs
        _obs.install(_obs.ObsConfig(enabled=True, trace=True,
                                    export_dir=args.obs_export))

    from repro.kernels import dispatch
    dispatch.set_default_backend(args.backend)
    print(f"# backend={dispatch.default_backend()}", flush=True)
    print("name,us_per_call,derived[,latency columns]")
    from benchmarks import common
    from benchmarks import (kernels_bench, q1_wordcount, q2_forward,
                            q3_scalejoin, q4_reconfig, q5_elastic_stress,
                            q6_nyse, q7_serving)
    mods = (q1_wordcount, q2_forward, q3_scalejoin, q4_reconfig,
            q5_elastic_stress, q6_nyse, q7_serving, kernels_bench)
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        names = {m.__name__.split(".")[-1] for m in mods}
        unknown = keep - names
        if unknown:
            ap.error(f"--only: unknown module(s) {sorted(unknown)}; "
                     f"choose from {sorted(names)}")
        mods = tuple(m for m in mods if m.__name__.split(".")[-1] in keep)
    ok = True
    row_span = {}                      # module name -> its slice of ROWS
    for mod in mods:
        params = inspect.signature(mod.main).parameters
        kw = {}
        if "mesh" in params:
            kw["mesh"] = args.mesh
        if "async_" in params:
            kw["async_"] = args.async_
        if "ingest_hosts" in params:
            kw["ingest_hosts"] = args.ingest_hosts
        row0 = len(common.ROWS)
        try:
            mod.main(**kw)
        except Exception:
            ok = False
            common.emit(mod.__name__, 0.0, "FAIL (exception)")
            traceback.print_exc()
        row_span[mod.__name__.split(".")[-1]] = (row0, len(common.ROWS))
    bad = common.failed_rows()
    if args.csv:
        common.write_csv(args.csv)
    if args.bench_dir:
        import jax
        os.makedirs(args.bench_dir, exist_ok=True)
        config = dict(backend=dispatch.default_backend(), mesh=args.mesh,
                      async_=args.async_, ingest_hosts=args.ingest_hosts,
                      n_devices=len(jax.devices()))
        for name, (lo, hi) in row_span.items():
            if hi == lo:
                continue
            # q1_wordcount -> BENCH_q1.json; kernels_bench -> BENCH_kernels_bench.json
            short = name.split("_")[0] if name.startswith("q") else name
            path = os.path.join(args.bench_dir, f"BENCH_{short}.json")
            common.write_bench_json(path, name, common.ROWS[lo:hi], config)
            print(f"# wrote {path}", flush=True)
    if args.obs_export:
        from repro import obs as _obs
        o = _obs.get()
        if o is not None:
            paths = o.export(args.obs_export)
            print(f"# obs export: {sorted(paths.values())}", flush=True)
    if bad:
        print(f"# {len(bad)} FAIL row(s):", file=sys.stderr)
        for name, _, derived in bad:
            print(f"#   {name}: {derived}", file=sys.stderr)
    # the bench run gates: any FAIL row (not just exceptions) is nonzero
    if not ok or bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
