"""Benchmark harness: one module per paper table/figure (Q1-Q6) + kernels.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,
derived`` CSV rows (plus the §Roofline pointer — the roofline table itself
is produced by repro.launch.roofline against the dry-run artifacts).
"""

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (kernels_bench, q1_wordcount, q2_forward,
                            q3_scalejoin, q4_reconfig, q5_elastic_stress,
                            q6_nyse)
    ok = True
    for mod in (q1_wordcount, q2_forward, q3_scalejoin, q4_reconfig,
                q5_elastic_stress, q6_nyse, kernels_bench):
        try:
            mod.main()
        except Exception:
            ok = False
            print(f"{mod.__name__},FAIL,", flush=True)
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
