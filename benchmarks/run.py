"""Benchmark harness: one module per paper table/figure (Q1-Q6) + kernels.

``python -m benchmarks.run [--backend xla|pallas|pallas-interpret]`` prints
``name,us_per_call,derived`` CSV rows (plus the §Roofline pointer — the
roofline table itself is produced by repro.launch.roofline against the
dry-run artifacts).  ``--backend`` sets the kernel dispatch default for the
whole run; unset, it resolves to ``xla`` on CPU hosts and ``pallas`` on TPU
(see ``repro.kernels.dispatch``).
"""

import argparse
import os
import sys
import traceback

# plain `python -m benchmarks.run` from a checkout: put src/ on the path
# (pytest gets this from pyproject's pythonpath; bare python does not)
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="kernel dispatch backend (default: xla on CPU, "
                         "pallas on TPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run "
                         "(e.g. kernels_bench,q1_wordcount)")
    args = ap.parse_args(argv)

    from repro.kernels import dispatch
    dispatch.set_default_backend(args.backend)
    print(f"# backend={dispatch.default_backend()}", flush=True)
    print("name,us_per_call,derived")
    from benchmarks import (kernels_bench, q1_wordcount, q2_forward,
                            q3_scalejoin, q4_reconfig, q5_elastic_stress,
                            q6_nyse)
    mods = (q1_wordcount, q2_forward, q3_scalejoin, q4_reconfig,
            q5_elastic_stress, q6_nyse, kernels_bench)
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        names = {m.__name__.split(".")[-1] for m in mods}
        unknown = keep - names
        if unknown:
            ap.error(f"--only: unknown module(s) {sorted(unknown)}; "
                     f"choose from {sorted(names)}")
        mods = tuple(m for m in mods if m.__name__.split(".")[-1] in keep)
    ok = True
    for mod in mods:
        try:
            mod.main()
        except Exception:
            ok = False
            print(f"{mod.__name__},FAIL,", flush=True)
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
