"""Shared benchmark scaffolding: timed pipeline drives + CSV rows."""

from __future__ import annotations

import time

import numpy as np
import jax

ROWS = []

CSV_HEADER = ["name", "us_per_call", "derived", "p50_ms", "p99_ms",
              "detect_switch_ms"]


def emit(name: str, us_per_call: float, derived: str = "", *,
         p50_ms: float = None, p99_ms: float = None,
         detect_switch_ms: float = None):
    """One result row.  The optional latency columns (tick-latency p50/p99
    and detection→switch latency, all ms) come from the live-runtime
    variants; plain rows leave them empty in the CSV."""
    ROWS.append((name, us_per_call, derived, p50_ms, p99_ms,
                 detect_switch_ms))
    extra = "".join(
        f",{k}={v:.2f}" for k, v in [("p50_ms", p50_ms), ("p99_ms", p99_ms),
                                     ("d2s_ms", detect_switch_ms)]
        if v is not None)
    print(f"{name},{us_per_call:.1f},{derived}{extra}", flush=True)


def failed_rows():
    """Rows that signal a failure: a FAIL marker in the name or derived
    column (e.g. ``outputs_match_static=False``).  SKIP rows don't count."""
    bad = []
    for row in ROWS:
        name, us, derived = row[0], row[1], row[2]
        text = f"{name} {derived}"
        if "FAIL" in text or "=False" in text:
            bad.append((name, us, derived))
    return bad


def write_csv(path: str):
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)   # quotes the comma-laden derived column
        w.writerow(CSV_HEADER)
        for name, us, derived, p50, p99, d2s in ROWS:
            w.writerow([name, f"{us:.1f}", derived]
                       + [("" if v is None else f"{v:.3f}")
                          for v in (p50, p99, d2s)])


def run_ingest_bench(batches, n_sources: int, n_leaves: int, *, tick: int,
                     oracle_cap: int = None):
    """Shared multihost-ingest harness (q1/q3): root-merge throughput per
    leaf count in {1, n_leaves} (warm-jit pass then timed pass), plus a
    recorded pass checked tuple-for-tuple against the single-ScaleGate
    oracle.  Returns ``(tput_by_leaves, tier_ticks, tier_parity_ok)``."""
    from repro.ingest import (IngestTier, collect_tuples,
                              single_gate_stream)

    kw = dict(worker="thread", leaf_cap=tick, root_cap=2 * tick,
              out_pad=2 * tick)
    tput = {}
    for leaves in sorted({1, n_leaves}):
        list(IngestTier(batches, n_sources, leaves, **kw))   # warm jits
        tier = IngestTier(batches, n_sources, leaves, **kw)
        t0 = time.perf_counter()
        list(tier)
        tput[leaves] = tier.stats().tuples_out / (time.perf_counter() - t0)
    tier = IngestTier(batches, n_sources, n_leaves, record=True, **kw)
    tier_ticks = list(tier)
    oracle = single_gate_stream(batches, n_sources,
                                cap=oracle_cap or 3 * tick)
    ok = collect_tuples(tier_ticks) == collect_tuples(oracle)
    return tput, tier_ticks, ok


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out
