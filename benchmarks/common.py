"""Shared benchmark scaffolding: timed pipeline drives + CSV rows."""

from __future__ import annotations

import time

import numpy as np
import jax

ROWS = []

CSV_HEADER = ["name", "us_per_call", "derived", "p50_ms", "p99_ms",
              "detect_switch_ms"]


def emit(name: str, us_per_call: float, derived: str = "", *,
         p50_ms: float = None, p99_ms: float = None,
         detect_switch_ms: float = None):
    """One result row.  The optional latency columns (tick-latency p50/p99
    and detection→switch latency, all ms) come from the live-runtime
    variants; plain rows leave them empty in the CSV."""
    ROWS.append((name, us_per_call, derived, p50_ms, p99_ms,
                 detect_switch_ms))
    extra = "".join(
        f",{k}={v:.2f}" for k, v in [("p50_ms", p50_ms), ("p99_ms", p99_ms),
                                     ("d2s_ms", detect_switch_ms)]
        if v is not None)
    print(f"{name},{us_per_call:.1f},{derived}{extra}", flush=True)


def failed_rows():
    """Rows that signal a failure: a FAIL marker in the name or derived
    column (e.g. ``outputs_match_static=False``).  SKIP rows don't count."""
    bad = []
    for row in ROWS:
        name, us, derived = row[0], row[1], row[2]
        text = f"{name} {derived}"
        if "FAIL" in text or "=False" in text:
            bad.append((name, us, derived))
    return bad


def write_csv(path: str):
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)   # quotes the comma-laden derived column
        w.writerow(CSV_HEADER)
        for name, us, derived, p50, p99, d2s in ROWS:
            w.writerow([name, f"{us:.1f}", derived]
                       + [("" if v is None else f"{v:.3f}")
                          for v in (p50, p99, d2s)])


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out
