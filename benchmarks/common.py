"""Shared benchmark scaffolding: timed pipeline drives + CSV rows."""

from __future__ import annotations

import json
import re
import time

import numpy as np
import jax

ROWS = []

CSV_HEADER = ["name", "us_per_call", "derived", "p50_ms", "p99_ms",
              "detect_switch_ms", "detect_recover_ms"]


def emit(name: str, us_per_call: float, derived: str = "", *,
         p50_ms: float = None, p99_ms: float = None,
         detect_switch_ms: float = None, detect_recover_ms: float = None):
    """One result row.  The optional latency columns (tick-latency p50/p99,
    detection→switch latency, and the fault-tolerance twin
    detection→recovered latency, all ms) come from the live-runtime and
    recovery variants; plain rows leave them empty in the CSV."""
    ROWS.append((name, us_per_call, derived, p50_ms, p99_ms,
                 detect_switch_ms, detect_recover_ms))
    extra = "".join(
        f",{k}={v:.2f}" for k, v in [("p50_ms", p50_ms), ("p99_ms", p99_ms),
                                     ("d2s_ms", detect_switch_ms),
                                     ("d2r_ms", detect_recover_ms)]
        if v is not None)
    print(f"{name},{us_per_call:.1f},{derived}{extra}", flush=True)


def failed_rows():
    """Rows that signal a failure: a FAIL marker in the name or derived
    column (e.g. ``outputs_match_static=False``).  SKIP rows don't count."""
    bad = []
    for row in ROWS:
        name, us, derived = row[0], row[1], row[2]
        text = f"{name} {derived}"
        if "FAIL" in text or "=False" in text:
            bad.append((name, us, derived))
    return bad


def write_csv(path: str):
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)   # quotes the comma-laden derived column
        w.writerow(CSV_HEADER)
        for name, us, derived, p50, p99, d2s, d2r in ROWS:
            w.writerow([name, f"{us:.1f}", derived]
                       + [("" if v is None else f"{v:.3f}")
                          for v in (p50, p99, d2s, d2r)])


TPUT_RE = re.compile(r"([0-9][0-9.e+]*)\s*t/s")


def write_bench_json(path: str, query: str, rows, config: dict):
    """Perf-trajectory artifact (``BENCH_q<id>.json``): the run config plus
    this query's result rows.  ``tput_tps`` is parsed from the first
    ``<N> t/s`` figure in the derived column when present, else derived
    from us_per_call; rows without either leave it null."""
    out_rows = []
    for name, us, derived, p50, p99, d2s, d2r in rows:
        m = TPUT_RE.search(derived or "")
        tput = (float(m.group(1)) if m
                else (1e6 / us if us else None))
        out_rows.append(dict(name=name, us_per_call=us, tput_tps=tput,
                             p50_ms=p50, p99_ms=p99, detect_switch_ms=d2s,
                             detect_recover_ms=d2r, derived=derived))
    with open(path, "w") as f:
        json.dump(dict(query=query, config=config, rows=out_rows), f,
                  indent=2)
        f.write("\n")


def run_device_resident_bench(make_stream, n_sources: int, n_leaves: int,
                              make_pipe, *, tick: int, super_batch: int = 8,
                              queue_cap: int = 4, oracle_cap: int = None,
                              reps: int = 3):
    """Device-resident hot path vs the per-tick host-merge baseline on the
    identical multihost stream (q1/q3 shared harness).

    * baseline — ``RootMerge`` on host (one watermark sync per merge
      round) feeding one compiled step dispatch per tick;
    * device   — fused stacked-leaf root merge (``RootMerge(device=True)``)
      feeding the persistent compiled K-tick scan (``super_batch=K``).

    The gated comparison isolates the *hot path* the PR changes: the leaf
    rounds are prerecorded once (leaf ingest is byte-identical in both
    variants and, on a single-core CPU host, dominates end-to-end time),
    then each variant's merge→step loop runs once from fresh state for the
    parity outputs and ``reps`` more times on the warm executables for the
    best-of timing.  An end-to-end async pass (full ``IngestTier`` +
    ``AsyncStreamRuntime``) runs last as the informational whole-system
    rows.  Single-core CPU caveat: XLA "device" compute shares the one
    core with ingest, so the tick math itself is not accelerated — the
    hot-path speedup here measures what the fused merge + persistent scan
    remove (per-tick dispatch, watermark syncs, staging); on a real
    accelerator the same code path also overlaps host/device work.

    Returns ``(res, parity)``: ``res["hot"]`` (host_tps/dev_tps/speedup/
    fill), ``res["host"|"device"]["report"]`` (end-to-end), and the
    exact-output gates (device-merged stream vs single-ScaleGate oracle,
    host-variant vs device-variant output multisets, device-variant vs a
    synchronous replay of its own merged stream)."""
    from repro.core import tuples as T
    from repro.core.async_runtime import AsyncStreamRuntime
    from repro.ingest import IngestTier, collect_tuples, single_gate_stream
    from repro.ingest import leaf as L
    from repro.ingest.root import RootMerge, bucket
    from repro.ingest.tier import SourcePartitioner
    from repro.io import NullSink
    from repro.io.sinks import flatten_outputs

    batches = list(make_stream())
    kmax, pw = batches[0].kmax, batches[0].payload_width
    part = SourcePartitioner(n_sources, range(n_leaves))

    # prerecord the leaf rounds (identical input to both merge variants)
    gates = {l: L.LeafGate(l, n_sources, part.owned_mask(l), tick, kmax, pw)
             for l in part.leaves}
    rounds = []
    for r, b in enumerate(batches):
        b_np = L.batch_to_np(b)
        keep = b_np["valid"]
        leaf_of = part.assignment[np.clip(b_np["source"], 0, n_sources - 1)]
        rounds.append([gates[l].push_round(
            r, {f: b_np[f][keep & (leaf_of == l)] for f in L.FIELDS})
            for l in part.leaves])
    fin = []
    for l in part.leaves:
        gates[l].flush_all()
        fin.append(gates[l].push_round(len(batches), None, final=True))
    rounds.append(fin)
    ntup = sum(int((np.asarray(b.valid) & ~np.asarray(b.is_control)).sum())
               for b in batches)

    # identical fixed-shape output contract for both variants: the device
    # path reserves one chunk per leaf (cap + n_leaves*chunk lanes), so the
    # host baseline buckets from the same floor — otherwise the comparison
    # measures lane-count padding (every lane costs real compute per tick
    # downstream), not the merge/dispatch/sync overhead the PR removes
    chunk = bucket(tick)

    def make_root(device):
        return RootMerge(max(2 * n_leaves, n_leaves + 4), 2 * tick, kmax,
                         pw, part.leaves,
                         out_pad=(tick if device else n_leaves * chunk),
                         device=device, check_every=8)

    def drive_host(pipe, root, collect=None):
        for outs in rounds:
            rb = root.push(outs)
            o1, o2, sw, il = pipe.step_staged(rb)
            bool(sw), np.asarray(il)      # control-lane syncs, as in live
            if collect is not None:
                collect.append((rb, o1, o2))

    fill = [0, 0]                         # dispatches, ticks dispatched

    def drive_device(pipe, root, collect=None):
        group, key = [], [None]

        def flush():
            if not group:
                return
            b0 = group[0]
            pad = [T.empty_batch(b0.batch, b0.kmax, b0.payload_width)
                   ] * (super_batch - len(group))
            out = pipe.run_persistent_staged(pipe.stage_super(group + pad))
            bool(out.switched.any()), np.asarray(out.inst_load.sum(axis=0))
            fill[0] += 1
            fill[1] += len(group)
            if collect is not None:
                collect.append((list(group), out))
            del group[:]

        for outs in rounds:
            rb = root.push(outs)
            k2 = (rb.batch, rb.kmax, rb.payload_width)
            if group and k2 != key[0]:
                flush()                   # shape change: flush the group
            group.append(rb)
            key[0] = k2
            if len(group) == super_batch:
                flush()
        flush()

    # fresh-state pass: compiles everything + yields the parity outputs
    pipe_h, pipe_d = make_pipe(), make_pipe()
    coll_h, coll_d = [], []
    drive_host(pipe_h, make_root(False), coll_h)
    drive_device(pipe_d, make_root(True), coll_d)
    host_outs = sorted(sum((flatten_outputs(o1) + flatten_outputs(o2)
                            for _, o1, o2 in coll_h), []))
    dev_outs = sorted(sum(
        (flatten_outputs(o.outs_pre) + flatten_outputs(o.outs_post)
         for _, o in coll_d), []))
    dev_emitted = [rb for grp, _ in coll_d for rb in grp]

    pipe_s = make_pipe()                  # sequential replay oracle
    sync_outs = []
    for rb in dev_emitted:
        o1, o2, _ = pipe_s.step(rb)
        sync_outs += flatten_outputs(o1) + flatten_outputs(o2)
    oracle = single_gate_stream(list(make_stream()), n_sources,
                                cap=oracle_cap or 3 * tick)
    parity = dict(
        tier=collect_tuples(dev_emitted) == collect_tuples(oracle),
        pipeline=host_outs == dev_outs,
        sync=sorted(sync_outs) == dev_outs,
    )

    # timed reps on the warm executables (fresh roots, best-of timing —
    # single-core scheduler noise makes mean/median unstable)
    fill[0] = fill[1] = 0
    hs, ds = [], []
    for _ in range(reps):
        root = make_root(False)
        t0 = time.perf_counter()
        drive_host(pipe_h, root)
        hs.append(ntup / (time.perf_counter() - t0))
        root = make_root(True)
        t0 = time.perf_counter()
        drive_device(pipe_d, root)
        ds.append(ntup / (time.perf_counter() - t0))
    res = {"hot": dict(host_tps=max(hs), dev_tps=max(ds),
                       speedup=max(ds) / max(max(hs), 1e-9),
                       fill=fill[1] / max(fill[0], 1), reps=reps,
                       ntup=ntup)}

    # end-to-end async pass (informational): full tier + async runtime
    for name, device, sb, pipe in (("host", False, 1, pipe_h),
                                   ("device", True, super_batch, pipe_d)):
        tier = IngestTier(make_stream(), n_sources, n_leaves,
                          worker="thread", leaf_cap=tick,
                          root_cap=2 * tick,
                          out_pad=(tick if device else n_leaves * chunk),
                          root_device=device)
        rt = AsyncStreamRuntime(pipe, tier, sink=NullSink(),
                                queue_cap=queue_cap, super_batch=sb)
        res[name] = dict(report=rt.run())
    return res, parity


def run_ingest_bench(batches, n_sources: int, n_leaves: int, *, tick: int,
                     oracle_cap: int = None):
    """Shared multihost-ingest harness (q1/q3): root-merge throughput per
    leaf count in {1, n_leaves} (warm-jit pass then timed pass), plus a
    recorded pass checked tuple-for-tuple against the single-ScaleGate
    oracle.  Returns ``(tput_by_leaves, tier_ticks, tier_parity_ok)``."""
    from repro.ingest import (IngestTier, collect_tuples,
                              single_gate_stream)

    kw = dict(worker="thread", leaf_cap=tick, root_cap=2 * tick,
              out_pad=2 * tick)
    tput = {}
    for leaves in sorted({1, n_leaves}):
        list(IngestTier(batches, n_sources, leaves, **kw))   # warm jits
        tier = IngestTier(batches, n_sources, leaves, **kw)
        t0 = time.perf_counter()
        list(tier)
        tput[leaves] = tier.stats().tuples_out / (time.perf_counter() - t0)
    tier = IngestTier(batches, n_sources, n_leaves, record=True, **kw)
    tier_ticks = list(tier)
    oracle = single_gate_stream(batches, n_sources,
                                cap=oracle_cap or 3 * tick)
    ok = collect_tuples(tier_ticks) == collect_tuples(oracle)
    return tput, tier_ticks, ok


def run_recovery_bench(name: str, cfg, batches, *, mode: str = "stop",
                       crash_after: int = 6, crash_mid_save: bool = True):
    """Kill-and-restore as a measured bench row: runs
    ``repro.launch.recovery.kill_restore_drill`` on an ``api.RuntimeConfig``
    stack (victim → latest complete manifest → identical rebuilt stack →
    replay) and emits one parity-gated row whose ``detect_recover_ms``
    column is the detection→recovered latency — the fault-tolerance twin of
    the detection→switch column.  ``exactly_once=False`` in the derived
    text makes it a FAIL row (``failed_rows`` → nonzero bench exit)."""
    from repro.launch.recovery import kill_restore_drill

    rep = kill_restore_drill(cfg, batches, mode=mode,
                             crash_after=crash_after,
                             crash_mid_save=crash_mid_save)
    emit(name, rep.detect_to_recover_ms * 1e3,
         f"restored_step={rep.restored_step}, {rep.n_committed} committed "
         f"+ {rep.n_replayed} replayed, exactly_once={rep.parity}",
         detect_recover_ms=rep.detect_to_recover_ms)
    return rep


def _amplified_source(src, events_per_tick: int):
    """Detail-event pressure for the sampled variant: fire
    ``events_per_tick`` extra flight events per batch on the ingest thread
    — a ~10x event rate the sampler must absorb without widening the
    overhead gate.  Only ring detail thins; every counter still counts."""
    from repro import obs
    for b in src:
        for i in range(events_per_tick):
            obs.event("synthetic_load", seq=i)
        yield b


def run_obs_overhead_bench(make_pipe, make_source, warm, *,
                           queue_cap: int = 4, reps: int = 3,
                           synthetic_events: int = 10):
    """Observability cost gate: the identical async run under four obs
    settings — fully off (baseline), metrics+flight with tracing disabled
    (the always-on tier, gated <2%), full span tracing (gated <10%), and
    full tracing under adaptive head sampling while the source fires
    ``synthetic_events`` extra flight events per tick (~10x the normal
    event rate; gated <2% — sampling must make tracing always-on cheap).

    Each variant gets a fresh pipeline compiled outside the timed window
    (``pipe.step(warm)``) and ``reps`` full runs; best-of throughput is
    compared (single-core scheduler noise makes means unstable).  The
    previously installed global ``Obs`` is restored afterwards, whatever
    happens — the bench must not leave its instrumentation behind.

    Returns per-variant tps, the relative overheads, ``parity`` (exact
    output-set equality across all variants — obs must never perturb
    results), and ``counters_exact`` (``bus.ticks``/``bus.tuples`` totals
    bit-identical between the trace and sampled runs: sampling thins
    detail records only, never accounting)."""
    from repro import obs
    from repro.core.async_runtime import AsyncStreamRuntime

    prev = obs.get()
    tps, results, counters = {}, {}, {}
    sampler_snap = {}
    try:
        for name, cfg, amplify in (
                ("off", None, 0),
                ("metrics", obs.ObsConfig(enabled=True, trace=False), 0),
                ("trace", obs.ObsConfig(enabled=True, trace=True), 0),
                ("sampled", obs.ObsConfig(
                    enabled=True, trace=True,
                    event_sample=1.0 / 64.0, span_sample=1.0 / 16.0,
                    event_budget_per_s=2000.0), synthetic_events)):
            obs.set_current(obs.Obs(cfg) if cfg is not None else None)
            best = 0.0
            for _ in range(reps):
                pipe = make_pipe()
                pipe.step(warm)               # compile outside the window
                src = make_source()
                if amplify:
                    src = _amplified_source(src, amplify)
                rt = AsyncStreamRuntime(pipe, src, queue_cap=queue_cap)
                rep = rt.run()
                best = max(best, rep.throughput_tps)
            tps[name] = best
            results[name] = rt.sink.results()
            o = obs.get()
            if o is not None and cfg.trace:
                counters[name] = {
                    k: v for k, v in o.snapshot()["counters"].items()
                    if k in ("bus.ticks", "bus.tuples")}
                if o.sampler is not None:
                    sampler_snap = o.sampler.snapshot()
    finally:
        obs.set_current(prev)
    base = max(tps["off"], 1e-9)
    return dict(
        base_tps=tps["off"], metrics_tps=tps["metrics"],
        trace_tps=tps["trace"], sampled_tps=tps["sampled"],
        metrics_overhead=1.0 - tps["metrics"] / base,
        trace_overhead=1.0 - tps["trace"] / base,
        sampled_overhead=1.0 - tps["sampled"] / base,
        counters_exact=(counters["trace"] == counters["sampled"]),
        sampler=sampler_snap,
        parity=(results["off"] == results["metrics"]
                == results["trace"] == results["sampled"]))


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out
