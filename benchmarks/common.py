"""Shared benchmark scaffolding: timed pipeline drives + CSV rows."""

from __future__ import annotations

import time

import numpy as np
import jax

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def failed_rows():
    """Rows that signal a failure: a FAIL marker in the name or derived
    column (e.g. ``outputs_match_static=False``).  SKIP rows don't count."""
    bad = []
    for name, us, derived in ROWS:
        text = f"{name} {derived}"
        if "FAIL" in text or "=False" in text:
            bad.append((name, us, derived))
    return bad


def write_csv(path: str):
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)   # quotes the comma-laden derived column
        w.writerow(["name", "us_per_call", "derived"])
        for name, us, derived in ROWS:
            w.writerow([name, f"{us:.1f}", derived])


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out
