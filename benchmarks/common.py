"""Shared benchmark scaffolding: timed pipeline drives + CSV rows."""

from __future__ import annotations

import time

import numpy as np
import jax

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out
