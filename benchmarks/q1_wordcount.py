"""Q1 (§8.1, Fig. 6): wordcount/paircount throughput+latency, VSN vs SN,
across the paper's duplication levels (wordcount, pair L/M/H).

VSN shares each tuple with all instances (no copies); SN expands each tuple
per Corollary 1 (one copy per responsible instance).  We report tuples/s,
per-tick latency, and the measured duplication factor — the paper's Fig. 6
trend is VSN >= SN with the gap growing in the duplication level.

``--mesh N`` additionally runs the VSN pipeline on an N-device mesh
(core.runtime.MeshPipeline) with batched multi-tick ingest — the scale-up
path; emulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--async`` runs the live-runtime variant: ``AsyncStreamRuntime`` overlaps
host ingest (datagen + device_put of tick T+1) with device compute of
tick T, against the synchronous host loop (``run_sync``) on the identical
stream — reporting the overlap gain, tick-latency p50/p99, and exact
async-vs-sync output-set parity (a FAIL row if they diverge).

``--ingest-hosts N`` runs the multihost variant: the workload is spread
over 2N physical sources and merged by the hierarchical multi-host
ScaleGate (``repro.ingest.IngestTier``, N leaf workers feeding the root
merge).  Reports root-merge throughput scaling vs leaf count and a
parity-gated row: the tier-merged stream must equal the single-ScaleGate
oracle tuple-for-tuple, and driving both streams through the same
pipeline (``MeshPipeline`` when combined with ``--mesh``) must produce
identical outputs.
"""

import time

import numpy as np
import jax

from benchmarks.common import emit, time_fn
from repro.core.aggregate import count_aggregate, fast_init
from repro.core.aggregate import tick_fast as agg_fast
from repro.core.runtime import MeshPipeline, SNPipeline, VSNPipeline
from repro.core.vsn import merge_fast_state
from repro.core.windows import WindowSpec
from repro.data import datagen

K_VIRT = 256
N_INST = 8
TICK = 256
WS = WindowSpec(wa=1000, ws=2000, wt="multi")   # 1s/2s windows (delta=ms)


def fast_tick(op, st, ready, resp, explicit_w=None):
    return agg_fast(op, "count", st, ready, resp)


def run_case(mode: str, wc_mode: str, pair_dist: int, n_ticks: int = 12):
    rng = np.random.default_rng(7)
    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)
    cls = VSNPipeline if mode == "vsn" else SNPipeline
    kw = dict(tick_fn=fast_tick)
    if mode == "vsn":
        kw["merge_fn"] = merge_fast_state
        kw["init_sigma"] = lambda: fast_init(op.resolved())
    pipe = cls(op, n_max=N_INST, n_active=N_INST, stash_cap=TICK, **kw)
    if mode == "sn":
        pipe.sigmas = jax.tree.map(
            lambda a: jax.numpy.broadcast_to(a, (N_INST,) + a.shape),
            fast_init(op.resolved()))
    gen = datagen.tweets(rng, n_ticks=n_ticks, tick=TICK, words_per_tweet=6,
                         vocab=5000, k_virt=K_VIRT, mode=wc_mode,
                         pair_dist=pair_dist, rate_per_tick=50)
    batches = list(gen)
    pipe.step(batches[0])          # compile
    t0 = time.perf_counter()
    for b in batches[1:]:
        pipe.step(b)
    dt = time.perf_counter() - t0
    tput = TICK * (n_ticks - 1) / dt
    lat_us = dt / (n_ticks - 1) * 1e6
    dup = (np.mean([d for d in pipe.duplication if d > 0])
           if mode == "sn" else 1.0)
    return tput, lat_us, dup


def run_mesh(n_shards: int, wc_mode: str, pair_dist: int, n_ticks: int = 12):
    """VSN on an n-device mesh: batched multi-tick ingest, one compiled
    shard_map step for the whole stream after warmup."""
    from repro.launch.mesh import make_stream_mesh

    rng = np.random.default_rng(7)
    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)
    mesh = make_stream_mesh(n_shards)
    pipe = MeshPipeline(op, mesh, stash_cap=TICK, mode="fast-agg",
                        agg_kind="count")
    batches = list(datagen.tweets(
        rng, n_ticks=n_ticks, tick=TICK, words_per_tweet=6, vocab=5000,
        k_virt=K_VIRT, mode=wc_mode, pair_dist=pair_dist, rate_per_tick=50))
    o = pipe.run(batches[:1])          # compile the T=1 step
    o = pipe.run(batches[1:])          # compile + run the batched step
    jax.block_until_ready(o[0].tau)
    t0 = time.perf_counter()
    o = pipe.run(batches[1:])
    jax.block_until_ready(o[0].tau)
    dt = time.perf_counter() - t0
    tput = TICK * (n_ticks - 1) / dt
    coll = pipe.collective_bytes()
    return tput, sum(coll.values())


def run_ingest(n_leaves: int, mesh: int = 0, n_ticks: int = 12):
    """Multihost ingest: root-merge throughput vs leaf count + parity.

    Returns (tput_by_leaves, tier_parity_ok, pipe_parity_ok_or_None)."""
    from benchmarks.common import run_ingest_bench
    from repro.ingest import single_gate_stream
    from repro.io.sinks import flatten_outputs

    n_sources = 2 * n_leaves
    batches = list(datagen.tweets(
        np.random.default_rng(7), n_ticks=n_ticks, tick=TICK,
        words_per_tweet=6, vocab=5000, k_virt=K_VIRT, rate_per_tick=50,
        n_sources=n_sources))
    tput, tier_ticks, tier_ok = run_ingest_bench(batches, n_sources,
                                                 n_leaves, tick=TICK)

    pipe_ok = None
    if mesh:
        oracle_ticks = single_gate_stream(batches, n_sources, cap=3 * TICK)
        from repro.launch.mesh import make_stream_mesh
        op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024,
                             extra_slots=2, n_inputs=n_sources)

        def drive(ticks):
            pipe = MeshPipeline(op, make_stream_mesh(mesh),
                                stash_cap=4 * TICK, mode="fast-agg",
                                agg_kind="count")
            res = []
            for b in ticks:
                o1, o2, _ = pipe.step(b)
                res += flatten_outputs(o1) + flatten_outputs(o2)
            return sorted(res)

        pipe_ok = drive(tier_ticks) == drive(oracle_ticks)
    return tput, tier_ok, pipe_ok


def make_fast_pipe(op):
    return VSNPipeline(op, n_max=N_INST, n_active=N_INST, stash_cap=TICK,
                       tick_fn=fast_tick, merge_fn=merge_fast_state,
                       init_sigma=lambda: fast_init(op.resolved()))


def run_async(wc_mode: str, pair_dist: int, n_ticks: int = 32):
    """Async (overlapped-ingest) vs synchronous host loop on the same
    stream: same pipeline, same tuples, exact output-set parity required."""
    from repro.core.async_runtime import AsyncStreamRuntime, run_sync
    from repro.io import SyntheticSource

    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)

    def gen():
        rng = np.random.default_rng(7)
        return datagen.tweets(rng, n_ticks=n_ticks, tick=TICK,
                              words_per_tweet=6, vocab=5000, k_virt=K_VIRT,
                              mode=wc_mode, pair_dist=pair_dist,
                              rate_per_tick=50)

    warm = next(iter(gen()))

    async_pipe = make_fast_pipe(op)
    async_pipe.step(warm)                    # compile outside the window
    rt = AsyncStreamRuntime(async_pipe, SyntheticSource(gen()), queue_cap=4)
    rep_a = rt.run()

    sync_pipe = make_fast_pipe(op)
    sync_pipe.step(warm)
    rep_s, sink_s = run_sync(sync_pipe, SyntheticSource(gen()))

    ok = rt.sink.results() == sink_s.results()
    return rep_a, rep_s, ok


def run_obs_overhead(wc_mode: str, pair_dist: int, n_ticks: int = 32):
    """Observability overhead on the q1 async run: obs off vs metrics-only
    (tracing disabled) vs full tracing, best-of-reps, output parity
    required.  The gates — disabled <2%, enabled <10% — are the PR's
    'near-free when off' contract."""
    from benchmarks.common import run_obs_overhead_bench
    from repro.io import SyntheticSource

    op = count_aggregate(WS, k_virt=K_VIRT, out_cap=1024, extra_slots=2)

    def gen():
        rng = np.random.default_rng(7)
        return datagen.tweets(rng, n_ticks=n_ticks, tick=TICK,
                              words_per_tweet=6, vocab=5000, k_virt=K_VIRT,
                              mode=wc_mode, pair_dist=pair_dist,
                              rate_per_tick=50)

    warm = next(iter(gen()))
    return run_obs_overhead_bench(lambda: make_fast_pipe(op),
                                  lambda: SyntheticSource(gen()), warm)


def emit_obs_overhead(qname: str, ob):
    """The gated obs-overhead rows: FAIL when the tracing-disabled tier
    costs >=2%, full tracing costs >=10%, sampled tracing under the 10x
    event storm costs >=2%, sampling perturbs the exact counters, or any
    variant's outputs diverge (parity=False trips ``failed_rows`` by
    itself)."""
    fail = ""
    if ob["metrics_overhead"] >= 0.02:
        fail += " FAIL(disabled_overhead>=2%)"
    if ob["trace_overhead"] >= 0.10:
        fail += " FAIL(trace_overhead>=10%)"
    emit(f"{qname}_obs_overhead",
         1e6 / max(ob["trace_tps"], 1e-9),
         f"obs off {ob['base_tps']:.0f} t/s; tracing-disabled "
         f"{ob['metrics_overhead'] * 100:+.1f}% (gate <2%), full trace "
         f"{ob['trace_overhead'] * 100:+.1f}% (gate <10%), "
         f"parity={ob['parity']}{fail}")
    sfail = ""
    if ob["sampled_overhead"] >= 0.02:
        sfail += " FAIL(sampled_overhead>=2%)"
    if not ob["counters_exact"]:
        sfail += " FAIL(counters_diverged)"
    ev = (ob.get("sampler") or {}).get("events", {}).get(
        "synthetic_load", {})
    emit(f"{qname}_obs_sampled",
         1e6 / max(ob["sampled_tps"], 1e-9),
         f"sampled trace under 10x event storm "
         f"{ob['sampled_overhead'] * 100:+.1f}% vs off (gate <2%), "
         f"kept {ev.get('kept', 0)}/{ev.get('attempts', 0)} synthetic "
         f"events, exact counters bit-identical={ob['counters_exact']}"
         f"{sfail}")


def run_device_resident(n_hosts: int, n_ticks: int = 96, tick: int = 16,
                        super_batch: int = 8):
    """Device-resident hot path (fused device root merge + persistent
    K-tick compiled scan) vs the per-tick host-merge baseline over the
    N-host ingest rounds on the identical stream, parity-gated (tier vs
    single-gate oracle, host vs device output multisets, device vs
    synchronous replay).  Reduced shape (k_virt/out_cap/n_inst), same
    convention as q3's async variant: small ticks keep the per-tick
    dispatch+sync overhead the PR removes visible against the tick math,
    which on this CPU-only host runs on the same single core."""
    from benchmarks.common import run_device_resident_bench

    kv, n_inst, out_cap = 64, 4, 64
    n_sources = 2 * n_hosts
    op = count_aggregate(WS, k_virt=kv, out_cap=out_cap, extra_slots=2,
                         n_inputs=n_sources)

    def make_stream():
        rng = np.random.default_rng(7)
        return datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                              words_per_tweet=6, vocab=5000, k_virt=kv,
                              rate_per_tick=50, n_sources=n_sources)

    def make_pipe():
        return VSNPipeline(op, n_max=n_inst, n_active=n_inst,
                           stash_cap=4 * tick, tick_fn=fast_tick,
                           merge_fn=merge_fast_state,
                           init_sigma=lambda: fast_init(op.resolved()))

    return run_device_resident_bench(make_stream, n_sources, n_hosts,
                                     make_pipe, tick=tick,
                                     super_batch=super_batch)


def emit_device_resident(qname: str, res, parity):
    """Shared q1/q3 rows for the device-resident-vs-host-merge comparison:
    hot-path baseline + device rows, the parity+speedup gate row (any
    parity False, or a hot-path speedup below the 0.8 noise floor, is a
    FAIL row), and an informational end-to-end async row.  The >=1.5x
    target assumes an accelerator device; on a single-core CPU host the
    tick math shares the core with ingest, so the hot-path row measures
    the removed dispatch/sync/staging overhead only."""
    hot = res["hot"]
    speed = hot["speedup"]
    emit(f"{qname}_hotpath_hostmerge_tput_tps",
         1e6 / max(hot["host_tps"], 1e-9),
         f"{hot['host_tps']:.0f} t/s per-tick host-merge hot path "
         f"(best of {hot['reps']})")
    emit(f"{qname}_hotpath_device_resident_tput_tps",
         1e6 / max(hot["dev_tps"], 1e-9),
         f"{hot['dev_tps']:.0f} t/s fused root + persistent scan "
         f"(K fill {hot['fill']:.1f})")
    emit(f"{qname}_device_resident_speedup",
         1e6 / max(hot["dev_tps"], 1e-9),
         f"device/host {speed:.2f}x hot path "
         "(target >=1.5x on accelerator; single-core CPU host)"
         + ("" if speed >= 0.8 else " FAIL(speedup<0.8)")
         + f", parity tier={parity['tier']}"
           f" pipeline={parity['pipeline']} sync={parity['sync']}")
    rep_h, rep_d = res["host"]["report"], res["device"]["report"]
    e2e = rep_d.throughput_tps / max(rep_h.throughput_tps, 1e-9)
    emit(f"{qname}_device_resident_e2e_tput_tps",
         1e6 / max(rep_d.throughput_tps, 1e-9),
         f"{rep_d.throughput_tps:.0f} t/s end-to-end async vs "
         f"{rep_h.throughput_tps:.0f} t/s host-merge ({e2e:.2f}x; "
         "leaf ingest shares the core, informational)",
         p50_ms=rep_d.p50_ms, p99_ms=rep_d.p99_ms)


def main(mesh: int = 0, async_: bool = False, ingest_hosts: int = 0):
    for wc_mode, dist, label in [("wordcount", 0, "wordcount"),
                                 ("paircount", 3, "pair_L"),
                                 ("paircount", 10, "pair_M")]:
        t_v, l_v, _ = run_case("vsn", wc_mode, dist)
        t_s, l_s, dup = run_case("sn", wc_mode, dist)
        emit(f"q1_{label}_vsn_tput_tps", 1e6 / t_v, f"{t_v:.0f} t/s")
        emit(f"q1_{label}_sn_tput_tps", 1e6 / t_s, f"{t_s:.0f} t/s")
        emit(f"q1_{label}_speedup", l_v,
             f"vsn/sn={t_v / t_s:.2f}x dup={dup:.2f}")
    if async_:
        rep_a, rep_s, ok = run_async("wordcount", 0)
        gain = rep_a.throughput_tps / max(rep_s.throughput_tps, 1e-9)
        emit("q1_wordcount_async", 1e6 / max(rep_a.throughput_tps, 1e-9),
             f"{rep_a.throughput_tps:.0f} t/s async vs "
             f"{rep_s.throughput_tps:.0f} t/s sync host loop "
             f"(overlap {gain:.2f}x), outputs_match_sync={ok}",
             p50_ms=rep_a.p50_ms, p99_ms=rep_a.p99_ms)
        emit_obs_overhead("q1_wordcount", run_obs_overhead("wordcount", 0))
    if mesh:
        if len(jax.devices()) < mesh:
            emit("q1_mesh_SKIP", 0.0,
                 f"needs {mesh} devices, have {len(jax.devices())}")
            return
        t_m, coll = run_mesh(mesh, "wordcount", 0)
        emit(f"q1_wordcount_mesh{mesh}_tput_tps", 1e6 / t_m,
             f"{t_m:.0f} t/s batched ingest, collective_bytes={coll}")
    if ingest_hosts:
        use_mesh = mesh if (mesh and len(jax.devices()) >= mesh) else 0
        tput, tier_ok, pipe_ok = run_ingest(ingest_hosts, mesh=use_mesh)
        for leaves, tps in sorted(tput.items()):
            emit(f"q1_ingest_root_tput_leaves{leaves}",
                 1e6 / max(tps, 1e-9),
                 f"{tps:.0f} t/s root merge, {leaves} leaf workers")
        scale = tput[ingest_hosts] / max(tput[1], 1e-9)
        label = (f"q1_wordcount_ingest{ingest_hosts}"
                 + (f"_mesh{use_mesh}" if use_mesh else "_vsn"))
        derived = (f"{ingest_hosts}-leaf/1-leaf root tput {scale:.2f}x, "
                   f"outputs_match_oracle={tier_ok}")
        if pipe_ok is not None:
            derived += f", pipeline_outputs_match={pipe_ok}"
        emit(label, 1e6 / max(tput[ingest_hosts], 1e-9), derived)
    if async_ and ingest_hosts:
        res, parity = run_device_resident(ingest_hosts)
        emit_device_resident("q1_wordcount", res, parity)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0)
    ap.add_argument("--async", dest="async_", action="store_true")
    ap.add_argument("--ingest-hosts", type=int, default=0)
    a = ap.parse_args()
    main(mesh=a.mesh, async_=a.async_, ingest_hosts=a.ingest_hosts)
