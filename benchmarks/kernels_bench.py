"""Structural micro-bench of the Pallas kernels (interpret mode on CPU —
not TPU timings; recorded so the perf-iteration log has a fixed harness)
plus their jnp refs (which XLA compiles natively on CPU)."""

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.window_join.ops import window_join_ref_op
from repro.kernels.flash_attention.ops import attention_ref_op
from repro.kernels.linear_scan.ops import linear_scan_ref_op


def main():
    rng = np.random.default_rng(0)
    B, K, R, P = 128, 512, 16, 4
    nt = np.sort(rng.integers(0, 1000, B)).astype(np.int32)
    ns = rng.integers(0, 2, B).astype(np.int32)
    npay = rng.uniform(0, 100, (B, P)).astype(np.float32)
    stt = rng.integers(0, 900, (K, R)).astype(np.int32)
    ss = rng.integers(0, 2, (K, R)).astype(np.int32)
    sp = rng.uniform(0, 100, (K, R, P)).astype(np.float32)
    us, _ = time_fn(lambda: window_join_ref_op(nt, ns, npay, stt, ss, sp,
                                               ws=500))
    comps = B * K * R
    emit("kern_window_join_ref", us, f"{comps / us:.1f} comps/us")

    q = rng.normal(0, 1, (8, 256, 64)).astype(np.float32)
    k = rng.normal(0, 1, (8, 256, 64)).astype(np.float32)
    us, _ = time_fn(lambda: attention_ref_op(q, k, k, causal=True))
    emit("kern_attention_ref", us, "8x256x64")

    r = rng.normal(0, 1, (4, 512, 32)).astype(np.float32)
    w = rng.uniform(0.9, 0.99, (4, 512, 32)).astype(np.float32)
    us, _ = time_fn(lambda: linear_scan_ref_op(r, r, r, w))
    emit("kern_linear_scan_ref", us, "4x512x32")


if __name__ == "__main__":
    main()
