"""Micro-bench of all five kernels through the backend dispatcher.

Each kernel is timed at the session backend (``benchmarks.run --backend``,
``REPRO_KERNEL_BACKEND``, or the hardware default — ``xla`` on CPU, where
the jnp ref oracles compile natively; ``pallas`` on TPU) alongside the ref
oracle, so one harness produces comparable rows on any host."""

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import dispatch, lowering
from repro.kernels.window_join.ops import window_join_op, window_join_ref_op
from repro.kernels.segment_aggregate.ops import segment_aggregate_op
from repro.kernels.scalegate_merge.ops import scalegate_merge_op
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.linear_scan.ops import linear_scan_op


def lint_row():
    """Mosaic-lowering lint as a gating bench row: any kernel regressing to
    a rank-1 BlockSpec / 1-D iota flips the row to FAIL and run.py exits
    nonzero (same contract as the parity rows)."""
    t0 = time.perf_counter()
    reports = lowering.lint_registered()
    us = (time.perf_counter() - t0) * 1e6
    bad = sorted(n for n, r in reports.items() if not r.ok)
    # a kernel registered for dispatch but missing a lint case must FAIL
    # too — otherwise the gate silently narrows when an ops import moves
    bad += sorted(set(dispatch.registered()) - set(reports))
    status = "FAIL:" + ";".join(bad) if bad else \
        f"mosaic_lint_ok={len(reports)}/{len(dispatch.registered())}"
    emit("kern_lowering_lint", us, status)


def main():
    backend = dispatch.default_backend()
    rng = np.random.default_rng(0)
    lint_row()

    B, K, R, P = 128, 512, 16, 4
    nt = np.sort(rng.integers(0, 1000, B)).astype(np.int32)
    ns = rng.integers(0, 2, B).astype(np.int32)
    npay = rng.uniform(0, 100, (B, P)).astype(np.float32)
    stt = rng.integers(0, 900, (K, R)).astype(np.int32)
    ss = rng.integers(0, 2, (K, R)).astype(np.int32)
    sp = rng.uniform(0, 100, (K, R, P)).astype(np.float32)
    us, _ = time_fn(lambda: window_join_op(nt, ns, npay, stt, ss, sp,
                                           ws=500, backend=backend))
    comps = B * K * R
    emit(f"kern_window_join[{backend}]", us, f"{comps / us:.1f} comps/us")
    us, _ = time_fn(lambda: window_join_ref_op(nt, ns, npay, stt, ss, sp,
                                               ws=500))
    emit("kern_window_join_ref", us, f"{comps / us:.1f} comps/us")

    N, KS, S, W = 512, 256, 4, 2
    keys = rng.integers(-1, KS, N).astype(np.int32)
    slots = rng.integers(0, S, N).astype(np.int32)
    vals = rng.uniform(0, 1, (N, W)).astype(np.float32)
    acc = np.zeros((KS, S, W), np.float32)
    us, _ = time_fn(lambda: segment_aggregate_op(keys, slots, vals, acc,
                                                 backend=backend))
    emit(f"kern_segment_aggregate[{backend}]", us, f"{N} hits -> {KS}x{S}")

    n, srcs = 256, 4
    tau = rng.integers(0, 5000, n).astype(np.int32)
    src = rng.integers(0, srcs, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    us, _ = time_fn(lambda: scalegate_merge_op(tau, src, valid,
                                               n_sources=srcs,
                                               backend=backend))
    emit(f"kern_scalegate_merge[{backend}]", us, f"{n} lanes")

    q = rng.normal(0, 1, (8, 256, 64)).astype(np.float32)
    k = rng.normal(0, 1, (8, 256, 64)).astype(np.float32)
    us, _ = time_fn(lambda: flash_attention_op(q, k, k, causal=True,
                                               backend=backend))
    emit(f"kern_attention[{backend}]", us, "8x256x64")

    r = rng.normal(0, 1, (4, 512, 32)).astype(np.float32)
    w = rng.uniform(0.9, 0.99, (4, 512, 32)).astype(np.float32)
    us, _ = time_fn(lambda: linear_scan_op(r, r, r, w, backend=backend))
    emit(f"kern_linear_scan[{backend}]", us, "4x512x32")


if __name__ == "__main__":
    main()
