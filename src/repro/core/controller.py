"""Elasticity controllers (paper §8.4-§8.5).

STRETCH "does not embed a specific policy ... but defines a generic API for
external modules" (§3) — controllers are host-side Python that observe tick
metrics and emit ``Reconfiguration`` requests (new Pi, f_mu, active set).

* ``ThresholdController`` — §8.4: upper/target/lower CPU(load) thresholds
  (0.90 / 0.70 / 0.45).  Provision the smallest number of new instances
  bringing average load below target; decommission the largest number that
  keeps it below target.
* ``PredictiveController`` — §8.5 tightens the band to [0.70, 0.80] and
  sizes against *pending + predicted* work using the stream-join cost model
  of [22]: per-tuple cost grows linearly with the window population
  (rate x WS), so required capacity ~ rate^2 * WS / throughput_per_instance.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Reconfiguration:
    epoch: int
    n_active: int
    fmu: np.ndarray       # i32[K]
    active: np.ndarray    # bool[n_max]


@dataclasses.dataclass(frozen=True)
class LiveMetrics:
    """One tick's worth of runtime signals, as sampled by the live loop
    (io.metrics.MetricsBus builds these).  This is the §3 'generic API for
    external modules' made concrete: controllers never see the stream, only
    this snapshot."""
    rate_tps: float                          # offered/measured ingest rate
    inst_load: Optional[np.ndarray] = None   # per-instance work last tick
    n_active_observed: int = 0               # active count inst_load was
    #                                          measured under (the COMMITTED
    #                                          epoch, not a pending decision)
    queue_depth: int = 0                     # staged ticks waiting (backlog)
    queue_cap: int = 0
    backlog_tuples: float = 0.0              # tuples sitting in the queue
    tick_latency_s: float = 0.0
    slo_breaches: tuple = ()                 # new SLO breaches since the
    #                                          last decision (obs.slo
    #                                          SloBreach instances)

    def load_skew(self, n_active: int = None) -> float:
        """max/mean per-instance load (>= 1): a skewed f_mu saturates its
        hottest instance before the average rate says so.  The mean is over
        ``n_active`` instances when given (``inst_load`` spans all n_max
        slots, inactive ones zero); else over the loaded ones."""
        if self.inst_load is None:
            return 1.0
        load = np.asarray(self.inst_load, float)
        total = load.sum()
        if total <= 0:
            return 1.0
        n = n_active if n_active else int((load > 0).sum())
        return float(load.max() * max(n, 1) / total)


def balanced_fmu(k_virt: int, n_active: int, n_max: int) -> np.ndarray:
    """Round-robin key -> instance map over the active prefix (hash(k) % Pi,
    Operator 3 L4)."""
    return (np.arange(k_virt) % max(n_active, 1)).astype(np.int32)


def active_mask(n_active: int, n_max: int) -> np.ndarray:
    m = np.zeros((n_max,), bool)
    m[:n_active] = True
    return m


@dataclasses.dataclass
class ThresholdController:
    n_max: int
    k_virt: int
    capacity_per_instance: float          # tuples/s one instance sustains
    upper: float = 0.90
    target: float = 0.70
    lower: float = 0.45
    n_active: int = 1
    epoch: int = 0
    slo_breaches_seen: int = 0

    def observe(self, rate: float) -> Optional[Reconfiguration]:
        load = rate / (self.n_active * self.capacity_per_instance)
        desired = self.n_active
        if load > self.upper:
            # smallest provision bringing load below target (§8.4)
            desired = int(np.ceil(rate / (self.target * self.capacity_per_instance)))
        elif load < self.lower:
            # largest decommission staying below target (§8.4)
            desired = max(1, int(np.ceil(
                rate / (self.target * self.capacity_per_instance))))
        desired = min(self.n_max, max(1, desired))
        if desired == self.n_active:
            return None
        self.n_active = desired
        self.epoch += 1
        return Reconfiguration(
            epoch=self.epoch, n_active=desired,
            fmu=balanced_fmu(self.k_virt, desired, self.n_max),
            active=active_mask(desired, self.n_max))

    def observe_live(self, m: LiveMetrics) -> Optional[Reconfiguration]:
        """Closed-loop entry point: fold the live signals into an effective
        rate, then apply the §8.4 thresholds.  Load skew inflates the rate
        (the hottest instance saturates first) and a filling in-flight
        queue signals the pipeline is already behind the offered rate."""
        pressure = 1.0
        if m.queue_cap > 0:
            pressure += m.queue_depth / m.queue_cap
        # an SLO breach is direct evidence the objective is missed at the
        # current capacity, whatever the raw load says: each fresh breach
        # adds scale-up pressure (bounded — breaches are cooldown-gated)
        if m.slo_breaches:
            self.slo_breaches_seen += len(m.slo_breaches)
            pressure += 0.5 * len(m.slo_breaches)
        # skew must be judged against the active set the load was MEASURED
        # under; self.n_active may already hold a not-yet-committed decision
        # (a pending switch), and mixing the two inflates skew and cascades
        # spurious scale-ups under a steady rate.
        skew = m.load_skew(m.n_active_observed or None)
        rc = self.observe(m.rate_tps * skew * pressure)
        if rc is not None:
            from repro import obs as _obs
            _obs.event("controller_decide", policy="threshold",
                       rate_tps=m.rate_tps, skew=skew, pressure=pressure,
                       queue_depth=m.queue_depth, epoch=int(rc.epoch),
                       n_active=int(rc.n_active))
        return rc


@dataclasses.dataclass
class PredictiveController:
    """§8.5: narrower [lower, upper] band + the [22] join cost model.

    Join work per second ~ rate * (window population) = rate^2 * WS (+ the
    pending backlog), so capacity planning uses the *predicted* comparisons
    rather than the instantaneous CPU load.
    """
    n_max: int
    k_virt: int
    comparisons_per_s_per_instance: float
    ws_seconds: float
    lower: float = 0.70
    upper: float = 0.80
    n_active: int = 1
    epoch: int = 0
    backlog: float = 0.0
    slo_breaches_seen: int = 0

    def observe(self, rate: float) -> Optional[Reconfiguration]:
        work = rate * rate * self.ws_seconds + self.backlog   # comparisons/s
        cap = self.n_active * self.comparisons_per_s_per_instance
        load = work / max(cap, 1e-9)
        desired = self.n_active
        if load > self.upper or load < self.lower:
            mid = 0.5 * (self.lower + self.upper)
            desired = int(np.ceil(
                work / (mid * self.comparisons_per_s_per_instance)))
        desired = min(self.n_max, max(1, desired))
        if desired == self.n_active:
            return None
        self.n_active = desired
        self.epoch += 1
        return Reconfiguration(
            epoch=self.epoch, n_active=desired,
            fmu=balanced_fmu(self.k_virt, desired, self.n_max),
            active=active_mask(desired, self.n_max))

    def observe_live(self, m: LiveMetrics) -> Optional[Reconfiguration]:
        """Closed-loop entry point: queued tuples become pending work in
        the [22] cost model (each backlogged tuple will be compared against
        the window population ~ rate * WS), then the §8.5 band applies."""
        self.backlog = m.backlog_tuples * m.rate_tps * self.ws_seconds
        if m.slo_breaches:
            # breaches mean the cost model under-predicted: inflate the
            # pending-work term so the band recomputes capacity upward
            self.slo_breaches_seen += len(m.slo_breaches)
            self.backlog *= 1.0 + 0.5 * len(m.slo_breaches)
        rc = self.observe(m.rate_tps)
        if rc is not None:
            from repro import obs as _obs
            _obs.event("controller_decide", policy="predictive",
                       rate_tps=m.rate_tps, backlog=self.backlog,
                       queue_depth=m.queue_depth, epoch=int(rc.epoch),
                       n_active=int(rc.n_active))
        return rc
