"""Time-based sliding-window arithmetic (paper §2.1).

Windows cover ``[l*WA, l*WA + WS)`` for ``l`` in Z.  A tuple with event time
``tau`` falls into window indices ``l`` with

    l_max = floor(tau / WA)                 (``latestWinL``  / Alg. 2 L10)
    l_min = floor((tau - WS) / WA) + 1      (``earliestWinL`` / Alg. 2 L9)

so each tuple touches at most ``n_slots = ceil(WS / WA)`` window instances.
``WT = multi`` keeps all ``n_slots`` live instances per key in a ring buffer
(slot of window ``l`` is ``l % n_slots``); ``WT = single`` keeps one instance
per key that *slides* via ``f_S`` (§2.1, Fig. 1).

A window instance ``w = <zeta, l, k>`` is *expired* when its right boundary
``l*WA + WS <= W`` (Definition 2 discussion) — at that point ``f_O`` may fire
and the slot may be shifted/recycled, and output tuples take ``tau = right
boundary`` (Observation 1).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

SINGLE = "single"
MULTI = "multi"


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    wa: int          # window advance (delta ticks)
    ws: int          # window size    (delta ticks)
    wt: str = MULTI  # window type: "single" | "multi"

    def __post_init__(self):
        if self.wa <= 0 or self.ws <= 0:
            raise ValueError("WA and WS must be positive")
        if self.wt not in (SINGLE, MULTI):
            raise ValueError(f"bad window type {self.wt!r}")

    @property
    def n_slots(self) -> int:
        """Number of concurrently-live window instances per key."""
        if self.wt == SINGLE:
            return 1
        return -(-self.ws // self.wa)  # ceil

    def latest_win_l(self, tau):
        """Left boundary index of the latest window containing ``tau``."""
        return jnp.floor_divide(tau, self.wa)

    def earliest_win_l(self, tau):
        """Left boundary index of the earliest window containing ``tau``."""
        return jnp.floor_divide(tau - self.ws, self.wa) + 1

    def window_indices(self, tau):
        """(l_min, l_max) inclusive window-index range for event time tau."""
        return self.earliest_win_l(tau), self.latest_win_l(tau)

    def slot_of(self, l):
        """Ring-buffer slot of window index ``l``."""
        return jnp.mod(l, self.n_slots)

    def left_of(self, l):
        return l * self.wa

    def right_of(self, l):
        return l * self.wa + self.ws

    def expired(self, l, watermark):
        """Window ``l`` is expired once no future tuple can fall in it."""
        return self.right_of(l) <= watermark
