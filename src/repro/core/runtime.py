"""Pipeline driver: ScaleGate -> epoch handling -> executor tick (§7, Fig. 5).

``setup(O+, m, n)``: a pipeline is created with ``n_max`` instances of which
``n_active`` are connected (the rest are the paper's pool: active=False,
zero responsible keys, negligible work).  Each ``step``:

  1. (optional) a ``Reconfiguration`` from a controller is encapsulated in
     per-source control tuples stamped with the last forwarded tau
     (addSTRETCH, Alg. 5) and pushed with the data;
  2. ScaleGate merges and gates ready tuples (shared TB);
  3. prepareReconfig adopts pending tables (Alg. 6);
  4. the tick is processed in two epoch phases split at gamma (Alg. 4 L17):
     the tau-sorted prefix <= gamma under f_mu, the rest under f_mu*;
  5. outputs from all instances feed the downstream TB (Lemma 2/3 make the
     concatenation a valid sorted source set).

``VSNPipeline`` shares sigma (the paper); ``SNPipeline`` keeps dedicated
sigma_j and pays duplication + state transfer — the measured baseline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic, scalegate, sn, tuples as T, vsn
from repro.core import watermark as wm
from repro.core.controller import Reconfiguration
from repro.core.operator import OperatorDef, tick as general_tick


@dataclasses.dataclass
class VSNPipeline:
    op: OperatorDef
    n_max: int
    n_active: int
    stash_cap: int = 256
    tick_fn: Callable = None
    merge_fn: Callable = None
    init_sigma: Callable = None

    def __post_init__(self):
        self.op = self.op.resolved()
        k = self.op.k_virt
        fmu = jnp.asarray(np.arange(k) % self.n_active, jnp.int32)
        active = jnp.asarray(
            np.arange(self.n_max) < self.n_active, bool)
        self.epoch = elastic.init_epoch(fmu, active)
        self.sigma = (self.init_sigma or self.op.init_state)()
        self.sg = scalegate.init_scalegate(
            self.op.n_inputs, self.stash_cap, 1,
            self.op.payload_out if False else 1)  # placeholder, reset below
        self._tick = self.tick_fn or general_tick
        self._merge = self.merge_fn or vsn.merge_states
        self._sg_ready = False
        self._step = jax.jit(self._step_impl)

    def _ensure_gate(self, incoming: T.TupleBatch):
        if not self._sg_ready:
            self.sg = scalegate.init_scalegate(
                self.op.n_inputs, self.stash_cap, incoming.kmax,
                incoming.payload_width)
            self._sg_ready = True

    def _step_impl(self, sg, epoch, sigma, incoming, fmu_new, active_new):
        sg, ready = scalegate.push(sg, incoming)
        epoch = elastic.prepare_reconfig(epoch, ready, fmu_new, active_new)
        pre, post = elastic.split_epoch_masks(epoch, ready)

        ready_pre = dataclasses.replace(ready, valid=pre | (ready.is_control & ready.valid))
        sigma, outs1 = vsn.run_tick(self.op, sigma, ready_pre, epoch.fmu,
                                    epoch.active, self._tick, self._merge)

        live = ready.valid & ~ready.is_control
        w_end = jnp.max(jnp.where(live, ready.tau, 0))
        epoch, switched = elastic.advance_epoch(epoch, w_end)

        ready_post = dataclasses.replace(ready, valid=post)
        sigma, outs2 = vsn.run_tick(self.op, sigma, ready_post, epoch.fmu,
                                    epoch.active, self._tick, self._merge)
        return sg, epoch, sigma, outs1, outs2, switched

    def step(self, incoming: T.TupleBatch,
             reconfig: Optional[Reconfiguration] = None):
        """Push one tick; returns (outputs_pre, outputs_post, switched)."""
        self._ensure_gate(incoming)
        if reconfig is not None:
            ctrl = elastic.make_control_tuple(
                int(np.asarray(self.sg.wmark.frontier).max()),
                reconfig.epoch, incoming.kmax, incoming.payload_width)
            # one control tuple per source so every per-source stream stays
            # sorted (Alg. 5); stamped with that source's last tau.
            ctrls = []
            for i in range(self.op.n_inputs):
                tau_i = int(np.asarray(self.sg.wmark.frontier)[i])
                c = dataclasses.replace(
                    ctrl, tau=jnp.asarray([tau_i], jnp.int32),
                    source=jnp.asarray([i], jnp.int32))
                ctrls.append(c)
            incoming = functools.reduce(T.concat, ctrls, incoming)
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            pad = T.empty_batch(self.op.n_inputs, incoming.kmax,
                                incoming.payload_width)
            incoming = T.concat(incoming, pad)
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        (self.sg, self.epoch, self.sigma, outs1, outs2,
         switched) = self._step(self.sg, self.epoch, self.sigma, incoming,
                                fmu_new, active_new)
        return outs1, outs2, switched


@dataclasses.dataclass
class SNPipeline:
    """The shared-nothing baseline: dedicated sigma_j, duplication at
    forward, state transfer at reconfiguration."""
    op: OperatorDef
    n_max: int
    n_active: int
    stash_cap: int = 256
    tick_fn: Callable = None

    def __post_init__(self):
        self.op = self.op.resolved()
        k = self.op.k_virt
        fmu = jnp.asarray(np.arange(k) % self.n_active, jnp.int32)
        active = jnp.asarray(np.arange(self.n_max) < self.n_active, bool)
        self.epoch = elastic.init_epoch(fmu, active)
        self.sigmas = sn.init_states(self.op, self.n_max)
        self._tick = self.tick_fn or general_tick
        self._sg_ready = False
        self.bytes_transferred = 0
        self.duplication = []
        self._step = jax.jit(self._step_impl)

    def _ensure_gate(self, incoming: T.TupleBatch):
        if not self._sg_ready:
            self.sg = scalegate.init_scalegate(
                self.op.n_inputs, self.stash_cap, incoming.kmax,
                incoming.payload_width)
            self._sg_ready = True

    def _step_impl(self, sg, epoch, sigmas, incoming, fmu_new, active_new):
        sg, ready = scalegate.push(sg, incoming)
        epoch = elastic.prepare_reconfig(epoch, ready, fmu_new, active_new)
        pre, post = elastic.split_epoch_masks(epoch, ready)

        dup = sn.duplication_factor(
            dataclasses.replace(ready, valid=pre), epoch.fmu, epoch.active)
        ready_pre = dataclasses.replace(
            ready, valid=pre | (ready.is_control & ready.valid))
        sigmas, outs1 = sn.run_tick(self.op, sigmas, ready_pre, epoch.fmu,
                                    epoch.active, self._tick)

        live = ready.valid & ~ready.is_control
        w_end = jnp.max(jnp.where(live, ready.tau, 0))
        fmu_old = epoch.fmu
        epoch, switched = elastic.advance_epoch(epoch, w_end)
        # SN pays the state transfer when ownership changes (§2.5):
        sigmas, moved_bytes = jax.lax.cond(
            switched,
            lambda s: elastic.sn_transfer(s, fmu_old, epoch.fmu),
            lambda s: (s, jnp.zeros((), jnp.int32)),
            sigmas)

        ready_post = dataclasses.replace(ready, valid=post)
        sigmas, outs2 = sn.run_tick(self.op, sigmas, ready_post, epoch.fmu,
                                    epoch.active, self._tick)
        return sg, epoch, sigmas, outs1, outs2, switched, dup, moved_bytes

    def step(self, incoming: T.TupleBatch,
             reconfig: Optional[Reconfiguration] = None):
        self._ensure_gate(incoming)
        if reconfig is not None:
            ctrls = []
            for i in range(self.op.n_inputs):
                tau_i = int(np.asarray(self.sg.wmark.frontier)[i])
                c = elastic.make_control_tuple(
                    tau_i, reconfig.epoch, incoming.kmax,
                    incoming.payload_width)
                c = dataclasses.replace(c, source=jnp.asarray([i], jnp.int32))
                ctrls.append(c)
            incoming = functools.reduce(T.concat, ctrls, incoming)
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            pad = T.empty_batch(self.op.n_inputs, incoming.kmax,
                                incoming.payload_width)
            incoming = T.concat(incoming, pad)
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        (self.sg, self.epoch, self.sigmas, outs1, outs2, switched, dup,
         moved) = self._step(self.sg, self.epoch, self.sigmas, incoming,
                             fmu_new, active_new)
        self.duplication.append(float(dup))
        self.bytes_transferred += int(moved)
        return outs1, outs2, switched
