"""Pipeline driver: ScaleGate -> epoch handling -> executor tick (§7, Fig. 5).

``setup(O+, m, n)``: a pipeline is created with ``n_max`` instances of which
``n_active`` are connected (the rest are the paper's pool: active=False,
zero responsible keys, negligible work).  Each ``step``:

  1. (optional) a ``Reconfiguration`` from a controller is encapsulated in
     per-source control tuples stamped with the last forwarded tau
     (addSTRETCH, Alg. 5) and pushed with the data;
  2. ScaleGate merges and gates ready tuples (shared TB);
  3. prepareReconfig adopts pending tables (Alg. 6);
  4. the tick is processed in two epoch phases split at gamma (Alg. 4 L17):
     the tau-sorted prefix <= gamma under f_mu, the rest under f_mu*;
  5. outputs from all instances feed the downstream TB (Lemma 2/3 make the
     concatenation a valid sorted source set).

``VSNPipeline`` shares sigma (the paper); ``SNPipeline`` keeps dedicated
sigma_j and pays duplication + state transfer — the measured baseline.
``MeshPipeline`` is the VSN pipeline on a real device mesh: sigma sharded
over the instance axis in fixed key blocks (owner-computes), ScaleGate +
EpochState replicated, the whole step — including batched multi-tick
ingest (``lax.scan`` over T stacked ticks) — compiled into one
``shard_map`` call.  Output-set parity with ``VSNPipeline`` is exact,
including across a reconfiguration, and the compiled step moves zero
bytes of state between devices (Theorem 3 made physical).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic, scalegate, sn, tuples as T, vsn
from repro.core import watermark as wm
from repro.core.controller import Reconfiguration
from repro.core.operator import OperatorDef, tick as general_tick


def fold_frontier(frontier: np.ndarray, b: T.TupleBatch,
                  n_inputs: int) -> None:
    """Fold one batch's per-source max data tau into a host-side frontier
    (mutated in place): the Alg. 5 bookkeeping behind control-tuple stamps,
    shared by the async runtime's tick metadata and the mesh driver."""
    tau = np.asarray(b.tau)
    src = np.asarray(b.source)
    ok = np.asarray(b.valid) & ~np.asarray(b.is_control)
    for i in range(n_inputs):
        sel = ok & (src == i)
        if sel.any():
            frontier[i] = max(frontier[i], int(tau[sel].max()))


def ctrl_lanes(n_inputs: int, frontier, epoch_id: int, kmax: int,
               p: int) -> T.TupleBatch:
    """One control tuple per source so every per-source stream stays
    sorted (Alg. 5); each stamped with that source's last forwarded tau."""
    lanes = []
    for i in range(n_inputs):
        c = elastic.make_control_tuple(int(frontier[i]), epoch_id, kmax, p)
        c = dataclasses.replace(c, source=jnp.asarray([i], jnp.int32))
        lanes.append(c)
    return functools.reduce(T.concat, lanes)


def inject_ctrl(inc_stack: T.TupleBatch, ctrl: T.TupleBatch, rc_tick,
                n_inputs: int) -> T.TupleBatch:
    """Overwrite the ctrl pad region (the last ``n_inputs`` lanes) of tick
    ``rc_tick`` in a staged [K, B] super-batch with ``ctrl``'s lanes.

    ``rc_tick`` may be a traced scalar, so ONE compiled persistent
    executable covers both the reconfig and the steady-state call: with no
    reconfiguration the caller passes an all-invalid ``ctrl`` (and any
    tick), making the update a proven no-op — the pad region is already
    all-invalid by construction (``stage_super``)."""
    def upd(stack_leaf, ctrl_leaf):
        start = ((rc_tick, stack_leaf.shape[1] - n_inputs)
                 + (0,) * (stack_leaf.ndim - 2))
        return jax.lax.dynamic_update_slice(
            stack_leaf, ctrl_leaf[None].astype(stack_leaf.dtype), start)
    return jax.tree.map(upd, inc_stack, ctrl)


@jax.jit
def _pad_stack(pad: T.TupleBatch, *batches: T.TupleBatch) -> T.TupleBatch:
    """Append the all-invalid ctrl pad to each of K same-shape ticks and
    stack them into one [K, B] super-batch in ONE compiled call — staging
    must stay far cheaper than a tick, and the host-side alternative
    (K x n_fields separate concat/stack dispatches) is not."""
    padded = [T.concat(b, pad) for b in batches]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *padded)


@dataclasses.dataclass
class PersistentOut:
    """Host-visible result of one persistent K-tick run.  The data lane
    (``outs_pre``/``outs_post``) stays a device array stack of leading dim
    K until the sink materializes it; the rest is the control lane:
    per-tick switch flags, watermark reports and (VSN only) per-instance
    loads."""
    outs_pre: Any                  # [K, ...] per-tick pre-phase outputs
    outs_post: Any                 # [K, ...] per-tick post-phase outputs
    switched: jax.Array            # bool[K]  epoch switch per tick
    wmark: jax.Array               # i32[K]   watermark report per tick
    inst_load: Any = None          # i32[K, n_max] or None (mesh)


@dataclasses.dataclass
class VSNPipeline:
    op: OperatorDef
    n_max: int
    n_active: int
    stash_cap: int = 256
    tick_fn: Callable = None
    merge_fn: Callable = None
    init_sigma: Callable = None
    # step_staged returns a device-computed per-instance load vector (the
    # async runtime then skips its host-side key-histogram fallback)
    device_inst_load = True

    def __post_init__(self):
        self.op = self.op.resolved()
        k = self.op.k_virt
        fmu = jnp.asarray(np.arange(k) % self.n_active, jnp.int32)
        active = jnp.asarray(
            np.arange(self.n_max) < self.n_active, bool)
        self.epoch = elastic.init_epoch(fmu, active)
        self.sigma = (self.init_sigma or self.op.init_state)()
        self.sg = scalegate.init_scalegate(
            self.op.n_inputs, self.stash_cap, 1,
            self.op.payload_out if False else 1)  # placeholder, reset below
        self._tick = self.tick_fn or general_tick
        self._merge = self.merge_fn or vsn.merge_states
        self._sg_ready = False
        self._step = jax.jit(self._step_impl)
        # persistent K-tick driver: donate the ScaleGate and sigma buffers
        # (args 0 and 2) so the scan updates them in place; epoch is NEVER
        # donated — with no reconfiguration ``fmu_new`` aliases its tables.
        self._persistent = jax.jit(self._persistent_impl,
                                   donate_argnums=(0, 2))
        self._persistent_structs = {}
        self._empty_ctrl = {}          # (kmax, p) -> steady-state (ctrl, rc)

    def _ensure_gate(self, incoming: T.TupleBatch):
        if not self._sg_ready:
            self.sg = scalegate.init_scalegate(
                self.op.n_inputs, self.stash_cap, incoming.kmax,
                incoming.payload_width)
            self._sg_ready = True

    def ensure_gate_for(self, kmax: int, payload_width: int):
        """Initialize the gate from dimensions alone (no data tick yet) —
        the restore path needs a fully-shaped state template before any
        tuple has been staged."""
        self._ensure_gate(T.empty_batch(1, kmax, payload_width))

    # -- checkpoint/restore ------------------------------------------------
    def export_state(self) -> dict:
        """The pipeline's epoch-consistent mutable state at a tick boundary
        (ScaleGate stash + watermark, EpochState incl. any pending
        ``e_next``/``fmu_next`` switch, sigma) as one checkpointable pytree.
        The caller must materialize it to host (``np.asarray``) before the
        next dispatch — ``run_persistent_staged`` donates sg and sigma."""
        assert self._sg_ready, "export_state() before the first staged tick"
        return {"sg": self.sg, "epoch": self.epoch, "sigma": self.sigma}

    def import_state(self, state: dict):
        """Install a snapshot produced by ``export_state`` (possibly via a
        checkpoint roundtrip).  Counterpart of ``export_state``; the epoch
        shadow state readers (async runtime) re-derive from ``self.epoch``."""
        self.sg = jax.tree.map(jnp.asarray, state["sg"])
        self.epoch = jax.tree.map(jnp.asarray, state["epoch"])
        self.sigma = jax.tree.map(jnp.asarray, state["sigma"])
        self._sg_ready = True

    def _inst_load(self, ready: T.TupleBatch, epoch) -> jax.Array:
        """Per-instance load of one tick under the in-effect f_mu: one unit
        per (valid data lane, key-set entry) routed to its owner — the
        live signal the elasticity controllers consume (§8.4)."""
        data = ready.valid & ~ready.is_control
        kmask = data[:, None] & (ready.keys != T.NO_KEY)
        owners = epoch.fmu[jnp.clip(ready.keys, 0, None)]
        return jnp.zeros((self.n_max,), jnp.int32
                         ).at[owners].add(kmask.astype(jnp.int32))

    def _tick_with_epoch(self, sigma, ready, epoch):
        return vsn.run_tick(self.op, sigma, ready, epoch.fmu, epoch.active,
                            self._tick, self._merge)

    def _step_impl(self, sg, epoch, sigma, incoming, fmu_new, active_new):
        (sg, epoch, sigma, outs1, outs2, switched, _wmk,
         inst_load) = vsn.pipeline_tick(sg, epoch, sigma, incoming, fmu_new,
                                        active_new, self._tick_with_epoch,
                                        self._inst_load)
        return sg, epoch, sigma, outs1, outs2, switched, inst_load

    def _persistent_impl(self, sg, epoch, sigma, inc_stack, ctrl, rc_tick,
                         fmu_new, active_new):
        """K ticks inside one ``lax.scan``: only the control lane (switch
        flags, watermark reports, instance loads) and the stacked output
        buffers leave the compiled program — no per-tick host round-trip,
        no per-tick dispatch."""
        inc_stack = inject_ctrl(inc_stack, ctrl, rc_tick, self.op.n_inputs)

        def body(carry, incoming):
            sg, epoch, sigma = carry
            sg, epoch, sigma, o1, o2, sw, wmk, il = vsn.pipeline_tick(
                sg, epoch, sigma, incoming, fmu_new, active_new,
                self._tick_with_epoch, self._inst_load)
            return (sg, epoch, sigma), (o1, o2, sw, wmk, il)

        (sg, epoch, sigma), (o1, o2, sw, wmk, il) = jax.lax.scan(
            body, (sg, epoch, sigma), inc_stack)
        return sg, epoch, sigma, o1, o2, sw, wmk, il

    def stage(self, incoming: T.TupleBatch) -> T.TupleBatch:
        """Asynchronously place a tick on the device (async ingest: the
        ``device_put`` of tick T+1 overlaps device compute of tick T)."""
        self._ensure_gate(incoming)
        return jax.device_put(incoming)

    def step_staged(self, staged: T.TupleBatch,
                    reconfig: Optional[Reconfiguration] = None,
                    frontier=None):
        """``step`` on a pre-staged device batch; returns the extended
        ``(outs_pre, outs_post, switched, inst_load)``.

        ``frontier`` (host i32[n_inputs]: last forwarded tau per source) lets
        a control tuple be stamped without reading ``sg.wmark`` back from
        the device — a read that would block on the still-in-flight previous
        step and serialize the async loop.  When None, the device state is
        consulted (the synchronous path's behavior).
        """
        self._ensure_gate(staged)
        if reconfig is not None:
            if frontier is None:
                frontier = np.asarray(self.sg.wmark.frontier)
            from repro import obs as _obs
            _obs.counter_inc("pipeline.ctrl_injections")
            incoming = T.concat(staged, ctrl_lanes(
                self.op.n_inputs, frontier, reconfig.epoch, staged.kmax,
                staged.payload_width))
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            pad = T.empty_batch(self.op.n_inputs, staged.kmax,
                                staged.payload_width)
            incoming = T.concat(staged, pad)
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        (self.sg, self.epoch, self.sigma, outs1, outs2, switched,
         inst_load) = self._step(self.sg, self.epoch, self.sigma, incoming,
                                 fmu_new, active_new)
        return outs1, outs2, switched, inst_load

    def step(self, incoming: T.TupleBatch,
             reconfig: Optional[Reconfiguration] = None):
        """Push one tick; returns (outputs_pre, outputs_post, switched)."""
        outs1, outs2, switched, _ = self.step_staged(incoming, reconfig)
        return outs1, outs2, switched

    # -- persistent K-tick driver ------------------------------------------
    def _frontier_after(self, batches, frontier0=None):
        """Per-source last forwarded tau once ``batches`` have been pushed
        (the Alg. 5 stamp for a control tuple injected after them);
        ``frontier0`` avoids the blocking ``sg.wmark`` readback."""
        frontier = (np.asarray(frontier0).copy() if frontier0 is not None
                    else np.asarray(self.sg.wmark.frontier).copy())
        for b in batches:
            fold_frontier(frontier, b, self.op.n_inputs)
        return frontier

    def stage_super(self, batches) -> T.TupleBatch:
        """Stack K same-shape ticks — each with its all-invalid ctrl pad
        region appended — into one [K, B] device-resident super-batch (one
        transfer for the whole scan; ``inject_ctrl`` later rewrites the pad
        of at most one tick)."""
        batches = list(batches)
        assert batches, "empty super-batch"
        self._ensure_gate(batches[0])
        kmax, p = batches[0].kmax, batches[0].payload_width
        pad = T.empty_batch(self.op.n_inputs, kmax, p)
        return _pad_stack(pad, *batches)

    def run_persistent_staged(self, stack: T.TupleBatch,
                              reconfig: Optional[Reconfiguration] = None,
                              reconfig_at: int = 0,
                              frontier=None) -> PersistentOut:
        """The persistent scan over a pre-staged super-batch.  A reconfig's
        control tuples are injected into the ctrl pad lanes of tick
        ``reconfig_at`` *inside* the compiled program, so the mid-scan
        f_mu switch happens with zero state transfer and zero restaging;
        ``frontier`` must then be the per-source last-forwarded-tau AFTER
        the ticks preceding ``reconfig_at`` (see ``run_persistent``)."""
        kmax = stack.keys.shape[-1]
        p = stack.payload.shape[-1]
        if reconfig is not None:
            if frontier is None:
                frontier = np.asarray(self.sg.wmark.frontier)
            ctrl = ctrl_lanes(self.op.n_inputs, frontier, reconfig.epoch,
                              kmax, p)
            rc = jnp.asarray(max(reconfig_at, 0), jnp.int32)
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            # the steady-state (no-reconfig) operands are call-invariant;
            # rebuilding them per dispatch would tax every super-batch
            if (kmax, p) not in self._empty_ctrl:
                self._empty_ctrl[(kmax, p)] = (
                    T.empty_batch(self.op.n_inputs, kmax, p),
                    jnp.zeros((), jnp.int32))
            ctrl, rc = self._empty_ctrl[(kmax, p)]
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        args = (self.sg, self.epoch, self.sigma, stack, ctrl, rc, fmu_new,
                active_new)
        key = (stack.tau.shape[0], stack.tau.shape[1], kmax, p)
        if key not in self._persistent_structs:
            self._persistent_structs[key] = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), args)
        (self.sg, self.epoch, self.sigma, o1, o2, sw, wmk,
         il) = self._persistent(*args)
        return PersistentOut(outs_pre=o1, outs_post=o2, switched=sw,
                             wmark=wmk, inst_load=il)

    def run_persistent(self, batches,
                       reconfig: Optional[Reconfiguration] = None,
                       reconfig_at: int = 0,
                       frontier0=None) -> PersistentOut:
        """Run K ticks inside ONE compiled ``lax.scan`` with donated
        ScaleGate and sigma buffers: steady-state data never crosses the
        host boundary between ticks (``persistent_hlo`` + ``launch.mesh.
        host_transfer_ops`` is the witness).  Tick-for-tick identical to K
        sequential ``step`` calls, including a mid-scan reconfiguration."""
        batches = list(batches)
        assert batches, "empty super-batch"
        self._ensure_gate(batches[0])
        frontier = None
        if reconfig is not None:
            frontier = self._frontier_after(batches[:max(reconfig_at, 0)],
                                            frontier0)
        stack = self.stage_super(batches)
        return self.run_persistent_staged(stack, reconfig=reconfig,
                                          reconfig_at=reconfig_at,
                                          frontier=frontier)

    def persistent_hlo(self) -> str:
        """Compiled HLO of every persistent executable built so far — feed
        to ``launch.mesh.host_transfer_ops`` to prove the data lane stays
        on device for the whole scan."""
        texts = []
        for structs in self._persistent_structs.values():
            texts.append(self._persistent.lower(
                *structs).compile().as_text())
        return "\n".join(texts)


@dataclasses.dataclass
class SNPipeline:
    """The shared-nothing baseline: dedicated sigma_j, duplication at
    forward, state transfer at reconfiguration."""
    op: OperatorDef
    n_max: int
    n_active: int
    stash_cap: int = 256
    tick_fn: Callable = None

    def __post_init__(self):
        self.op = self.op.resolved()
        k = self.op.k_virt
        fmu = jnp.asarray(np.arange(k) % self.n_active, jnp.int32)
        active = jnp.asarray(np.arange(self.n_max) < self.n_active, bool)
        self.epoch = elastic.init_epoch(fmu, active)
        self.sigmas = sn.init_states(self.op, self.n_max)
        self._tick = self.tick_fn or general_tick
        self._sg_ready = False
        self.bytes_transferred = 0
        self.duplication = []
        self._step = jax.jit(self._step_impl)

    def _ensure_gate(self, incoming: T.TupleBatch):
        if not self._sg_ready:
            self.sg = scalegate.init_scalegate(
                self.op.n_inputs, self.stash_cap, incoming.kmax,
                incoming.payload_width)
            self._sg_ready = True

    def _step_impl(self, sg, epoch, sigmas, incoming, fmu_new, active_new):
        sg, ready = scalegate.push(sg, incoming)
        epoch = elastic.prepare_reconfig(epoch, ready, fmu_new, active_new)
        pre, post = elastic.split_epoch_masks(epoch, ready)

        dup = sn.duplication_factor(
            dataclasses.replace(ready, valid=pre), epoch.fmu, epoch.active)
        ready_pre = dataclasses.replace(
            ready, valid=pre | (ready.is_control & ready.valid))
        sigmas, outs1 = sn.run_tick(self.op, sigmas, ready_pre, epoch.fmu,
                                    epoch.active, self._tick)

        live = ready.valid & ~ready.is_control
        w_end = jnp.max(jnp.where(live, ready.tau, 0))
        fmu_old = epoch.fmu
        epoch, switched = elastic.advance_epoch(epoch, w_end)
        # SN pays the state transfer when ownership changes (§2.5):
        sigmas, moved_bytes = jax.lax.cond(
            switched,
            lambda s: elastic.sn_transfer(s, fmu_old, epoch.fmu),
            lambda s: (s, jnp.zeros((), jnp.int32)),
            sigmas)

        ready_post = dataclasses.replace(ready, valid=post)
        sigmas, outs2 = sn.run_tick(self.op, sigmas, ready_post, epoch.fmu,
                                    epoch.active, self._tick)
        return sg, epoch, sigmas, outs1, outs2, switched, dup, moved_bytes

    def step(self, incoming: T.TupleBatch,
             reconfig: Optional[Reconfiguration] = None):
        self._ensure_gate(incoming)
        if reconfig is not None:
            ctrls = []
            for i in range(self.op.n_inputs):
                tau_i = int(np.asarray(self.sg.wmark.frontier)[i])
                c = elastic.make_control_tuple(
                    tau_i, reconfig.epoch, incoming.kmax,
                    incoming.payload_width)
                c = dataclasses.replace(c, source=jnp.asarray([i], jnp.int32))
                ctrls.append(c)
            incoming = functools.reduce(T.concat, ctrls, incoming)
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            pad = T.empty_batch(self.op.n_inputs, incoming.kmax,
                                incoming.payload_width)
            incoming = T.concat(incoming, pad)
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        (self.sg, self.epoch, self.sigmas, outs1, outs2, switched, dup,
         moved) = self._step(self.sg, self.epoch, self.sigmas, incoming,
                             fmu_new, active_new)
        self.duplication.append(float(dup))
        self.bytes_transferred += int(moved)
        return outs1, outs2, switched


@dataclasses.dataclass
class MeshPipeline:
    """The VSN pipeline executed on a device mesh (paper §5 at scale-up).

    sigma is sharded over ``mesh``'s ``axis`` in fixed contiguous key
    blocks; every other piece of state (ScaleGate stash + watermark
    frontiers, EpochState tables) is replicated — each device runs the
    identical merge over the identical incoming tuples, so the shared-TB
    contract holds with zero communication.  An ``f_mu`` reconfiguration
    swaps replicated tables only: no sigma row ever crosses a device
    (``collective_bytes()`` proves it from the compiled HLO).

    ``mode``:
      * ``"general"``  — the O+ oracle tick (operator.tick) per key block;
      * ``"fast-agg"`` — the vectorized commutative-reducer fast path
                         (aggregate.tick_fast, ``agg_kind`` in count|sum|max).

    ``run([b0, b1, ...])`` is the batched ingest: the T ticks are stacked
    and scanned inside one compiled shard_map call, so the hot loop does
    not round-trip to Python per tick.  ``step(b)`` is the T=1 view with
    the VSNPipeline return convention.
    """
    op: OperatorDef
    mesh: Any
    axis: str = "i"
    stash_cap: int = 256
    mode: str = "general"
    agg_kind: str = "count"
    backend: str = None          # kernel backend for the fast-agg scatter
    n_max: int = None            # logical instance count (tables); defaults
    n_active: int = None         # to the shard count
    # the mesh step keeps zero extra replicated outputs: per-instance load
    # comes from the async runtime's host-side key histogram instead
    device_inst_load = False

    def __post_init__(self):
        self.op = self.op.resolved()
        self.n_shards = self.mesh.shape[self.axis]
        if self.op.k_virt % self.n_shards:
            raise ValueError(f"k_virt={self.op.k_virt} must divide over "
                             f"{self.n_shards} shards")
        self.n_max = self.n_max or self.n_shards
        self.n_active = self.n_active or self.n_max
        k = self.op.k_virt
        fmu = jnp.asarray(np.arange(k) % self.n_active, jnp.int32)
        active = jnp.asarray(np.arange(self.n_max) < self.n_active, bool)
        self.epoch = elastic.init_epoch(fmu, active)
        if self.mode == "general":
            if self.op.lazy_expiry:
                # lazy-expiry operators (ScaleJoin) purge/store inside f_U
                # with global-key semantics that localize_op cannot slice;
                # the mesh route for them is vsn.join_local_tick.
                raise ValueError(
                    "MeshPipeline mode='general' does not support "
                    "lazy-expiry operators (ScaleJoin): use "
                    "vsn.shard_tick with vsn.join_local_tick")
            sigma = self.op.init_state()
            make_local = vsn.general_local_tick(self.op)
        elif self.mode == "fast-agg":
            from repro.core.aggregate import fast_init
            sigma = fast_init(self.op)
            make_local = vsn.fast_agg_local_tick(self.op, self.agg_kind,
                                                 self.backend)
        else:
            raise ValueError(f"unknown mode {self.mode!r}")
        self.sigma = vsn.mesh_device_put(sigma, self.mesh, self.axis, k)
        self._step_fn = vsn.shard_pipeline_step(self.op, self.mesh, self.axis,
                                                make_local, sigma)
        self._jit = jax.jit(self._step_fn)   # one jit; it caches per shape
        # persistent variant: ctrl injection fused into the compiled call,
        # sigma (the only big buffer; arg 2) donated.  sg/epoch are small
        # replicated tables and stay undonated (fmu_new may alias epoch).
        self._persistent = jax.jit(self._persistent_fn, donate_argnums=(2,))
        self._persistent_structs = {}
        self.last_wmarks = None              # i32[T] of the latest run
        self._sg_ready = False
        # abstract (shape+sharding) args per step variant, for the lazy
        # collective_bytes lowering — never pins device buffers
        self._arg_structs = {}

    def _persistent_fn(self, sg, epoch, sigma, inc_stack, ctrl, rc_tick,
                       fmu_new, active_new):
        inc_stack = inject_ctrl(inc_stack, ctrl, rc_tick, self.op.n_inputs)
        return self._step_fn(sg, epoch, sigma, inc_stack, fmu_new,
                             active_new)

    # -- plumbing ----------------------------------------------------------
    def _ensure_gate(self, incoming: T.TupleBatch):
        if not self._sg_ready:
            self.sg = scalegate.init_scalegate(
                self.op.n_inputs, self.stash_cap, incoming.kmax,
                incoming.payload_width)
            self._sg_ready = True

    def ensure_gate_for(self, kmax: int, payload_width: int):
        """Initialize the gate from dimensions alone (restore templates)."""
        self._ensure_gate(T.empty_batch(1, kmax, payload_width))

    # -- checkpoint/restore ------------------------------------------------
    def export_state(self) -> dict:
        """Same contract as ``VSNPipeline.export_state``.  ``np.asarray``
        on the key-block-sharded sigma gathers the shards, so the snapshot
        the checkpoint layer materializes is the full logical array."""
        assert self._sg_ready, "export_state() before the first staged tick"
        return {"sg": self.sg, "epoch": self.epoch, "sigma": self.sigma}

    def import_state(self, state: dict):
        """Install a snapshot: sg/epoch re-replicated across the mesh,
        sigma re-sharded into fixed key blocks (``vsn.mesh_device_put``) —
        a snapshot taken on N devices restores onto any divisor mesh."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        rep = NamedSharding(self.mesh, P())
        self.sg = jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a), rep), state["sg"])
        self.epoch = jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a), rep), state["epoch"])
        host_sigma = jax.tree.map(np.asarray, state["sigma"])
        self.sigma = vsn.mesh_device_put(host_sigma, self.mesh, self.axis,
                                         self.op.k_virt)
        self._sg_ready = True

    def _frontier_after(self, batches, frontier0=None):
        """Per-source last forwarded tau once ``batches`` have been pushed:
        the Alg. 5 stamp for a control tuple injected after them.
        ``frontier0`` (host-tracked) avoids the device readback of
        ``sg.wmark`` that would block on the in-flight step."""
        frontier = (np.asarray(frontier0).copy() if frontier0 is not None
                    else np.asarray(self.sg.wmark.frontier).copy())
        for b in batches:
            fold_frontier(frontier, b, self.op.n_inputs)
        return frontier

    # -- the driver --------------------------------------------------------
    def stage(self, incoming: T.TupleBatch) -> T.TupleBatch:
        """Asynchronously replicate a tick across the mesh (async ingest:
        the transfer of tick T+1 overlaps device compute of tick T)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        self._ensure_gate(incoming)
        rep = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda a: jax.device_put(a, rep), incoming)

    def step_staged(self, staged: T.TupleBatch,
                    reconfig: Optional[Reconfiguration] = None,
                    frontier=None):
        """One pre-staged tick with the extended return convention
        ``(outs_pre, outs_post, switched, inst_load)``; ``inst_load`` is
        None here (the async runtime derives it host-side from the tick's
        key histogram — the mesh step keeps zero extra replicated outputs).
        ``frontier`` as in ``VSNPipeline.step_staged``."""
        o1, o2, sw = self.run([staged], reconfig=reconfig,
                              frontier0=frontier)
        return o1, o2, sw[0], None

    def run(self, batches, reconfig: Optional[Reconfiguration] = None,
            reconfig_at: int = 0, frontier0=None):
        """Push T ticks in one compiled call; an optional reconfiguration is
        injected as control tuples riding with tick ``reconfig_at`` (Alg. 5:
        stamped with each source's last forwarded tau at that point).

        Returns ``(outs_pre, outs_post, switched)`` with leading tick axis T
        and the per-shard output lanes concatenated on axis 1.
        """
        batches = list(batches)
        assert batches, "empty tick stack"
        self._ensure_gate(batches[0])
        b0 = batches[0]
        kmax, p = b0.kmax, b0.payload_width

        padded = []
        for t, b in enumerate(batches):
            if reconfig is not None and t == reconfig_at:
                frontier = self._frontier_after(batches[:t], frontier0)
                pad = ctrl_lanes(self.op.n_inputs, frontier, reconfig.epoch,
                                 kmax, p)
            else:
                pad = T.empty_batch(self.op.n_inputs, kmax, p)
            padded.append(T.concat(b, pad))
        inc_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)

        if reconfig is not None:
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active

        key = (len(padded), padded[0].batch, kmax, p)
        args = (self.sg, self.epoch, self.sigma, inc_stack, fmu_new,
                active_new)
        # re-captured every call so collective_bytes lowers the steady-state
        # variant (first-call inputs arrive host-placed, later ones carry
        # the replicated shardings of the previous step's outputs).  Only
        # mesh shardings are kept: a host-placed (single-device) input is
        # uncommitted in the real call, but abstract lowering would treat
        # it as pinned and reject the device mix.
        from jax.sharding import NamedSharding

        def struct(a):
            sh = getattr(a, "sharding", None)
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype,
                sharding=sh if isinstance(sh, NamedSharding) else None)

        self._arg_structs[key] = jax.tree.map(struct, args)
        (self.sg, self.epoch, self.sigma, outs1, outs2, switched,
         wmk) = self._jit(*args)
        self.last_wmarks = wmk
        return outs1, outs2, switched

    def step(self, incoming: T.TupleBatch,
             reconfig: Optional[Reconfiguration] = None):
        """One tick, VSNPipeline-style: returns (outs_pre, outs_post,
        switched) with the T=1 axis kept on the outputs."""
        outs1, outs2, switched = self.run([incoming], reconfig=reconfig)
        return outs1, outs2, switched[0]

    # -- persistent K-tick driver ------------------------------------------
    def stage_super(self, batches) -> T.TupleBatch:
        """Stack K ticks (each with its all-invalid ctrl pad region) and
        replicate the [K, B] super-batch across the mesh in one transfer."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        batches = list(batches)
        assert batches, "empty super-batch"
        self._ensure_gate(batches[0])
        kmax, p = batches[0].kmax, batches[0].payload_width
        pad = T.empty_batch(self.op.n_inputs, kmax, p)
        stack = _pad_stack(pad, *batches)
        rep = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda a: jax.device_put(a, rep), stack)

    def run_persistent_staged(self, stack: T.TupleBatch,
                              reconfig: Optional[Reconfiguration] = None,
                              reconfig_at: int = 0,
                              frontier=None) -> PersistentOut:
        """As ``VSNPipeline.run_persistent_staged``, on the mesh: the ctrl
        injection, the K-tick scan and the sharded two-phase ticks are one
        compiled call with donated sigma.  ``inst_load`` is None (the mesh
        step keeps zero extra replicated outputs)."""
        from jax.sharding import NamedSharding

        kmax = stack.keys.shape[-1]
        p = stack.payload.shape[-1]
        if reconfig is not None:
            if frontier is None:
                frontier = np.asarray(self.sg.wmark.frontier)
            ctrl = ctrl_lanes(self.op.n_inputs, frontier, reconfig.epoch,
                              kmax, p)
            rc = jnp.asarray(max(reconfig_at, 0), jnp.int32)
            fmu_new = jnp.asarray(reconfig.fmu)
            active_new = jnp.asarray(reconfig.active)
        else:
            ctrl = T.empty_batch(self.op.n_inputs, kmax, p)
            rc = jnp.zeros((), jnp.int32)
            fmu_new = self.epoch.fmu
            active_new = self.epoch.active
        args = (self.sg, self.epoch, self.sigma, stack, ctrl, rc, fmu_new,
                active_new)

        def struct(a):
            sh = getattr(a, "sharding", None)
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype,
                sharding=sh if isinstance(sh, NamedSharding) else None)

        key = (stack.tau.shape[0], stack.tau.shape[1], kmax, p)
        self._persistent_structs[key] = jax.tree.map(struct, args)
        (self.sg, self.epoch, self.sigma, o1, o2, sw,
         wmk) = self._persistent(*args)
        self.last_wmarks = wmk
        return PersistentOut(outs_pre=o1, outs_post=o2, switched=sw,
                             wmark=wmk, inst_load=None)

    def run_persistent(self, batches,
                       reconfig: Optional[Reconfiguration] = None,
                       reconfig_at: int = 0,
                       frontier0=None) -> PersistentOut:
        """K ticks in one compiled, donated call on the mesh; tick-for-tick
        identical to ``run`` (they share the scan body) but with the ctrl
        injection on device and sigma updated in place."""
        batches = list(batches)
        assert batches, "empty super-batch"
        self._ensure_gate(batches[0])
        frontier = None
        if reconfig is not None:
            frontier = self._frontier_after(batches[:max(reconfig_at, 0)],
                                            frontier0)
        stack = self.stage_super(batches)
        return self.run_persistent_staged(stack, reconfig=reconfig,
                                          reconfig_at=reconfig_at,
                                          frontier=frontier)

    def persistent_hlo(self) -> str:
        """Compiled HLO of every persistent executable built so far (for
        ``launch.mesh.host_transfer_ops`` — the data lane must show zero
        host transfers)."""
        texts = []
        for structs in self._persistent_structs.values():
            texts.append(self._persistent.lower(
                *structs).compile().as_text())
        return "\n".join(texts)

    # -- accounting --------------------------------------------------------
    def collective_bytes(self):
        """Cross-device traffic of the compiled step(s), from the HLO: the
        zero-state-transfer witness (Theorem 3).  Returns {collective-kind:
        bytes} summed over every step variant compiled so far."""
        from repro.launch.mesh import collective_bytes as _cb

        total = {}
        for structs in self._arg_structs.values():
            hlo = self._jit.lower(*structs).compile().as_text()
            for kind, b in _cb(hlo).items():
                total[kind] = total.get(kind, 0) + b
        return total

    def switch_bytes(self) -> int:
        """Bytes a reconfiguration actually moves: the replicated tables."""
        return elastic.vsn_switch_bytes(self.epoch)
