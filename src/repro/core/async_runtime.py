"""AsyncStreamRuntime: live double-buffered ingest + closed-loop elasticity.

The batch drivers (benchmarks, tests) pre-stage whole streams and pay a
host round-trip per tick.  This runtime makes the stream *live*:

* an **ingest thread** pulls ticks from an ``io`` source, computes the tiny
  host-side tick metadata (per-source frontier, tuple count, key
  histogram), and ``stage``s the batch onto the device — so the
  ``device_put`` of tick T+1 runs concurrently with device compute of
  tick T.  A ``BoundedQueue`` between the threads applies backpressure:
  the producer blocks, memory never grows past ``queue_cap`` ticks;
* the **step loop** dispatches the compiled ``VSNPipeline`` /
  ``MeshPipeline`` step on the staged batch and *never* blocks on the
  outputs (sinks keep device handles).  The only host syncs are the
  sampled metrics of the *previous* tick — the ``switched`` flag and the
  per-instance load vector — fetched while the current tick computes
  (double buffering);
* the **control loop** closes §8.4-§8.5: each tick, a ``MetricsBus``
  snapshot (offered/measured rate, per-instance load, queue depth) is fed
  to the controller, and an emitted ``Reconfiguration`` is injected
  mid-stream through the existing control-tuple path (Alg. 5), stamped
  from the *host-tracked* per-source frontier so no device readback stalls
  the loop.  Detection→switch latency (decision wall-clock to the first
  observed epoch switch) is measured per reconfiguration.

``run_sync`` is the measured baseline: the same semantics as a plain
host loop (generate, step, block on outputs), so async-vs-sync throughput
isolates the overlap gain and async-vs-sync output sets pin correctness.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Tuple

import numpy as np
import jax

from repro import obs as _obs
from repro.core import tuples as T
from repro.core.controller import Reconfiguration
from repro.core.runtime import fold_frontier
from repro.io.metrics import MetricsBus
from repro.io.queues import BoundedQueue, QueueClosed
from repro.io.sinks import CollectSink


@dataclasses.dataclass
class TickMeta:
    """Host-side facts about one tick, computed in the ingest thread."""
    tick_id: int
    n_tuples: int                  # valid data lanes
    frontier_before: np.ndarray    # i64[n_inputs] last tau per source BEFORE
    key_hist: Optional[np.ndarray]  # i64[k_virt] (lane, key) routing counts


@dataclasses.dataclass
class StagedTick:
    meta: TickMeta
    staged: T.TupleBatch           # device-resident


@dataclasses.dataclass
class StagedSuper:
    """K consecutive same-shape ticks staged as one [K, B] super-batch for
    the pipeline's persistent compiled driver (``run_persistent_staged``).
    ``n_pad`` trailing ticks are all-invalid no-op fillers (a partial tail
    or an early flush on a shape change keeps one compiled K shape)."""
    metas: List[TickMeta]          # one per REAL tick, in order
    stack: T.TupleBatch            # device-resident [K, B] stack
    n_pad: int


@dataclasses.dataclass
class RunReport:
    ticks: int
    tuples: int
    wall_s: float
    throughput_tps: float
    p50_ms: float
    p99_ms: float
    queue_high_water: int
    blocked_puts: int
    reconfig_trace: List[Tuple[int, Reconfiguration]]
    switches: int
    detect_to_switch_ms: List[float]
    detect_to_switch_ticks: List[int]
    # detections whose switch never committed (flushed at stop())
    unresolved_detections: int = 0
    # per-stage latency breakdown {stage: {p50,p90,p99,mean,count}} in ms,
    # from span tracing when enabled (empty otherwise)
    stage_latency_ms: dict = dataclasses.field(default_factory=dict)
    # sampled per-tuple end-to-end timelines (admission -> ... -> emit),
    # when ObsConfig.exemplar_rate > 0 (empty otherwise)
    exemplar_timelines: list = dataclasses.field(default_factory=list)
    # SLO breaches observed during the run (SloBreach.to_dict() dicts),
    # when ObsConfig.slo_rules is set (empty otherwise)
    slo_breaches: list = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        d2s = (f"{np.mean(self.detect_to_switch_ms):.1f}ms"
               f"/{np.mean(self.detect_to_switch_ticks):.1f}t"
               if self.detect_to_switch_ms else "n/a")
        return (f"{self.ticks} ticks, {self.tuples} tuples in "
                f"{self.wall_s:.2f}s = {self.throughput_tps:.0f} t/s; "
                f"tick latency p50={self.p50_ms:.2f}ms p99={self.p99_ms:.2f}ms; "
                f"{len(self.reconfig_trace)} reconfigs ({self.switches} "
                f"switched, detection->switch {d2s}); queue high-water "
                f"{self.queue_high_water}")


def _initial_frontier(pipeline, n_inputs: int) -> np.ndarray:
    """Seed the host-tracked frontier from the pipeline's ScaleGate state:
    a pre-warmed pipeline (e.g. a compile tick stepped before run()) has
    already forwarded taus, and control tuples stamped below them would
    violate the per-source sorted-stream invariant (Alg. 5).  Runs before
    the stream starts, so the device read cannot stall an in-flight step."""
    if getattr(pipeline, "_sg_ready", False):
        return np.asarray(pipeline.sg.wmark.frontier).astype(np.int64).copy()
    return np.zeros((n_inputs,), np.int64)


def make_report(metrics: MetricsBus, reconfig_trace, switches: int,
                queue=None, slo_breaches=None) -> RunReport:
    """Assemble the RunReport from a finished run's metrics (shared by the
    async loop and the run_sync baseline)."""
    p50, p99 = metrics.latency_quantiles_ms()
    o = _obs.get()
    return RunReport(
        ticks=metrics.n_ticks,
        tuples=metrics.total_tuples,
        wall_s=(metrics.t_end or 0.0) - (metrics.t_start or 0.0),
        throughput_tps=metrics.throughput_tps(),
        p50_ms=p50, p99_ms=p99,
        queue_high_water=0 if queue is None else queue.high_water,
        blocked_puts=0 if queue is None else queue.blocked_puts,
        reconfig_trace=list(reconfig_trace),
        switches=switches,
        detect_to_switch_ms=list(metrics.detect_to_switch_ms),
        detect_to_switch_ticks=list(metrics.detect_to_switch_ticks),
        unresolved_detections=len(metrics.unresolved_detections),
        stage_latency_ms=({} if o is None or not o.tracer.enabled
                          else o.tracer.stage_latency_ms()),
        exemplar_timelines=([] if o is None or o.timeline is None
                            else o.timeline.completed()),
        slo_breaches=[b.to_dict() for b in (slo_breaches or [])])


def tick_meta(b: T.TupleBatch, tick_id: int, n_inputs: int, k_virt: int,
              frontier: np.ndarray, with_hist: bool = True) -> TickMeta:
    """Compute a tick's metadata and fold its taus into the running
    ``frontier`` (mutated) — numpy views only, no device work.

    ``with_hist=False`` skips the O(B*KMAX) key histogram: it is only
    consumed by the host-side load fallback for pipelines whose step does
    not return a device ``inst_load`` (MeshPipeline), and the ingest
    thread should stay as light as possible."""
    ok = np.asarray(b.valid) & ~np.asarray(b.is_control)
    before = frontier.copy()
    fold_frontier(frontier, b, n_inputs)
    hist = None
    if with_hist:
        keys = np.asarray(b.keys)
        km = ok[:, None] & (keys >= 0)
        if km.any():
            hist = np.bincount(keys[km].ravel(),
                               minlength=k_virt).astype(np.int64)
        else:
            hist = np.zeros((k_virt,), np.int64)
    return TickMeta(tick_id, int(ok.sum()), before, hist)


class AsyncStreamRuntime:
    """Drive a pipeline from a live source with overlapped ingest and a
    controller in the loop.  ``pipeline`` must expose ``stage`` and
    ``step_staged`` (VSNPipeline and MeshPipeline do)."""

    def __init__(self, pipeline, source, sink=None, controller=None,
                 queue_cap: int = 4, metrics: Optional[MetricsBus] = None,
                 super_batch: int = 1, checkpointer=None, tick0: int = 0):
        self.pipeline = pipeline
        self.source = source
        # fault tolerance: ``checkpointer`` (a StreamCheckpointer) is asked
        # at every tick boundary, BEFORE the dispatch that donates the
        # pipeline state; ``tick0`` offsets tick ids on a resumed run so
        # sink tick ids and checkpoint steps stay absolute across restarts
        self.checkpointer = checkpointer
        self.tick0 = int(tick0)
        self.sink = sink if sink is not None else CollectSink()
        self.controller = controller
        # super_batch=K stages K consecutive same-shape ticks as ONE
        # device-resident stack and dispatches the pipeline's persistent
        # compiled K-tick scan instead of K step calls: one dispatch, one
        # control-lane sync, zero host crossings for the data lane.  The
        # controller still runs (once per super-batch); its reconfiguration
        # is injected into the scan's first tick on device.
        assert super_batch >= 1
        if super_batch > 1:
            assert hasattr(pipeline, "run_persistent_staged"), pipeline
        self.super_batch = super_batch
        self.queue = BoundedQueue(queue_cap)
        self.metrics = metrics or MetricsBus(queue_cap=queue_cap)
        # a caller-supplied bus must still know the in-flight cap, or the
        # controllers' queue-pressure term silently never fires
        self.metrics.queue_cap = self.metrics.queue_cap or queue_cap
        self.reconfig_trace: List[Tuple[int, Reconfiguration]] = []
        self.switches = 0
        # host shadows of the COMMITTED epoch tables (mesh load fallback +
        # the n_active a load sample is judged under); read once before the
        # stream starts, so no in-flight sync.  Pending (injected but not
        # yet switched) reconfigurations live in the MetricsBus, which
        # hands back what a switch committed.
        self._fmu_shadow = np.asarray(pipeline.epoch.fmu).copy()
        self._active_shadow = np.asarray(pipeline.epoch.active).copy()
        self._ingest_error: Optional[BaseException] = None
        # SLO breaches: _pending feeds the NEXT controller decision via
        # LiveMetrics.slo_breaches, _all accumulates for the RunReport
        self._pending_breaches: List = []
        self._all_breaches: List = []

    # -- ingest thread ------------------------------------------------------
    def _ingest(self, max_ticks: Optional[int]):
        n_inputs = self.pipeline.op.n_inputs
        k_virt = self.pipeline.op.k_virt
        # the key histogram is only needed for the host-side load fallback
        # (pipelines whose step doesn't return a device inst_load)
        with_hist = not getattr(self.pipeline, "device_inst_load", False)
        frontier = _initial_frontier(self.pipeline, n_inputs)
        try:
            if self.super_batch > 1:
                self._ingest_super(max_ticks, n_inputs, k_virt, with_hist,
                                   frontier)
            else:
                for i, b in enumerate(self.source):
                    if max_ticks is not None and i >= max_ticks:
                        break
                    with _obs.span("ingest.stage"):
                        meta = tick_meta(b, self.tick0 + i, n_inputs,
                                         k_virt, frontier,
                                         with_hist=with_hist)
                        staged = self.pipeline.stage(b)   # async transfer
                    tl = _obs.exemplars()
                    if tl is not None:
                        ok = np.asarray(b.valid) & ~np.asarray(b.is_control)
                        tl.scan(np.asarray(b.source), np.asarray(b.tau),
                                ok, "stage", tick_id=meta.tick_id)
                    self.queue.put(StagedTick(meta, staged))
        except BaseException as e:              # surfaced after join()
            self._ingest_error = e
            _obs.event("ingest_error", error=repr(e))
        finally:
            self.queue.close()

    def _ingest_super(self, max_ticks, n_inputs: int, k_virt: int,
                      with_hist: bool, frontier: np.ndarray):
        """Group up to ``super_batch`` consecutive same-shape ticks and
        stage each group as one device stack.  A shape change flushes the
        open group early; a partial group is padded with all-invalid no-op
        ticks so every dispatch reuses ONE compiled K-tick executable."""
        K = self.super_batch
        group: List[T.TupleBatch] = []
        metas: List[TickMeta] = []
        gkey = None

        def flush():
            nonlocal group, metas
            if not group:
                return
            n_pad = K - len(group)
            b0 = group[0]
            with _obs.span("ingest.stage"):
                ticks = group + [T.empty_batch(b0.batch, b0.kmax,
                                               b0.payload_width)] * n_pad
                stack = self.pipeline.stage_super(ticks)   # async transfer
            self.queue.put(StagedSuper(metas=metas, stack=stack,
                                       n_pad=n_pad))
            group, metas = [], []

        for i, b in enumerate(self.source):
            if max_ticks is not None and i >= max_ticks:
                break
            key = (b.batch, b.kmax, b.payload_width)
            if group and key != gkey:
                flush()
            gkey = key
            metas.append(tick_meta(b, self.tick0 + i, n_inputs, k_virt,
                                   frontier, with_hist=with_hist))
            tl = _obs.exemplars()
            if tl is not None:
                ok = np.asarray(b.valid) & ~np.asarray(b.is_control)
                # bind to the super-batch's decision tick (the first tick
                # id of the open group) — that is the id _drain sees
                tl.scan(np.asarray(b.source), np.asarray(b.tau), ok,
                        "stage", tick_id=metas[0].tick_id)
            group.append(b)
            if len(group) == K:
                flush()
        flush()

    @staticmethod
    def _combine_meta(metas: List[TickMeta]) -> TickMeta:
        """One decision-granularity view of a super-batch: tuple counts and
        key histograms sum; the frontier stamp is the one BEFORE the first
        tick (the reconfiguration is injected there)."""
        hist = (None if metas[0].key_hist is None
                else np.sum([m.key_hist for m in metas], axis=0))
        return TickMeta(tick_id=metas[0].tick_id,
                        n_tuples=sum(m.n_tuples for m in metas),
                        frontier_before=metas[0].frontier_before,
                        key_hist=hist)

    # -- metric sampling ----------------------------------------------------
    def _host_inst_load(self, key_hist) -> Optional[np.ndarray]:
        if key_hist is None:
            return None
        n_max = self._active_shadow.shape[0]
        return np.bincount(self._fmu_shadow, weights=key_hist,
                           minlength=n_max).astype(np.int64)

    def _drain(self, pending, idle_s: float = 0.0):
        """Fetch the sampled metrics of a completed tick (blocks only on the
        scalar ``switched`` flag and the tiny per-instance load vector).
        ``idle_s`` — time the loop spent waiting on the source for the NEXT
        tick — is subtracted so a paced/starved source does not inflate the
        reported tick latency."""
        tick_id, switched, inst_load, meta, t_dispatch = pending
        with _obs.span("runtime.drain"):
            sw = bool(np.asarray(switched))
            load = (np.asarray(inst_load) if inst_load is not None
                    else self._host_inst_load(meta.key_hist))
        latency = max(time.perf_counter() - t_dispatch - idle_s, 0.0)
        _obs.event("tick", tick_id=tick_id, n_tuples=meta.n_tuples,
                   latency_ms=latency * 1e3, queue_depth=self.queue.depth,
                   queue_high_water=self.queue.high_water, switched=sw,
                   wmark_frontier=meta.frontier_before.tolist())
        # record BEFORE updating the shadows: this tick's load was measured
        # under the pre-switch tables, and the (inst_load, n_active) pair
        # must stay consistent or the controller reads phantom skew.
        self.metrics.record_tick(tick_id, meta.n_tuples, latency, load,
                                 self.queue.depth,
                                 n_active=int(self._active_shadow.sum()))
        o = _obs.get()
        if o is not None:
            if o.timeline is not None:
                # the tick's outputs are known delivered here: drain then
                # emit, completing this tick's exemplar timelines
                o.timeline.mark_tick(tick_id, "drain")
                o.timeline.mark_tick(tick_id, "emit")
            if o.slo is not None:
                # evaluate on the freshest tick-latency/drain quantiles;
                # breaches reach the controller at the next _decide
                new = o.evaluate_slo()
                if new:
                    self._pending_breaches.extend(new)
                    self._all_breaches.extend(new)
        if sw:
            self.switches += 1
            # the switch commits the LATEST rc injected by this tick; any
            # earlier ones it superseded are resolved with it.
            resolved = self.metrics.record_switch(tick_id)
            if resolved:
                rc = resolved[-1]
                self._fmu_shadow = np.asarray(rc.fmu).copy()
                self._active_shadow = np.asarray(rc.active).copy()
                _obs.event("switch", tick_id=tick_id, epoch=int(rc.epoch),
                           n_active=int(self._active_shadow.sum()))

    def _decide(self, meta: TickMeta) -> Optional[Reconfiguration]:
        if self.controller is None:
            return None
        hint = None
        if hasattr(self.source, "rate_hint"):
            hint = self.source.rate_hint(meta.tick_id)
        if hint is None and len(self.metrics.records) < 2:
            return None    # no rate signal yet: a measured rate of 0.0 at
            # stream start would read as idle and trigger a bogus scale-down
        breaches = tuple(self._pending_breaches)
        self._pending_breaches.clear()
        snap = self.metrics.snapshot(
            rate_hint=hint, queue_depth=self.queue.depth,
            backlog_tuples=float(self.queue.depth * meta.n_tuples),
            slo_breaches=breaches)
        with _obs.span("controller.decide"):
            return self.controller.observe_live(snap)

    # -- the loop -----------------------------------------------------------
    def run(self, max_ticks: Optional[int] = None) -> RunReport:
        th = threading.Thread(target=self._ingest, args=(max_ticks,),
                              daemon=True)
        self.metrics.start()
        th.start()
        pending = None
        try:
            while True:
                t_wait = time.perf_counter()
                try:
                    item = self.queue.get()
                except QueueClosed:     # ingest done and every tick drained
                    break
                idle_s = time.perf_counter() - t_wait
                if isinstance(item, StagedSuper):
                    meta = self._combine_meta(item.metas)
                else:
                    meta = item.meta
                if self.checkpointer is not None:
                    # the boundary BEFORE this tick: pipeline state covers
                    # every tick < meta.tick_id and nothing of this one;
                    # capture is synchronous-to-host (the dispatch below
                    # donates sg/sigma), the disk write is async
                    with _obs.span("runtime.checkpoint"):
                        self.checkpointer.maybe_save(meta.tick_id,
                                                     meta.frontier_before)
                rc = self._decide(meta)
                t0 = time.perf_counter()
                with _obs.span("runtime.dispatch"):
                    if isinstance(item, StagedSuper):
                        out = self.pipeline.run_persistent_staged(
                            item.stack, reconfig=rc, reconfig_at=0,
                            frontier=meta.frontier_before)
                        o1, o2 = out.outs_pre, out.outs_post
                        switched = out.switched.any()
                        inst_load = (None if out.inst_load is None
                                     else out.inst_load.sum(axis=0))
                    else:
                        o1, o2, switched, inst_load = \
                            self.pipeline.step_staged(
                                item.staged, reconfig=rc,
                                frontier=meta.frontier_before)
                tl = _obs.exemplars()
                if tl is not None:
                    tl.mark_tick(meta.tick_id, "dispatch")
                if rc is not None:
                    self.reconfig_trace.append((meta.tick_id, rc))
                    self.metrics.record_detection(rc.epoch,
                                                  meta.tick_id, rc)
                    _obs.event("reconfig", tick_id=meta.tick_id,
                               epoch=int(rc.epoch),
                               n_active=int(np.asarray(rc.active).sum()))
                self.sink.accept(meta.tick_id, o1, o2)
                if pending is not None:
                    # tick T-1 syncs while T computes; the wait for T's
                    # arrival was source idle time, not T-1's latency
                    self._drain(pending, idle_s=idle_s)
                pending = (meta.tick_id, switched, inst_load, meta, t0)
            if pending is not None:
                self._drain(pending)
        except BaseException as e:
            # failures come with a timeline, not just a stack trace: stamp
            # the crash into the ring and dump it (when a dump_dir is
            # configured) before unwinding
            _obs.event("runtime_crash", error=repr(e))
            o = _obs.get()
            if o is not None:
                o.dump_flight(reason=f"runtime_crash: {e!r}")
            raise
        finally:
            # on error the ingest thread may be parked in put(); closing
            # the queue releases it so nothing (thread or staged device
            # buffers) outlives the run
            self.queue.close()
            self.metrics.stop()
            th.join(timeout=30)
            if self.checkpointer is not None:
                self.checkpointer.wait()   # never exit with a torn save
        if self._ingest_error is not None:
            o = _obs.get()
            if o is not None:
                o.dump_flight(
                    reason=f"ingest_error: {self._ingest_error!r}")
            raise self._ingest_error
        return make_report(self.metrics, self.reconfig_trace, self.switches,
                           queue=self.queue, slo_breaches=self._all_breaches)


def run_sync(pipeline, source, sink=None, controller=None,
             max_ticks: Optional[int] = None,
             reconfig_trace=None) -> Tuple[RunReport, Any]:
    """The synchronous host-loop baseline: generate a tick, step, block on
    the outputs, repeat.  Same semantics as the async loop (same control
    tuples, same frontier stamps) minus every overlap — the reference both
    for the throughput comparison and for output-set parity.

    ``reconfig_trace`` replays a recorded ``[(tick_id, Reconfiguration)]``
    (e.g. from an async run) instead of consulting ``controller``, so a
    parity check can hold the reconfiguration sequence fixed.
    """
    sink = sink if sink is not None else CollectSink()
    metrics = MetricsBus(queue_cap=0)
    n_inputs = pipeline.op.n_inputs
    k_virt = pipeline.op.k_virt
    frontier = _initial_frontier(pipeline, n_inputs)
    replay = dict(reconfig_trace) if reconfig_trace is not None else None
    trace: List[Tuple[int, Reconfiguration]] = []
    switches = 0
    active_shadow = np.asarray(pipeline.epoch.active).copy()
    metrics.start()
    for tick_id, b in enumerate(source):
        if max_ticks is not None and tick_id >= max_ticks:
            break
        meta = tick_meta(b, tick_id, n_inputs, k_virt, frontier,
                         with_hist=False)
        if replay is not None:
            rc = replay.get(tick_id)
        elif controller is not None:
            hint = (source.rate_hint(tick_id)
                    if hasattr(source, "rate_hint") else None)
            if hint is None and len(metrics.records) < 2:
                rc = None     # no rate signal yet (see _decide)
            else:
                rc = controller.observe_live(
                    metrics.snapshot(rate_hint=hint))
        else:
            rc = None
        t0 = time.perf_counter()
        o1, o2, switched, inst_load = pipeline.step_staged(
            b, reconfig=rc, frontier=meta.frontier_before)
        if rc is not None:
            trace.append((tick_id, rc))
            metrics.record_detection(rc.epoch, tick_id, rc)
        jax.block_until_ready((o1, o2))        # the synchronous host loop
        sw = bool(np.asarray(switched))
        load = None if inst_load is None else np.asarray(inst_load)
        metrics.record_tick(tick_id, meta.n_tuples,
                            time.perf_counter() - t0, load, 0,
                            n_active=int(active_shadow.sum()))
        if sw:
            switches += 1
            resolved = metrics.record_switch(tick_id)
            if resolved:
                active_shadow = np.asarray(resolved[-1].active).copy()
        sink.accept(tick_id, o1, o2)
    metrics.stop()
    return make_report(metrics, trace, switches), sink
