"""Shared-Nothing execution (paper §2.2, Alg. 1-2) — the baseline.

forwardSN (Alg. 1): each tuple is *copied* to every downstream instance
responsible for at least one of its keys — this is the data duplication of
Theorem 1 (duplication factor = mean distinct responsible instances per
tuple).  processSN (Alg. 2): each instance keeps a dedicated state
``sigma_j`` (no sharing), so elastic reconfigurations additionally require
*state transfer* (§2.5) — implemented in elastic.py as the measured baseline.

On a mesh this is the all-to-all dispatch pattern; on the reference host
executor the duplication shows up as per-instance valid masks over the same
lane layout (tuples are not compacted — lane b is "queued at instance j"
iff ``route[b, j]``), which keeps the executor shape-static while preserving
queue semantics and per-instance arrival order.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.operator import OperatorDef, OpState, tick
from repro.core.vsn import responsibility


def route_matrix(batch: T.TupleBatch, fmu: jax.Array, active: jax.Array
                 ) -> jax.Array:
    """forwardSN routing: route[b, j] = instance j receives a copy of tuple b
    (Alg. 1 L5-7: at least one of t's keys maps to j)."""
    n_inst = active.shape[0]
    key_ok = batch.keys >= 0                                  # [B, KMAX]
    dest = fmu[jnp.clip(batch.keys, 0, fmu.shape[0] - 1)]     # [B, KMAX]
    onehot = (dest[..., None] == jnp.arange(n_inst)) & key_ok[..., None]
    route = jnp.any(onehot, axis=1)                           # [B, n_inst]
    # control tuples reach every instance (Alg. 5 fans them out per queue)
    route = route | batch.is_control[:, None]
    return route & batch.valid[:, None] & active[None, :]


def duplication_factor(batch: T.TupleBatch, fmu: jax.Array,
                       active: jax.Array) -> jax.Array:
    """Copies sent per input tuple (1.0 = no duplication)."""
    route = route_matrix(batch, fmu, active)
    sent = jnp.sum(route.astype(jnp.float32))
    n = jnp.maximum(jnp.sum(batch.valid.astype(jnp.float32)), 1.0)
    return sent / n


def run_tick(op: OperatorDef, states_j, ready: T.TupleBatch,
             fmu: jax.Array, active: jax.Array,
             tick_fn: Callable = tick):
    """One SN tick: route copies, then each instance processes its queue
    against its *dedicated* state (leading [n_inst] axis on ``states_j``).

    SN instances only see the tuples routed to them, so their implicit
    watermarks stall on dry queues (§2.3); like Flink, the tick's end
    watermark is *explicitly* broadcast to every instance."""
    route = route_matrix(ready, fmu, active)
    live = ready.valid & ~ready.is_control
    w_end = jnp.max(jnp.where(live, ready.tau, 0))

    def per_instance(j, state_j):
        queued = dataclasses.replace(ready, valid=route[:, j])
        resp = responsibility(fmu, j, active)
        return tick_fn(op, state_j, queued, resp, explicit_w=w_end)

    n_inst = active.shape[0]
    return jax.vmap(per_instance)(jnp.arange(n_inst), states_j)


def init_states(op: OperatorDef, n_inst: int):
    """Dedicated per-instance states: sigma_j stacked on a leading axis."""
    one = op.init_state()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_inst,) + a.shape),
                        one)
