"""The generalized stateful operator ``O+`` (paper §4.2, Alg. 2).

``O+(WA, WS, I, f_MK, WT, S, f_mu, f_U, f_O, f_S)`` subsumes Aggregates and
Joins (Theorem 2) and admits arbitrary per-tuple key *sets* (Definition 4).

TPU adaptation of the function contract (DESIGN.md §5): the paper invokes
``f_U``/``f_O``/``f_S`` per (key, window-instance); here every user function
is *vectorized over the virtual key axis* ``K`` — the runtime hands the user
the full key-sliced state for one window slot plus an update mask, and keeps
(a) per-(key,slot) occupancy, (b) the ring of live window generations,
(c) expiry bookkeeping (``rho``, Alg. 2 L33-35) itself.  Semantics are those
of Alg. 2 processed one ready tuple at a time (``jax.lax.scan``), which the
tests pin against hand-computed traces (Appendix E).

State layout
------------
``sigma`` is a user pytree whose leaves carry leading dims ``[K, n_slots]``.
Window boundaries are global (the window grid does not depend on the key), so
one scalar ``next_l`` — the earliest non-expired window index, the paper's
``rho / WA`` — plus the ring discipline ``slot(l) = l % n_slots`` recovers
every live instance boundary.

User functions (all leaves sliced to one slot ``s``: leading dim ``[K]``):

  f_u(zeta_s, tup, win_l, mask[K])   -> (zeta_s', out_payload[K,P], out_valid[K])
  f_o(zeta_s, win_l, key_ids[K])     -> (out_payload[K,P], out_valid[K])
  f_s(zeta_s, new_left)              -> (zeta_s', occupied[K])

Defaults follow Table 1: ``f_U`` stores the tuple in a bounded per-instance
ring (``TupleStore``), ``f_O`` emits nothing, ``f_S`` purges stale tuples.
Output tuples take ``tau = right boundary`` (Observation 1) via
``prepare_out_tuples``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.windows import MULTI, SINGLE, WindowSpec

# next_l before any tuple arrived: the paper inits rho to 0 but lowers it to
# the first tuple's earliest window (Alg. 2 L24); we use a sentinel and
# resolve it on first contact so windows with negative indices work too.
UNSET_L = jnp.iinfo(jnp.int32).min


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Tup:
    """One tuple, as seen by f_U (scan-carried scalar view)."""
    tau: jax.Array       # i32[]
    payload: jax.Array   # f32[P]
    source: jax.Array    # i32[]
    keys: jax.Array      # i32[KMAX]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OpState:
    zeta: Any            # user pytree, leaves [K, n_slots, ...]
    occupied: jax.Array  # bool[K, n_slots]  (check&Create bookkeeping)
    next_l: jax.Array    # i32[] earliest non-expired window index (= rho/WA)
    watermark: jax.Array  # i32[] instance watermark W


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Outputs:
    """Fixed-capacity output buffer for one tick (+ overflow accounting)."""
    tau: jax.Array       # i32[cap]
    payload: jax.Array   # f32[cap, P]
    valid: jax.Array     # bool[cap]
    count: jax.Array     # i32[] number of valid lanes
    overflow: jax.Array  # i32[] outputs dropped (buffer too small)

    def as_batch(self, kmax: int = 1) -> T.TupleBatch:
        return T.make_batch(self.tau, self.payload, valid=self.valid, kmax=kmax)


def _empty_outputs(cap: int, p: int) -> Outputs:
    return Outputs(tau=jnp.zeros((cap,), jnp.int32),
                   payload=jnp.zeros((cap, p), jnp.float32),
                   valid=jnp.zeros((cap,), bool),
                   count=jnp.zeros((), jnp.int32),
                   overflow=jnp.zeros((), jnp.int32))


def _emit(outs: Outputs, tau: jax.Array, payload: jax.Array,
          valid: jax.Array) -> Outputs:
    """Append up to K masked rows into the output buffer (drop + count extra)."""
    cap = outs.tau.shape[0]
    vi = valid.astype(jnp.int32)
    pos = outs.count + jnp.cumsum(vi) - vi  # target lane per emitted row
    idx = jnp.where(valid & (pos < cap), pos, cap)  # cap == drop lane
    n = jnp.sum(vi)
    tau_b = jnp.broadcast_to(jnp.asarray(tau, jnp.int32), valid.shape)
    return Outputs(
        tau=outs.tau.at[idx].set(tau_b, mode="drop"),
        payload=outs.payload.at[idx].set(payload.astype(jnp.float32), mode="drop"),
        valid=outs.valid.at[idx].set(valid, mode="drop"),
        count=jnp.minimum(outs.count + n, cap),
        overflow=outs.overflow + jnp.maximum(outs.count + n - cap, 0) -
                 jnp.maximum(outs.count - cap, 0),
    )


# ---------------------------------------------------------------------------
# Table-1 default behaviours
# ---------------------------------------------------------------------------

def tuple_store_init(k: int, n_slots: int, ring: int, p: int):
    """Default zeta: bounded per-(key,slot) tuple ring (Table 1 f_U default)."""
    return {
        "tau": jnp.full((k, n_slots, ring), -1, jnp.int32),
        "payload": jnp.zeros((k, n_slots, ring, p), jnp.float32),
        "source": jnp.zeros((k, n_slots, ring), jnp.int32),
        "count": jnp.zeros((k, n_slots), jnp.int32),
    }


def default_f_u(zeta_s, tup: Tup, win_l, mask):
    """Store t in w.zeta of t's sender; return no phi (Table 1)."""
    ring = zeta_s["tau"].shape[-1]
    slot = jnp.mod(zeta_s["count"], ring)
    k_ids = jnp.arange(zeta_s["tau"].shape[0])
    new = {
        "tau": zeta_s["tau"].at[k_ids, slot].set(tup.tau),
        "payload": zeta_s["payload"].at[k_ids, slot].set(tup.payload),
        "source": zeta_s["source"].at[k_ids, slot].set(tup.source),
        "count": zeta_s["count"] + 1,
    }
    out = jnp.zeros((zeta_s["tau"].shape[0], tup.payload.shape[-1]), jnp.float32)
    return new, out, jnp.zeros((zeta_s["tau"].shape[0],), bool)


def default_f_o(zeta_s, win_l, key_ids):
    """Return no phi (Table 1)."""
    k = key_ids.shape[0]
    p = zeta_s["payload"].shape[-1] if isinstance(zeta_s, dict) and "payload" in zeta_s else 1
    return jnp.zeros((k, p), jnp.float32), jnp.zeros((k,), bool)


def default_f_s(ws: int):
    """Purge stale tuples (Table 1): drop entries with tau < new left bound."""
    def f_s(zeta_s, new_left):
        stale = zeta_s["tau"] < new_left
        zeta = dict(zeta_s)
        zeta["tau"] = jnp.where(stale, -1, zeta_s["tau"])
        live = jnp.sum((zeta["tau"] >= 0).astype(jnp.int32), axis=-1)
        return zeta, live > 0
    return f_s


# ---------------------------------------------------------------------------
# The operator definition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatorDef:
    """``O+(WA, WS, I, f_MK, WT, S, f_mu, f_U, f_O, f_S)`` — paper §4.2.

    ``f_mk`` may be None when the ingress already materializes key sets into
    ``TupleBatch.keys`` (our datagens do, mirroring metadata-borne keys §3).
    ``f_mu`` is not stored here: routing tables live with the *executor*
    (sn.py / vsn.py) because they are epoch state (Alg. 4), not operator
    definition.
    """
    window: WindowSpec
    n_inputs: int                                   # I
    k_virt: int                                     # virtual key space |K|
    payload_out: int                                # S (flattened width)
    init_zeta: Callable[[], Any]
    f_u: Callable = None
    f_o: Callable = None
    f_s: Callable = None
    f_mk: Optional[Callable[[T.TupleBatch], jax.Array]] = None
    out_cap: int = 256                              # per-tick output lanes
    extra_slots: int = 0                            # ring slack for batched paths
    lazy_expiry: bool = False                       # skip f_O rounds when f_O = "-"
    name: str = "o_plus"

    @property
    def slots(self) -> int:
        """Physical slot-ring size >= live window instances (slack lets the
        vectorized fast paths hold two in-flight generations per slot)."""
        return self.window.n_slots + self.extra_slots

    def slot_of(self, l):
        return jnp.mod(l, self.slots)

    def resolved(self) -> "OperatorDef":
        """Fill Table-1 defaults for unspecified functions."""
        return dataclasses.replace(
            self,
            f_u=self.f_u or default_f_u,
            f_o=self.f_o or default_f_o,
            f_s=self.f_s or default_f_s(self.window.ws),
        )

    def init_state(self) -> OpState:
        return OpState(zeta=self.init_zeta(),
                       occupied=jnp.zeros((self.k_virt, self.slots), bool),
                       next_l=jnp.full((), UNSET_L, jnp.int32),
                       watermark=jnp.zeros((), jnp.int32))


def _slice_slot(zeta, s):
    return jax.tree.map(lambda a: a[:, s], zeta)


def _set_slot(zeta, s, zeta_s):
    return jax.tree.map(lambda a, v: a.at[:, s].set(v), zeta, zeta_s)


def _expire_round(op: OperatorDef, st: OpState, outs: Outputs,
                  resp: jax.Array, key_ids: jax.Array):
    """forwardAndShift for the earliest live window generation (Alg. 2 L12-18).

    Emits f_O for every occupied+responsible key of the expiring generation,
    then slides (WT=single) or recycles (WT=multi) the slot.
    """
    ws = op.window
    s = op.slot_of(st.next_l)
    zeta_s = _slice_slot(st.zeta, s)
    payload, f_valid = op.f_o(zeta_s, st.next_l, key_ids)
    occ = st.occupied[:, s]
    emit_mask = f_valid & occ & resp
    outs = _emit(outs, ws.right_of(st.next_l), payload, emit_mask)

    if ws.wt == SINGLE:
        # slide the instance forward by WA; f_S purges / shifts state.
        zeta_new, still_occ = op.f_s(zeta_s, ws.left_of(st.next_l + 1))
        zeta = _set_slot(st.zeta, s, zeta_new)
        occupied = st.occupied.at[:, s].set(still_occ & occ)
    else:
        # recycle the slot for window generation next_l + n_slots.
        blank = _slice_slot(jax.tree.map(jnp.zeros_like, st.zeta), s)
        fresh = _slice_slot(op.init_zeta(), s)
        del blank
        zeta = _set_slot(st.zeta, s, fresh)
        occupied = st.occupied.at[:, s].set(False)
    return dataclasses.replace(st, zeta=zeta, occupied=occupied,
                               next_l=st.next_l + 1), outs


def _expire_all(op: OperatorDef, st: OpState, outs: Outputs, w,
                resp: jax.Array, key_ids: jax.Array):
    """while rho + WS <= W: forwardAndShift (Alg. 2 L33-35).

    NOTE the paper checks ``rho + WS < W`` with *exclusive* boundaries over
    continuous time; in integer delta ticks a window ``[l*WA, l*WA+WS)`` is
    safe to close once ``W >= l*WA + WS`` (no tuple with tau < right can
    still arrive, Definition 2), hence ``<=``.
    """
    def cond(carry):
        st, _ = carry
        return (st.next_l != UNSET_L) & (op.window.right_of(st.next_l) <= w)

    def body(carry):
        st, outs = carry
        return _expire_round(op, st, outs, resp, key_ids)

    return jax.lax.while_loop(cond, body, (st, outs))


def process_tuple(op: OperatorDef, st: OpState, outs: Outputs, tup: Tup,
                  resp: jax.Array, valid, key_offset=0) -> Tuple[OpState, Outputs]:
    """processSN/processVSN body for one ready tuple (Alg. 2 L31-36).

    ``resp`` is the responsibility mask over virtual keys for *this*
    instance under the current epoch's f_mu (Alg. 2 L26 / Alg. 4 L23); the
    executors own its construction.

    ``key_offset`` supports the mesh owner-computes layout (vsn.shard_tick):
    a shard holding the contiguous key block ``[key_offset, key_offset +
    k_virt)`` runs the tick against its local rows while tuple keys and
    emitted key ids stay *global* — ``key_ids`` below are global values.
    """
    ws = op.window
    key_ids = key_offset + jnp.arange(op.k_virt)

    # updateW (implicit watermarks: the ready stream is sorted, §2.3).
    w = jnp.where(valid, jnp.maximum(st.watermark, tup.tau), st.watermark)
    # first contact resolves the window frontier (rho <- tau_1, Alg. 2 L24)
    next_l = jnp.where((st.next_l == UNSET_L) & valid,
                       ws.earliest_win_l(tup.tau), st.next_l)
    st = dataclasses.replace(st, watermark=w, next_l=next_l)

    # Expired windows first (Alg. 2 L33-35).  Operators whose f_O is the
    # Table-1 "-" default (e.g. ScaleJoin, which purges inside f_U) may skip
    # the round entirely — expiry then only tracks the frontier.
    if op.lazy_expiry:
        next_l = jnp.maximum(st.next_l, op.window.earliest_win_l(w))
        next_l = jnp.where(st.next_l == UNSET_L, op.window.earliest_win_l(w),
                           next_l)
        st = dataclasses.replace(st, next_l=next_l)
    else:
        st, outs = _expire_all(op, st, outs, w, resp, key_ids)

    # handleInputTuple (Alg. 2 L19-30).
    resp_tuple = resp  # bool[K] — f_mu(k) == j for this instance
    # union of one-hots over the tuple's key set, restricted to responsibility
    khit = jnp.zeros((op.k_virt,), bool)
    for kk in range(tup.keys.shape[0]):  # KMAX is small & static
        key = tup.keys[kk]
        khit = khit | ((key_ids == key) & (key >= 0))
    khit = khit & resp_tuple & valid

    l_min_raw, l_max = ws.window_indices(tup.tau)
    l_min = jnp.maximum(l_min_raw, st.next_l)  # expired generations excluded
    if ws.wt == SINGLE:
        l_max = l_min  # Alg. 2 L22: single updates only the earliest instance

    def upd_body(off, carry):
        st, outs = carry
        l = l_min + off
        active = l <= l_max
        s = op.slot_of(l)
        zeta_s = _slice_slot(st.zeta, s)
        mask = khit & active
        zeta_new, payload, f_valid = op.f_u(zeta_s, tup, l, mask)
        # check&Create + masked commit: non-selected keys keep their state.
        zeta_sel = jax.tree.map(
            lambda new, old: jnp.where(
                jnp.reshape(mask, mask.shape + (1,) * (new.ndim - 1)), new, old),
            zeta_new, zeta_s)
        zeta = _set_slot(st.zeta, s, zeta_sel)
        occupied = st.occupied.at[:, s].max(mask)
        # f_U may emit multiple outputs per key: payload [K,P] or [K,E,P].
        if payload.ndim == 3:
            emit_valid = (f_valid & mask[:, None]).reshape(-1)
            payload = payload.reshape(-1, payload.shape[-1])
        else:
            emit_valid = f_valid & mask
        outs = _emit(outs, ws.right_of(l), payload, emit_valid)
        return dataclasses.replace(st, zeta=zeta, occupied=occupied), outs

    n_upd = ws.n_slots if ws.wt == MULTI else 1
    st, outs = jax.lax.fori_loop(0, n_upd, upd_body, (st, outs))
    return st, outs


def tick(op: OperatorDef, st: OpState, ready: T.TupleBatch,
         resp: jax.Array, explicit_w=None, key_offset=0) -> Tuple[OpState, Outputs]:
    """Process one ready batch tuple-by-tuple (general, order-preserving path).

    ``explicit_w`` models *explicit watermark* propagation (§2.3): an
    end-of-tick watermark broadcast to the instance regardless of which
    tuples were routed to it — required for SN correctness when an
    instance's queue runs dry (the paper's zero-rate caveat).

    ``key_offset`` shifts the local key block to global ids for the mesh
    owner-computes layout (see ``process_tuple``); single-host executors
    leave it 0.

    Fast vectorized paths for specific operator families live in
    aggregate.py / join.py; tests pin them against this oracle.
    """
    op = op.resolved()
    outs = _empty_outputs(op.out_cap, op.payload_out)

    def body(carry, lane):
        st, outs = carry
        tup = Tup(tau=ready.tau[lane], payload=ready.payload[lane],
                  source=ready.source[lane], keys=ready.keys[lane])
        valid = ready.valid[lane] & ~ready.is_control[lane]
        st, outs = process_tuple(op, st, outs, tup, resp, valid, key_offset)
        return (st, outs), None

    (st, outs), _ = jax.lax.scan(body, (st, outs), jnp.arange(ready.batch))

    if explicit_w is not None:
        w = jnp.maximum(st.watermark, explicit_w)
        next_l = jnp.where(st.next_l == UNSET_L,
                           op.window.earliest_win_l(w), st.next_l)
        st = dataclasses.replace(st, watermark=w, next_l=next_l)
        if op.lazy_expiry:
            st = dataclasses.replace(
                st, next_l=jnp.maximum(st.next_l, op.window.earliest_win_l(w)))
        else:
            st, outs = _expire_all(op, st, outs, w, resp,
                                   key_offset + jnp.arange(op.k_virt))
    return st, outs
