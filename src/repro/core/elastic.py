"""Elastic reconfiguration — epochs, control tuples, the watermark barrier
(paper §5 "From static to elastic setups", §7, Alg. 4 L13-21, Alg. 5-6).

A reconfiguration is a new epoch ``e*`` with instance set ``O*`` and mapping
``f_mu*``, delivered through a *control tuple* timestamped with the last
forwarded event time per source (addSTRETCH, Alg. 5) so it never violates
the TB's sorted-source contract.  The switch triggers when the watermark
first exceeds ``gamma = t_ctrl.tau`` (Alg. 4 L17): every tuple with
``tau <= gamma`` is processed under ``f_mu``, everything later under
``f_mu*``.  In SPMD the "waitForInstances" barrier is the lockstep itself.

State-transfer accounting (the paper's headline):
  * VSN switch cost   = bytes of the tables swapped (4 * (K + n) + O(1));
  * SN  switch cost   = bytes of sigma rows whose owner changed — the state
    transfer StreamCloud/Flink-style elasticity must ship.  ``sn_transfer``
    implements it (gather rows from old owners) so benchmarks can measure
    both sides of Figure 9's story.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core import watermark as wm


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EpochState:
    """Cond. 2 variables {e, e*, O, O*, f_mu*, gamma} (§5)."""
    e: jax.Array            # i32[] current epoch id
    fmu: jax.Array          # i32[K] current key -> instance map
    active: jax.Array       # bool[n_max] current instance set O
    e_next: jax.Array       # i32[] pending epoch id (== e when none)
    fmu_next: jax.Array     # i32[K]
    active_next: jax.Array  # bool[n_max]
    gamma: jax.Array        # i32[] trigger event time (INF when none)
    reconfigs: jax.Array    # i32[] completed reconfigurations (metric)


def init_epoch(fmu: jax.Array, active: jax.Array) -> EpochState:
    return EpochState(
        e=jnp.zeros((), jnp.int32), fmu=fmu, active=active,
        e_next=jnp.zeros((), jnp.int32), fmu_next=fmu, active_next=active,
        gamma=jnp.full((), wm.INF_TIME, jnp.int32),
        reconfigs=jnp.zeros((), jnp.int32))


def make_control_tuple(last_tau, epoch_id: int, kmax: int,
                       payload_width: int) -> T.TupleBatch:
    """addSTRETCH (Alg. 5): a control tuple carrying the reconfiguration id,
    timestamped with the last forwarded tau so per-source sort order holds.
    The new tables travel out-of-band (replicated arrays), mirroring the
    paper's metadata-borne ``O*, f_mu*``."""
    b = T.empty_batch(1, kmax, payload_width)
    return dataclasses.replace(
        b,
        tau=jnp.asarray([last_tau], jnp.int32),
        valid=jnp.ones((1,), bool),
        is_control=jnp.ones((1,), bool),
        ctrl_epoch=jnp.asarray([epoch_id], jnp.int32))


def prepare_reconfig(st: EpochState, batch: T.TupleBatch,
                     fmu_new: jax.Array, active_new: jax.Array) -> EpochState:
    """prepareReconfig (Alg. 6): adopt the *latest* control tuple whose epoch
    id exceeds the operator's (Theorem 4: latest wins, same for all)."""
    is_ctrl = batch.is_control & batch.valid
    newest = jnp.max(jnp.where(is_ctrl, batch.ctrl_epoch, -1))
    gamma_c = jnp.max(jnp.where(is_ctrl & (batch.ctrl_epoch == newest),
                                batch.tau, -1))
    take = newest > st.e
    return dataclasses.replace(
        st,
        e_next=jnp.where(take, newest, st.e_next),
        fmu_next=jnp.where(take, fmu_new, st.fmu_next),
        active_next=jnp.where(take, active_new, st.active_next),
        gamma=jnp.where(take, gamma_c, st.gamma))


def split_epoch_masks(st: EpochState, batch: T.TupleBatch):
    """Partition a tick at gamma (Alg. 4 L17): lanes with tau <= gamma run
    under f_mu, later lanes under f_mu* (the ready batch is tau-sorted, so
    this preserves processing order)."""
    data = batch.valid & ~batch.is_control
    pre = data & (batch.tau <= st.gamma)
    post = data & (batch.tau > st.gamma)
    return pre, post


def advance_epoch(st: EpochState, w_end) -> Tuple[EpochState, jax.Array]:
    """Commit the pending epoch once the watermark has passed gamma (the
    barrier: in SPMD every instance evaluates this identically).  Returns
    (state, switched?)."""
    switch = (st.e_next > st.e) & (w_end > st.gamma)
    new = EpochState(
        e=jnp.where(switch, st.e_next, st.e),
        fmu=jnp.where(switch, st.fmu_next, st.fmu),
        active=jnp.where(switch, st.active_next, st.active),
        e_next=st.e_next,
        fmu_next=st.fmu_next,
        active_next=st.active_next,
        gamma=jnp.where(switch, wm.INF_TIME, st.gamma),
        reconfigs=st.reconfigs + switch.astype(jnp.int32),
    )
    return new, switch


def vsn_switch_bytes(st: EpochState) -> int:
    """Bytes touched by a VSN reconfiguration: the tables only."""
    return int(st.fmu.size * 4 + st.active.size + 12)


def sn_transfer(states_j: Any, fmu_old: jax.Array, fmu_new: jax.Array):
    """The SN baseline's state transfer: ship every key row whose owner
    changed from its old instance to its new one (serialization /
    deserialization of §1).  Returns (new states, bytes moved)."""
    moved = fmu_old != fmu_new

    k_virt = fmu_old.shape[0]

    def reship(leaf):
        # leaf: [n_inst, K, ...]; new_leaf[j, k] = leaf[fmu_old[k], k] if
        # fmu_new[k] == j (row fetched from old owner), else leaf[j, k].
        # Per-instance scalars (watermark/next_l bookkeeping) are not keyed
        # state and stay put.
        if leaf.ndim < 2 or leaf.shape[1] != k_virt:
            return leaf
        k_ids = jnp.arange(leaf.shape[1])
        from_old = leaf[fmu_old, k_ids]                  # [K, ...]
        n_inst = leaf.shape[0]
        take = (fmu_new[None, :] == jnp.arange(n_inst)[:, None]) & moved[None, :]
        take = take.reshape(take.shape + (1,) * (leaf.ndim - 2))
        return jnp.where(take, from_old[None], leaf)

    new_states = jax.tree.map(reship, states_j)
    row_bytes = sum(
        int(jnp.dtype(l.dtype).itemsize * l.size / (l.shape[0] * l.shape[1]))
        for l in jax.tree.leaves(states_j)
        if l.ndim >= 2 and l.shape[1] == k_virt)
    moved_rows = jnp.sum(moved.astype(jnp.int32))
    return new_states, (moved_rows * row_bytes).astype(jnp.int32)
