"""VSN execution (paper §5, Alg. 3-4): shared Tuple Buffer, shared state.

Every instance consumes the *same* totally-ordered ready batch (the
all-gathered ScaleGate output — our shared TB), and processes exactly the
virtual keys it is responsible for under the current epoch's ``f_mu``
(Alg. 4 L23 / Alg. 2 L26).  No tuple is ever duplicated (Observation 2) and
state never moves at reconfiguration (Theorem 3): each key row of the shared
``sigma`` is written by exactly one instance per epoch, so the merged state
is simply "row k comes from instance f_mu(k)".

Two realizations:

* ``run_tick`` — single-host reference used by tests/benchmarks: ``vmap``
  over instances against the shared state, then the disjoint-writer merge.
  On one device the vmapped instances literally share memory — the paper's
  own setting.
* ``shard_tick`` / ``shard_pipeline_step`` — mesh execution: ``sigma`` rows
  are sharded over the device axis in fixed contiguous key blocks
  (owner-computes: storage layout == responsibility), the ready batch and
  the epoch tables are replicated (the replicated TB *is* the shared Tuple
  Buffer: every shard observes the identical total order), and the merge is
  a no-op by layout.  An ``f_mu`` epoch switch only swaps the replicated
  tables — no sigma row ever crosses a device (Theorem 3 made physical:
  the compiled step contains zero cross-device collectives).  Batched
  multi-tick ingest stacks T ticks and ``lax.scan``s over them inside one
  ``shard_map`` call, so the hot loop does not round-trip to Python per
  tick.  ``core.runtime.MeshPipeline`` is the driver.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.operator import OperatorDef, OpState, Outputs, tick


def responsibility(fmu: jax.Array, j, active: jax.Array) -> jax.Array:
    """resp[k] = (f_mu(k) == j) for an active instance, else empty."""
    return (fmu == j) & active[j]


def merge_states(stacked: OpState, fmu: jax.Array) -> OpState:
    """Disjoint-writer merge: row k of sigma comes from instance f_mu(k).

    Scalars (watermark, next_l) advance identically on all instances —
    TB delivers the same watermarks to all readers (Definition 6) — so any
    reduction that picks a consistent value works; we take the max to also
    tolerate inactive instances that skipped the tick.
    """
    def pick_rows(leaf):
        # leaf: [n_inst, K, ...] -> [K, ...]
        return leaf[fmu, jnp.arange(leaf.shape[1])]

    zeta = jax.tree.map(pick_rows, stacked.zeta)
    occupied = pick_rows(stacked.occupied)
    return OpState(zeta=zeta, occupied=occupied,
                   next_l=jnp.max(stacked.next_l),
                   watermark=jnp.max(stacked.watermark))


def merge_fast_state(stacked, fmu: jax.Array):
    """Disjoint-writer merge for the fast-path states (FastAggState /
    FastJoinState): leaves with a leading [n_inst, K, ...] key axis are
    row-picked by f_mu; global counters take max (identical on writers) and
    per-instance metrics (collisions/comparisons) sum."""
    from repro.core.aggregate import FastAggState
    from repro.core.join import FastJoinState

    if isinstance(stacked, FastAggState):
        return FastAggState(
            op_state=merge_states(stacked.op_state, fmu),
            slot_l=jnp.max(stacked.slot_l, axis=0),
            collisions=jnp.sum(stacked.collisions))
    if isinstance(stacked, FastJoinState):
        rows = jnp.arange(stacked.tau.shape[1])
        return FastJoinState(
            tau=stacked.tau[fmu, rows], pay=stacked.pay[fmu, rows],
            stream=stacked.stream[fmu, rows], n=stacked.n[fmu, rows],
            c=jnp.max(stacked.c),
            comparisons=jnp.sum(stacked.comparisons))
    raise TypeError(type(stacked))


def run_tick(op: OperatorDef, state, ready: T.TupleBatch,
             fmu: jax.Array, active: jax.Array,
             tick_fn: Callable = tick,
             merge_fn: Callable = merge_states):
    """One VSN tick over all instances against shared state.

    ``tick_fn(op, state, ready, resp) -> (state, outs)`` defaults to the
    general O+ path; the fast paths (aggregate/join) plug in with their
    matching ``merge_fn`` since they obey the same responsibility contract.
    """
    n_inst = active.shape[0]

    def per_instance(j):
        resp = responsibility(fmu, j, active)
        return tick_fn(op, state, ready, resp, explicit_w=None)

    stacked_state, stacked_outs = jax.vmap(per_instance)(jnp.arange(n_inst))
    merged = merge_fn(stacked_state, fmu)
    return merged, stacked_outs  # outputs stay per-instance (readers merge)


def pipeline_tick(sg, epoch, sigma, incoming: T.TupleBatch,
                  fmu_new: jax.Array, active_new: jax.Array,
                  tick_with_epoch: Callable, on_ready: Callable = None):
    """One full pipeline tick: ScaleGate push -> prepareReconfig -> two-phase
    epoch-split tick (Alg. 4 L17) -> advanceEpoch — the single traced body
    shared by ``VSNPipeline._step_impl``, the mesh scan (``shard_pipeline_
    step``) and the persistent K-tick drivers (``runtime.run_persistent``),
    so the per-step and batched paths can never drift apart.

    ``tick_with_epoch(sigma, ready, epoch) -> (sigma, outs)`` runs one
    phase under the epoch in effect for it; ``on_ready(ready, epoch)``
    (optional) is evaluated right after prepareReconfig — under the
    in-effect ``f_mu``, before any switch — and its result is returned as
    ``extra`` (the per-instance-load hook).

    Returns ``(sg, epoch, sigma, outs_pre, outs_post, switched, wmk,
    extra)`` where ``wmk`` is this tick's watermark report — the one
    device scalar the control lane carries back per tick.
    """
    from repro.core import elastic, scalegate

    sg, ready = scalegate.push(sg, incoming)
    epoch = elastic.prepare_reconfig(epoch, ready, fmu_new, active_new)
    pre, post = elastic.split_epoch_masks(epoch, ready)
    extra = None if on_ready is None else on_ready(ready, epoch)

    ready_pre = dataclasses.replace(
        ready, valid=pre | (ready.is_control & ready.valid))
    sigma, outs1 = tick_with_epoch(sigma, ready_pre, epoch)

    live = ready.valid & ~ready.is_control
    w_end = jnp.max(jnp.where(live, ready.tau, 0))
    epoch, switched = elastic.advance_epoch(epoch, w_end)

    ready_post = dataclasses.replace(ready, valid=post)
    sigma, outs2 = tick_with_epoch(sigma, ready_post, epoch)
    return (sg, epoch, sigma, outs1, outs2, switched, sg.wmark.value(),
            extra)


def flatten_outputs(stacked: Outputs) -> Outputs:
    """Merge per-instance output buffers into one (downstream TB ingest).

    Ordered by (tau, instance): within an instance outputs are already
    timestamp-sorted (Lemma 2), so a stable sort by tau yields the global
    order the downstream ScaleGate expects.
    """
    tau = stacked.tau.reshape(-1)
    payload = stacked.payload.reshape(-1, stacked.payload.shape[-1])
    valid = stacked.valid.reshape(-1)
    order = jnp.argsort(jnp.where(valid, tau, jnp.iinfo(jnp.int32).max),
                        stable=True)
    return Outputs(tau=tau[order], payload=payload[order], valid=valid[order],
                   count=jnp.sum(stacked.count),
                   overflow=jnp.sum(stacked.overflow))


# ---------------------------------------------------------------------------
# Mesh execution (owner-computes key blocks over a device axis)
# ---------------------------------------------------------------------------

def localize_op(op: OperatorDef, lo, rows: int) -> OperatorDef:
    """View of ``op`` over the contiguous key block ``[lo, lo + rows)``.

    ``rows`` is static (shard width); ``lo`` may be a traced shard offset.
    ``init_zeta`` leaves with a leading ``k_virt`` axis are row-sliced so the
    MULTI slot-recycle path materializes block-local fresh state.

    Contract: the operator's user functions must treat the key axis
    *positionally* — they see block-local rows and may not close over the
    global ``k_virt`` or recompute global key identity from ``arange``
    (globally-meaningful key ids arrive via the tick's ``key_offset``).
    ``scalejoin_def`` violates this (its f_U's round-robin store compares
    the global counter against local ``arange``); ScaleJoin runs on the
    mesh through ``join_local_tick`` instead, which threads
    ``k_global``/``k_offset`` through the fast path explicitly.
    """
    full_init = op.init_zeta
    k_full = op.k_virt

    def init_local():
        return jax.tree.map(
            lambda a: (jax.lax.dynamic_slice_in_dim(a, lo, rows, 0)
                       if getattr(a, "ndim", 0) and a.shape[0] == k_full
                       else a),
            full_init())

    return dataclasses.replace(op, k_virt=rows, init_zeta=init_local)


def mesh_state_spec(sigma, k_virt: int, axis: str):
    """PartitionSpec pytree for a VSN state: leaves keyed by the virtual key
    axis (leading dim ``k_virt``) shard over ``axis``; scalars/tables
    replicate.  The watermark / next_l / epoch scalars are safe to
    replicate because every shard consumes the identical replicated ready
    batch (Definition 6).  The one per-shard metric — FastJoinState's
    ``comparisons``, an [n_shards] vector in the mesh layout (see
    ``join_local_tick``) — is sharded explicitly by field, not by shape,
    so shape coincidences can never mis-shard a replicated leaf."""
    from jax.sharding import PartitionSpec as P

    from repro.core.join import FastJoinState

    def spec(a):
        nd = getattr(a, "ndim", 0)
        if nd and a.shape[0] == k_virt:
            return P(axis)
        return P()

    specs = jax.tree.map(spec, sigma)
    if isinstance(sigma, FastJoinState):
        specs = dataclasses.replace(specs, comparisons=P(axis))
    return specs


def mesh_device_put(sigma, mesh, axis: str, k_virt: int):
    """Place a freshly-initialized global state onto the mesh: key-block
    sharded sigma, replicated everything else (zero-copy resharding later —
    the layout is fixed for the pipeline's lifetime, Theorem 3)."""
    from jax.sharding import NamedSharding

    n_shards = mesh.shape[axis]
    specs = mesh_state_spec(sigma, k_virt, axis)
    return jax.tree.map(
        lambda a, sp: jax.device_put(a, NamedSharding(mesh, sp)), sigma, specs)


def general_local_tick(op: OperatorDef) -> Callable:
    """Owner-computes local tick on the general O+ path: the shard processes
    every key it stores (storage layout == responsibility; ``f_mu`` remaps
    logical work attribution, never storage)."""
    def make(lo, rows: int):
        op_l = localize_op(op, lo, rows)
        resp = jnp.ones((rows,), bool)

        def fn(state, ready):
            return tick(op_l, state, ready, resp, key_offset=lo)
        return fn
    return make


def fast_agg_local_tick(op: OperatorDef, kind: str,
                        backend: str = None) -> Callable:
    """Owner-computes local tick on the vectorized aggregate fast path.
    Ring-collision counts accumulate across the scanned ticks (per-tick
    deltas are invisible from inside one batched step)."""
    from repro.core.aggregate import tick_fast as agg_fast

    def make(lo, rows: int):
        op_l = localize_op(op, lo, rows)
        resp = jnp.ones((rows,), bool)

        def fn(state, ready):
            prev = state.collisions
            state, outs = agg_fast(op_l, kind, state, ready, resp,
                                   backend=backend, key_offset=lo)
            return dataclasses.replace(state,
                                       collisions=prev + state.collisions), outs
        return fn
    return make


def join_local_tick(window, f_j: Callable, k_virt: int, out_cap: int,
                    emit: bool = True) -> Callable:
    """Owner-computes local tick for the ScaleJoin fast path (the sliced
    layout of join.tick_fast).  ``comparisons`` becomes a per-shard
    cumulative counter of shape [1] locally / [n_shards] globally."""
    from repro.core.join import tick_fast as join_fast

    def make(lo, rows: int):
        resp = jnp.ones((rows,), bool)

        def fn(state, ready):
            prev = state.comparisons
            state, outs = join_fast(window, f_j, state, ready, resp, out_cap,
                                    emit=emit, k_global=k_virt, k_offset=lo)
            return dataclasses.replace(
                state, comparisons=prev + state.comparisons[None]), outs
        return fn
    return make


def _lift_outs(outs: Outputs) -> Outputs:
    """Expand per-tick scalar counters to [T, 1] so the shard axis can
    concatenate them (out_spec P(None, axis) -> [T, n_shards] global)."""
    return dataclasses.replace(outs, count=outs.count[..., None],
                               overflow=outs.overflow[..., None])


def _outs_spec(axis: str) -> Outputs:
    from jax.sharding import PartitionSpec as P
    return Outputs(tau=P(None, axis), payload=P(None, axis),
                   valid=P(None, axis), count=P(None, axis),
                   overflow=P(None, axis))


def shard_tick(mesh, axis: str, k_virt: int, make_local_tick: Callable,
               sigma_template):
    """Build the batched mesh VSN tick: ``step(sigma, ready_stack) ->
    (sigma, outs_stack)`` scanning T pre-gated ready batches through the
    owner-computes local tick inside ONE shard_map call.

    ``sigma`` leaves with a leading ``k_virt`` axis live sharded over
    ``axis`` in fixed contiguous key blocks; the ready stack is replicated
    (the shared TB).  No merge: rows are disjoint by layout, and the
    compiled step contains zero cross-device collectives.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    n_shards = mesh.shape[axis]
    assert k_virt % n_shards == 0, (k_virt, n_shards)
    rows = k_virt // n_shards
    spec_sigma = mesh_state_spec(sigma_template, k_virt, axis)

    def body(sigma, ready_stack):
        j = jax.lax.axis_index(axis)
        tick_l = make_local_tick(j * rows, rows)

        def scan_body(sigma, ready):
            sigma, outs = tick_l(sigma, ready)
            return sigma, outs

        sigma, outs = jax.lax.scan(scan_body, sigma, ready_stack)
        return sigma, _lift_outs(outs)

    def step(sigma, ready_stack):
        return shard_map(body, mesh=mesh,
                         in_specs=(spec_sigma, P()),
                         out_specs=(spec_sigma, _outs_spec(axis)),
                         check_vma=False)(sigma, ready_stack)

    return step


def shard_pipeline_step(op: OperatorDef, mesh, axis: str,
                        make_local_tick: Callable, sigma_template):
    """The full VSN pipeline step on the mesh: ScaleGate merge -> epoch
    handling -> two-phase tick, scanning T stacked incoming ticks inside one
    shard_map call (batched ingest).

    Everything except sigma is replicated: the ScaleGate state, the
    watermark frontiers and the EpochState tables are identical on every
    shard by construction (each shard runs the identical merge over the
    identical replicated incoming tuples), so the paper's shared-TB contract
    holds without any communication.  Returns

        step(sg, epoch, sigma, inc_stack, fmu_new, active_new)
          -> (sg, epoch, sigma, outs_pre, outs_post, switched[T], wmark[T])

    ``wmark[T]`` is the per-tick watermark report — part of the control
    lane the persistent driver reads back (the data lane never leaves the
    device between ticks).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    n_shards = mesh.shape[axis]
    assert op.k_virt % n_shards == 0, (op.k_virt, n_shards)
    rows = op.k_virt // n_shards
    spec_sigma = mesh_state_spec(sigma_template, op.k_virt, axis)

    def body(sg, epoch, sigma, inc_stack, fmu_new, active_new):
        j = jax.lax.axis_index(axis)
        tick_l = make_local_tick(j * rows, rows)

        def scan_body(carry, incoming):
            sg, epoch, sigma = carry
            sg, epoch, sigma, outs1, outs2, switched, wmk, _ = pipeline_tick(
                sg, epoch, sigma, incoming, fmu_new, active_new,
                lambda s, r, e: tick_l(s, r))
            return (sg, epoch, sigma), (outs1, outs2, switched, wmk)

        (sg, epoch, sigma), (o1, o2, sw, wmk) = jax.lax.scan(
            scan_body, (sg, epoch, sigma), inc_stack)
        return sg, epoch, sigma, _lift_outs(o1), _lift_outs(o2), sw, wmk

    def step(sg, epoch, sigma, inc_stack, fmu_new, active_new):
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), spec_sigma, P(), P(), P()),
            out_specs=(P(), P(), spec_sigma, _outs_spec(axis),
                       _outs_spec(axis), P(), P()),
            check_vma=False,
        )(sg, epoch, sigma, inc_stack, fmu_new, active_new)

    return step
