"""VSN execution (paper §5, Alg. 3-4): shared Tuple Buffer, shared state.

Every instance consumes the *same* totally-ordered ready batch (the
all-gathered ScaleGate output — our shared TB), and processes exactly the
virtual keys it is responsible for under the current epoch's ``f_mu``
(Alg. 4 L23 / Alg. 2 L26).  No tuple is ever duplicated (Observation 2) and
state never moves at reconfiguration (Theorem 3): each key row of the shared
``sigma`` is written by exactly one instance per epoch, so the merged state
is simply "row k comes from instance f_mu(k)".

Two realizations:

* ``run_tick`` — single-host reference used by tests/benchmarks: ``vmap``
  over instances against the shared state, then the disjoint-writer merge.
  On one device the vmapped instances literally share memory — the paper's
  own setting.
* ``shard_tick`` — mesh execution: ``sigma`` rows are sharded over the
  instance axis (fixed layout), the ready batch is replicated by an
  all-gather, and each shard masks in its rows; the merge is a no-op by
  construction.  Used by the streaming launcher and the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.operator import OperatorDef, OpState, Outputs, tick


def responsibility(fmu: jax.Array, j, active: jax.Array) -> jax.Array:
    """resp[k] = (f_mu(k) == j) for an active instance, else empty."""
    return (fmu == j) & active[j]


def merge_states(stacked: OpState, fmu: jax.Array) -> OpState:
    """Disjoint-writer merge: row k of sigma comes from instance f_mu(k).

    Scalars (watermark, next_l) advance identically on all instances —
    TB delivers the same watermarks to all readers (Definition 6) — so any
    reduction that picks a consistent value works; we take the max to also
    tolerate inactive instances that skipped the tick.
    """
    def pick_rows(leaf):
        # leaf: [n_inst, K, ...] -> [K, ...]
        return leaf[fmu, jnp.arange(leaf.shape[1])]

    zeta = jax.tree.map(pick_rows, stacked.zeta)
    occupied = pick_rows(stacked.occupied)
    return OpState(zeta=zeta, occupied=occupied,
                   next_l=jnp.max(stacked.next_l),
                   watermark=jnp.max(stacked.watermark))


def merge_fast_state(stacked, fmu: jax.Array):
    """Disjoint-writer merge for the fast-path states (FastAggState /
    FastJoinState): leaves with a leading [n_inst, K, ...] key axis are
    row-picked by f_mu; global counters take max (identical on writers) and
    per-instance metrics (collisions/comparisons) sum."""
    from repro.core.aggregate import FastAggState
    from repro.core.join import FastJoinState

    if isinstance(stacked, FastAggState):
        return FastAggState(
            op_state=merge_states(stacked.op_state, fmu),
            slot_l=jnp.max(stacked.slot_l, axis=0),
            collisions=jnp.sum(stacked.collisions))
    if isinstance(stacked, FastJoinState):
        rows = jnp.arange(stacked.tau.shape[1])
        return FastJoinState(
            tau=stacked.tau[fmu, rows], pay=stacked.pay[fmu, rows],
            stream=stacked.stream[fmu, rows], n=stacked.n[fmu, rows],
            c=jnp.max(stacked.c),
            comparisons=jnp.sum(stacked.comparisons))
    raise TypeError(type(stacked))


def run_tick(op: OperatorDef, state, ready: T.TupleBatch,
             fmu: jax.Array, active: jax.Array,
             tick_fn: Callable = tick,
             merge_fn: Callable = merge_states):
    """One VSN tick over all instances against shared state.

    ``tick_fn(op, state, ready, resp) -> (state, outs)`` defaults to the
    general O+ path; the fast paths (aggregate/join) plug in with their
    matching ``merge_fn`` since they obey the same responsibility contract.
    """
    n_inst = active.shape[0]

    def per_instance(j):
        resp = responsibility(fmu, j, active)
        return tick_fn(op, state, ready, resp, explicit_w=None)

    stacked_state, stacked_outs = jax.vmap(per_instance)(jnp.arange(n_inst))
    merged = merge_fn(stacked_state, fmu)
    return merged, stacked_outs  # outputs stay per-instance (readers merge)


def flatten_outputs(stacked: Outputs) -> Outputs:
    """Merge per-instance output buffers into one (downstream TB ingest).

    Ordered by (tau, instance): within an instance outputs are already
    timestamp-sorted (Lemma 2), so a stable sort by tau yields the global
    order the downstream ScaleGate expects.
    """
    tau = stacked.tau.reshape(-1)
    payload = stacked.payload.reshape(-1, stacked.payload.shape[-1])
    valid = stacked.valid.reshape(-1)
    order = jnp.argsort(jnp.where(valid, tau, jnp.iinfo(jnp.int32).max),
                        stable=True)
    return Outputs(tau=tau[order], payload=payload[order], valid=valid[order],
                   count=jnp.sum(stacked.count),
                   overflow=jnp.sum(stacked.overflow))


def shard_tick(op: OperatorDef, mesh, axis: str):
    """Build the mesh VSN tick: state sharded over ``axis`` by key blocks,
    ready batch replicated (the all-gather *is* the shared TB: every shard
    observes the identical total order — DESIGN.md §2).

    Returns a function with the same signature as ``run_tick`` minus the
    merge (rows are disjoint by layout).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import shard_map

    n_shards = mesh.shape[axis]
    assert op.k_virt % n_shards == 0
    rows_per = op.k_virt // n_shards

    def local_tick(state, ready, fmu, active, shard_id):
        # local rows are [shard_id*rows_per, ...); fmu remaps *work*, and
        # work for remapped keys writes back via the owner-computes rule.
        lo = shard_id * rows_per
        resp_local = jnp.ones((rows_per,), bool) & active[shard_id]
        del fmu  # owner-computes: storage layout == responsibility
        return tick(op, state, ready, resp_local)

    def sharded(state, ready, fmu, active):
        def body(state, ready, fmu, active):
            j = jax.lax.axis_index(axis)
            return local_tick(state, ready, fmu, active, j)

        spec_state = jax.tree.map(lambda _: P(axis), state)
        return shard_map(
            body, mesh=mesh,
            in_specs=(spec_state, P(), P(), P()),
            out_specs=(spec_state, P(axis)),
            check_vma=False,
        )(state, ready, fmu, active)

    return sharded
