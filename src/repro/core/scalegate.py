"""ScaleGate / Elastic ScaleGate as a batched JAX merge (paper §2.4, §6).

The original ScaleGate is a lock-free skip list merging timestamp-sorted
source streams into one totally-ordered stream of *ready* tuples
(Definition 3), delivered to every reader.  On an SPMD TPU the
synchronization problem dissolves — the total order is a property of the
merged batch itself, which every instance observes identically (DESIGN.md
§2).  What we keep is ScaleGate's *semantics*, as a pure function:

    push(state, incoming) -> (state', ready_batch)

* tuples from each source arrive timestamp-sorted;
* the watermark is ``W = min_i max_m tau_i^m`` over active sources;
* the ready batch is totally ordered by ``(tau, source, arrival)`` and
  contains exactly the tuples with ``tau <= W`` not yet delivered;
* non-ready tuples wait in a fixed-capacity stash (TPU state is static —
  overflow is counted and surfaced, never silent).

The Elastic ScaleGate (ESG) extensions map to:
* ``addSources``/``removeSources``  -> watermark frontier add/flush
  (Lemma 3 safe lower bound / "flush tuple" of §6);
* ``addReaders``/``removeReaders``  -> the *reader* set is the executor's
  active-instance mask — every reader sees the same ready batch by
  construction, so reader membership is handled downstream (vsn.py).

``repro/kernels/scalegate_merge`` is the Pallas realization of the same
merge for the intra-chip (true shared-memory) domain.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core import watermark as wm


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScaleGateState:
    stash: T.TupleBatch          # fixed-capacity not-yet-ready tuples
    wmark: wm.WatermarkState     # per-source frontiers (Definition 3)
    overflow: jax.Array          # i32 count of tuples dropped on stash overflow

    @property
    def capacity(self) -> int:
        return self.stash.batch


def init_scalegate(n_sources: int, capacity: int, kmax: int,
                   payload_width: int, active=None) -> ScaleGateState:
    """``active`` masks the initial ESG source set: a hierarchical leaf gate
    (repro.ingest.leaf) owns only a subset of the global source ids and must
    not let the others gate its watermark."""
    return ScaleGateState(
        stash=T.empty_batch(capacity, kmax, payload_width),
        wmark=wm.init_watermark(n_sources, active=active),
        overflow=jnp.zeros((), jnp.int32),
    )


def _stable_order(tau: jax.Array, source: jax.Array, valid: jax.Array) -> jax.Array:
    """Deterministic total order: valid first, then (tau, source, arrival)."""
    n = tau.shape[0]
    # two stable passes => lexicographic (tau, source); arrival order breaks
    # remaining ties because argsort is stable.
    order1 = jnp.argsort(source, stable=True)
    tau1 = jnp.where(valid, tau, wm.INF_TIME)[order1]
    order2 = jnp.argsort(tau1, stable=True)
    return order1[order2]


# The tie-break CONTRACT of merge_order, per backend.  Both keys are valid
# ScaleGate total orders: the ready *set* and the per-tau grouping are
# identical under either; only the order among equal-tau tuples differs.
# Nothing downstream may depend on the tie order beyond determinism: the
# hierarchical root merge (repro.ingest.root) re-sorts whatever its leaves
# forward, so leaves running different backends compose correctly, and
# tests/test_ingest_tier.py pins cross-backend parity on tied-tau batches.
TIE_BREAK = {
    "xla": ("tau", "source", "arrival"),
    "pallas": ("tau", "arrival"),
    "pallas-interpret": ("tau", "arrival"),
}


def tie_break(backend: str = None):
    """The documented sort key of ``merge_order`` under ``backend``
    (resolved), as a tuple of field names — lexicographic, most-significant
    first.  ``arrival`` is the lane index in the combined stash+incoming
    buffer, so both contracts are deterministic total orders."""
    from repro.kernels import dispatch
    return TIE_BREAK[dispatch.resolve(backend)]


def merge_order(tau: jax.Array, source: jax.Array, valid: jax.Array,
                n_sources: int, backend: str = None) -> jax.Array:
    """The merge's total order, via the kernel backend dispatcher.

    ``xla`` (the CPU default) keeps the exact legacy order — lexicographic
    ``(tau, source, arrival)``.  The Pallas backends run the
    ``scalegate_merge`` bitonic network, which orders by ``(tau, arrival)``;
    both are valid ScaleGate total orders (see ``TIE_BREAK`` above).  The
    kernel itself now pads any batch to a power-of-two (rows, 128) tile
    internally (the Mosaic-ready 2-D layout), but non-power-of-two batches
    still take the argsort path here so their tie-break stays pinned to
    the documented xla contract.
    """
    from repro.kernels import dispatch

    n = tau.shape[0]
    if dispatch.resolve(backend) != "xla" and n > 1 and n & (n - 1) == 0:
        from repro.kernels.scalegate_merge.ops import scalegate_merge_op
        order, _, _ = scalegate_merge_op(tau, source, valid,
                                         n_sources=n_sources, backend=backend)
        return order
    return _stable_order(tau, source, valid)


def push(state: ScaleGateState, incoming: T.TupleBatch, *,
         backend: str = None,
         wstate: wm.WatermarkState = None) -> Tuple[ScaleGateState, T.TupleBatch]:
    """Merge a tick of per-source tuples; emit the ready prefix.

    The emitted batch has static size ``capacity + incoming.batch`` with a
    validity mask selecting the ready tuples (sorted, exactly-once).
    ``backend`` selects the merge-sort realization (see ``merge_order``);
    the per-source watermark frontiers are stateful and always tracked here.

    ``wstate`` overrides the implicit per-tuple frontier fold with an
    externally computed ``WatermarkState`` — the hierarchical root merge
    (repro.ingest.root) gates on *explicitly reported* per-leaf watermarks
    (``wm.observe_explicit``) because its incoming tuples keep their
    original source ids for the downstream pipeline while the root's
    frontier axis is the leaf set.
    """
    cap = state.capacity
    combined = T.concat(state.stash, incoming)

    # addTuple: fold the new arrivals into the per-source frontiers.
    if wstate is None:
        wstate = wm.observe(state.wmark, incoming.source, incoming.tau,
                            incoming.valid)
    w = wstate.value()

    order = merge_order(combined.tau, combined.source, combined.valid,
                        state.wmark.n_sources, backend)
    merged = T.take(combined, order)

    ready = merged.valid & (merged.tau <= w)
    out = dataclasses.replace(merged, valid=ready)

    # Stash = the non-ready survivors, compacted to the front of the buffer.
    keep = merged.valid & ~ready
    # order: kept lanes first (stable, so timestamp order is preserved).
    keep_order = jnp.argsort(~keep, stable=True)
    n_keep = jnp.sum(keep.astype(jnp.int32))
    lanes = jnp.arange(cap)
    stash = T.take(merged, keep_order[:cap], fill_invalid=lanes >= n_keep)
    dropped = jnp.maximum(n_keep - cap, 0)

    new_state = ScaleGateState(
        stash=stash, wmark=wstate, overflow=state.overflow + dropped)
    return new_state, out


def push_stacked(state: ScaleGateState, stacked: T.TupleBatch,
                 reports: jax.Array, rmask: jax.Array, *,
                 backend: str = None) -> Tuple[ScaleGateState, T.TupleBatch]:
    """Fused root merge: one kernel call over stacked per-leaf chunk rows.

    ``stacked`` is a TupleBatch whose fields carry a leading ``[rows, C]``
    layout (each row one padded ready chunk from a leaf, rows in leaf
    order); ``reports``/``rmask`` are the per-leaf reported watermarks and
    report mask of this round.  The frontier fold, the Definition-3
    reduction and the merge all happen inside one traced program
    (``wm.fold_reports`` + ``scalegate_merge_stacked``), so the steady
    state round never syncs to host.  Requires ``capacity % C == 0`` so the
    stash prepends as whole rows.

    Emission order: ``(tau, arrival)`` with arrival = stash lanes first,
    then leaf rows in order — a valid ScaleGate total order under either
    TIE_BREAK contract (the ready *set* and tau grouping match ``push``
    exactly; only the order among equal-tau tuples may differ from the flat
    xla path's ``(tau, source, arrival)``).
    """
    from repro.kernels.scalegate_merge.ops import scalegate_merge_stacked_op

    cap = state.capacity
    rows, c = stacked.tau.shape
    assert cap % c == 0, (cap, c)

    wstate, eff, w = wm.fold_reports(state.wmark, reports, rmask)

    incoming = jax.tree.map(
        lambda a: a.reshape((rows * c,) + a.shape[2:]), stacked)
    combined = T.concat(state.stash, incoming)
    n = combined.batch
    order2, _, _ = scalegate_merge_stacked_op(
        combined.tau.reshape(n // c, c), combined.source.reshape(n // c, c),
        combined.valid.reshape(n // c, c).astype(jnp.int32), eff,
        backend=backend)
    merged = T.take(combined, order2.reshape(-1))

    ready = merged.valid & (merged.tau <= w)
    out = dataclasses.replace(merged, valid=ready)

    keep = merged.valid & ~ready
    keep_order = jnp.argsort(~keep, stable=True)
    n_keep = jnp.sum(keep.astype(jnp.int32))
    lanes = jnp.arange(cap)
    stash = T.take(merged, keep_order[:cap], fill_invalid=lanes >= n_keep)
    dropped = jnp.maximum(n_keep - cap, 0)

    new_state = ScaleGateState(
        stash=stash, wmark=wstate, overflow=state.overflow + dropped)
    return new_state, out


def add_sources(state: ScaleGateState, mask: jax.Array, gamma) -> ScaleGateState:
    """ESG addSources — Lemma 3: start the new frontier at gamma."""
    return dataclasses.replace(state, wmark=wm.add_sources(state.wmark, mask, gamma))


def remove_sources(state: ScaleGateState, mask: jax.Array) -> ScaleGateState:
    """ESG removeSources — flush semantics of §6."""
    return dataclasses.replace(state, wmark=wm.remove_sources(state.wmark, mask))


# ------------------------------------------------- checkpoint export/import --
_STASH_FIELDS = tuple(f.name for f in dataclasses.fields(T.TupleBatch))


def export_np(state: ScaleGateState) -> dict:
    """Host-side snapshot of a gate (stash + frontier + overflow) as a dict
    of plain numpy arrays: a checkpointable pytree that is also picklable
    across process-worker channels."""
    import numpy as np
    return {
        "stash": {f: np.asarray(getattr(state.stash, f))
                  for f in _STASH_FIELDS},
        "wmark": wm.export_np(state.wmark),
        "overflow": np.asarray(state.overflow),
    }


def import_np(d: dict) -> ScaleGateState:
    return ScaleGateState(
        stash=T.TupleBatch(**{f: jnp.asarray(d["stash"][f])
                              for f in _STASH_FIELDS}),
        wmark=wm.import_np(d["wmark"]),
        overflow=jnp.asarray(d["overflow"], jnp.int32),
    )


def template_np(n_sources: int, capacity: int, kmax: int,
                payload_width: int) -> dict:
    """Zero-filled ``export_np``-shaped dict: the restore ``like`` template
    for a gate with these dimensions."""
    return export_np(init_scalegate(n_sources, capacity, kmax,
                                    payload_width))
