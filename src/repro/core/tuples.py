"""Tuple batches — the unit of data in the STRETCH runtime.

The paper processes one tuple at a time; a TPU runtime processes *batches* of
tuples per tick.  A ``TupleBatch`` is a structure-of-arrays view of ``B``
tuples ``<tau, ..., [phi[1], phi[2], ...]>`` (paper §2.1):

  * ``tau``     — event time in integer ``delta`` ticks (delta = 1 ms, as Flink).
  * ``keys``    — the *multi-key set* ``f_MK(t)`` (Definition 4), fixed width
                  ``KMAX`` with ``-1`` padding.  A single-key operator uses
                  ``KMAX == 1`` (``f_SK``, §2.1).
  * ``payload`` — dense float payload ``phi`` (schema flattened by the config).
  * ``source``  — index of the upstream physical stream (``0..I-1``).
  * ``valid``   — batch-lane occupancy (ticks are fixed-size; short ticks pad).
  * ``is_control`` / ``ctrl_epoch`` — the control-tuple lane used by the
                  elasticity protocol (§7, Alg. 5-6).  Control tuples are never
                  processed as data (``isControl``, Alg. 4 L13).

All fields are JAX arrays so a batch can live sharded on a mesh; the batch is
a registered pytree and can flow through jit/shard_map/scan.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NO_KEY = -1  # padding value inside the multi-key set


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TupleBatch:
    tau: jax.Array          # i32[B]
    keys: jax.Array         # i32[B, KMAX]
    payload: jax.Array      # f32[B, P]
    source: jax.Array       # i32[B]
    valid: jax.Array        # bool[B]
    is_control: jax.Array   # bool[B]
    ctrl_epoch: jax.Array   # i32[B]

    @property
    def batch(self) -> int:
        return self.tau.shape[0]

    @property
    def kmax(self) -> int:
        return self.keys.shape[1]

    @property
    def payload_width(self) -> int:
        return self.payload.shape[1]

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def make_batch(
    tau,
    payload,
    keys=None,
    source=None,
    valid=None,
    is_control=None,
    ctrl_epoch=None,
    kmax: int = 1,
) -> TupleBatch:
    """Build a TupleBatch from plain arrays, filling defaults."""
    tau = jnp.asarray(tau, jnp.int32)
    b = tau.shape[0]
    payload = jnp.asarray(payload, jnp.float32)
    if payload.ndim == 1:
        payload = payload[:, None]
    if keys is None:
        keys = jnp.full((b, kmax), NO_KEY, jnp.int32)
    else:
        keys = jnp.asarray(keys, jnp.int32)
        if keys.ndim == 1:
            keys = keys[:, None]
    if source is None:
        source = jnp.zeros((b,), jnp.int32)
    else:
        source = jnp.asarray(source, jnp.int32)
    if valid is None:
        valid = jnp.ones((b,), bool)
    else:
        valid = jnp.asarray(valid, bool)
    if is_control is None:
        is_control = jnp.zeros((b,), bool)
    else:
        is_control = jnp.asarray(is_control, bool)
    if ctrl_epoch is None:
        ctrl_epoch = jnp.zeros((b,), jnp.int32)
    else:
        ctrl_epoch = jnp.asarray(ctrl_epoch, jnp.int32)
    return TupleBatch(tau=tau, keys=keys, payload=payload, source=source,
                      valid=valid, is_control=is_control, ctrl_epoch=ctrl_epoch)


def empty_batch(b: int, kmax: int, payload_width: int) -> TupleBatch:
    return TupleBatch(
        tau=jnp.zeros((b,), jnp.int32),
        keys=jnp.full((b, kmax), NO_KEY, jnp.int32),
        payload=jnp.zeros((b, payload_width), jnp.float32),
        source=jnp.zeros((b,), jnp.int32),
        valid=jnp.zeros((b,), bool),
        is_control=jnp.zeros((b,), bool),
        ctrl_epoch=jnp.zeros((b,), jnp.int32),
    )


def concat(a: TupleBatch, b: TupleBatch) -> TupleBatch:
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def take(batch: TupleBatch, idx: jax.Array, fill_invalid: Optional[jax.Array] = None) -> TupleBatch:
    """Gather lanes ``idx``; lanes where ``fill_invalid`` is True are invalidated."""
    out = jax.tree.map(lambda x: x[idx], batch)
    if fill_invalid is not None:
        out = dataclasses.replace(out, valid=out.valid & ~fill_invalid)
    return out
