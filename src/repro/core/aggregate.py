"""Aggregates: ``A`` and the multi-key ``A+`` (paper §2.1, §4, Appendix D).

``A(WA, WS, 1, f_SK, WT, S, f_A, f_R)`` is instantiated on ``O+`` per
Theorem 2 (I=1, ``f_A -> f_O``, ``f_R -> f_S``/``f_U``).  ``A+`` replaces
``f_SK`` with ``f_MK`` (Definition 5) — in our runtime that is simply
``KMAX > 1`` key sets in the tuple batch, so A and A+ share code; this *is*
the paper's point that O+ unifies them.

Shipped instances (Appendix D):
  * ``count_aggregate``     — Operator 4/5: wordcount / paircount counters.
  * ``longest_aggregate``   — Operator 1/2: longest tweet per hashtag
                              (the §1 running example, traced in Appendix E).
  * ``reduce_aggregate``    — generic commutative-monoid f_R.

``tick_fast`` is the TPU fast path for commutative reducers: the whole ready
batch is scattered into (key, window-slot) cells at once instead of scanning
tuple-by-tuple — valid because the reducer is commutative and because a ready
tuple can never land in a window its own timestamp has expired (Lemma 1
argument, DESIGN.md §5).  Slot-ring slack (``extra_slots``) absorbs the
window generations spanned by one tick; an overrun is *counted*, never
silent.  ``tests/test_aggregate.py`` pins tick_fast == tick (general path).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.operator import (UNSET_L, OperatorDef, OpState, Outputs,
                                 _emit, _empty_outputs, _expire_all)
from repro.core.windows import MULTI, SINGLE, WindowSpec


def reduce_aggregate(window: WindowSpec, k_virt: int, *, width: int = 1,
                     f_r: Callable, init_val: float, emit_key: bool = True,
                     out_cap: int = 256, extra_slots: int = 0,
                     n_inputs: int = 1,
                     name: str = "aggregate") -> OperatorDef:
    """A/A+ with an incremental reducer f_R and expiry output f_A.

    zeta: {"acc": f32[K, slots, width]}; f_O emits ``[key, acc...]``.
    """

    def init_zeta():
        slots = window.n_slots + extra_slots
        return {"acc": jnp.full((k_virt, slots, width), init_val, jnp.float32)}

    def f_u(zeta_s, tup, win_l, mask):
        acc = f_r(zeta_s["acc"], tup.payload)          # [K, width]
        k = zeta_s["acc"].shape[0]
        return ({"acc": acc},
                jnp.zeros((k, width + 1), jnp.float32),
                jnp.zeros((k,), bool))

    def f_o(zeta_s, win_l, key_ids):
        if emit_key:
            payload = jnp.concatenate(
                [key_ids[:, None].astype(jnp.float32), zeta_s["acc"]], axis=-1)
        else:
            payload = zeta_s["acc"]
        return payload, jnp.ones((key_ids.shape[0],), bool)

    def f_s(zeta_s, new_left):
        k = zeta_s["acc"].shape[0]
        return ({"acc": jnp.full_like(zeta_s["acc"], init_val)},
                jnp.zeros((k,), bool))

    return OperatorDef(window=window, n_inputs=n_inputs, k_virt=k_virt,
                       payload_out=width + (1 if emit_key else 0),
                       init_zeta=init_zeta, f_u=f_u, f_o=f_o, f_s=f_s,
                       out_cap=out_cap, extra_slots=extra_slots, name=name)


def count_aggregate(window: WindowSpec, k_virt: int, **kw) -> OperatorDef:
    """Operator 4/5: per-key tuple count (wordcount / paircount)."""
    return reduce_aggregate(window, k_virt, width=1,
                            f_r=lambda acc, payload: acc + 1.0,
                            init_val=0.0, name=kw.pop("name", "count"), **kw)


def longest_aggregate(window: WindowSpec, k_virt: int, **kw) -> OperatorDef:
    """Operator 1/2: longest tweet per hashtag — payload[0] = length(phi)."""
    return reduce_aggregate(window, k_virt, width=1,
                            f_r=lambda acc, payload: jnp.maximum(acc, payload[..., :1]),
                            init_val=0.0, name=kw.pop("name", "longest"), **kw)


# ---------------------------------------------------------------------------
# Vectorized fast path (commutative reducers)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FastAggState:
    op_state: OpState
    slot_l: jax.Array      # i32[slots] window generation currently in each slot
    collisions: jax.Array  # i32[] ring overruns in the LAST tick (delta)


def fast_init(op: OperatorDef) -> FastAggState:
    return FastAggState(op_state=op.init_state(),
                        slot_l=jnp.arange(op.slots, dtype=jnp.int32),
                        collisions=jnp.zeros((), jnp.int32))


def _segment_tile_k(k: int) -> int:
    """Largest MXU-friendly tile that divides K (the kernel asserts K % tile).

    The hit-block axis needs no shim here: ``segment_aggregate`` lane-pads
    N to a multiple of 128 internally (dead -1 keys, zero values), so the
    concatenated (slot-generation x key-column) hit vectors below can have
    any length on any backend."""
    return 128 if k % 128 == 0 else k


def _scatter_reduce(op: OperatorDef, kind: str, acc, ready: T.TupleBatch,
                    resp: jax.Array, next_l, backend: str = None,
                    key_offset=0):
    """Scatter the whole tick into (key, slot) cells: the paper's per-tuple
    f_R loop becomes one segment-reduce, executed by the dispatched
    ``segment_aggregate`` kernel for additive reducers (count/sum; ``xla``
    resolves to the jnp scatter-add oracle, the Pallas backends to the
    one-hot matmul kernel).  ``max`` is not additive and keeps the scatter.

    ``f_MK`` returns a key *set* (Definition 4): a key repeated inside one
    tuple's KMAX-padded key array contributes exactly once, matching the
    general path's union of one-hots — earlier-column duplicates are masked.

    ``key_offset`` maps global tuple keys into the local row block
    ``[key_offset, key_offset + k_virt)`` (mesh owner-computes layout);
    out-of-block keys are dropped like NO_KEY.  Returns the extra mask
    ``m_any`` (key hits irrespective of ``resp``) used for bookkeeping that
    must stay identical across instances/shards (slot_l).
    """
    ws = op.window
    live = ready.valid & ~ready.is_control
    l_min = jnp.maximum(ws.earliest_win_l(ready.tau), next_l)
    l_max = ws.latest_win_l(ready.tau)
    if ws.wt == SINGLE:
        l_max = l_min
    dup_cols = []   # per kk: same key already seen in an earlier column
    for kk in range(ready.kmax):
        dup = jnp.zeros((ready.batch,), bool)
        for kk2 in range(kk):
            dup = dup | (ready.keys[:, kk2] == ready.keys[:, kk])
        dup_cols.append(dup)
    hits_l = []
    hits_k = []
    hits_m = []
    hits_any = []
    for d in range(ws.n_slots if ws.wt == MULTI else 1):
        l = l_min + d
        in_range = (l <= l_max) & live
        for kk in range(ready.kmax):
            key = ready.keys[:, kk] - key_offset
            in_block = (ready.keys[:, kk] >= 0) & (key >= 0) & \
                (key < op.k_virt) & ~dup_cols[kk]
            k_safe = jnp.clip(key, 0, op.k_virt - 1)
            hits_l.append(l)
            hits_k.append(k_safe)
            hits_m.append(in_range & in_block & resp[k_safe])
            # slot-grid bookkeeping mask: a live tuple marks its window
            # generations regardless of key/resp/block, so the value is
            # identical on every instance and every mesh shard.
            hits_any.append(in_range)
    l = jnp.concatenate(hits_l)
    k = jnp.concatenate(hits_k)
    m = jnp.concatenate(hits_m)
    m_any = jnp.concatenate(hits_any)
    s = op.slot_of(l)
    if kind == "max":
        val = jnp.tile(ready.payload[:, :1], (l.shape[0] // ready.batch, 1))
        acc = acc.at[k, s].max(jnp.where(m[:, None], val, -jnp.inf), mode="drop")
    else:
        from repro.kernels.segment_aggregate.ops import segment_aggregate_op
        if kind == "count":
            val = jnp.ones((l.shape[0], 1), jnp.float32)
        else:  # "sum"
            val = jnp.tile(ready.payload[:, :acc.shape[-1]],
                           (l.shape[0] // ready.batch, 1))
        acc = segment_aggregate_op(
            jnp.where(m, k, -1), s, jnp.where(m[:, None], val, 0.0), acc,
            tile_k=_segment_tile_k(acc.shape[0]), backend=backend)
    return acc, k, s, l, m, m_any


def tick_fast(op: OperatorDef, kind: str, st: FastAggState,
              ready: T.TupleBatch, resp: jax.Array, *,
              backend: str = None,
              key_offset=0) -> Tuple[FastAggState, Outputs]:
    """Whole-tick scatter update, then expiry (order-free for commutative f_R).

    ``key_offset`` runs the tick on a local key block (mesh layout, see
    ``_scatter_reduce``); emitted key ids stay global.
    """
    op = op.resolved()
    ops = st.op_state
    live = ready.valid & ~ready.is_control
    any_live = jnp.any(live)
    w_end = jnp.maximum(ops.watermark,
                        jnp.max(jnp.where(live, ready.tau, 0)))
    # first contact resolves the window frontier (cf. operator.process_tuple)
    first_tau = jnp.min(jnp.where(live, ready.tau, jnp.iinfo(jnp.int32).max))
    next_l = jnp.where((ops.next_l == UNSET_L) & any_live,
                       op.window.earliest_win_l(first_tau), ops.next_l)
    ops = dataclasses.replace(ops, next_l=next_l)

    acc, k_idx, s_idx, l_idx, m_idx, m_any = _scatter_reduce(
        op, kind, ops.zeta["acc"], ready, resp, ops.next_l, backend,
        key_offset)

    # Ring-overrun detection: the live window generations spanned by this
    # tick must fit the physical slot ring, else two generations alias one
    # slot (the counted-not-silent contract; pick extra_slots >= tick
    # tau-span / WA to stay clean).
    latest = jnp.max(jnp.where(live, op.window.latest_win_l(ready.tau),
                               ops.next_l))
    span = latest - ops.next_l + 1
    coll = jnp.maximum(span - op.slots, 0) * any_live.astype(jnp.int32)
    occ = ops.occupied
    occ = occ.at[k_idx, s_idx].max(m_idx, mode="drop")
    # slot_l tracks which window generation owns each ring slot — a global
    # property of the window grid, so the update mask ignores keys, resp
    # and the local block entirely (m_any = lane-in-range only): every
    # instance/shard computes the identical value (replication-safe on the
    # mesh, and the disjoint-writer max-merge is unchanged on one host).
    slot_l = st.slot_l.at[s_idx].set(jnp.where(m_any, l_idx, st.slot_l[s_idx]),
                                     mode="drop")

    ops = dataclasses.replace(ops, zeta={"acc": acc}, occupied=occ,
                              watermark=w_end)
    outs = _empty_outputs(op.out_cap, op.payload_out)
    ops, outs = _expire_all(op, ops, outs, w_end, resp,
                            key_offset + jnp.arange(op.k_virt))
    return (FastAggState(op_state=ops, slot_l=slot_l,
                         collisions=coll), outs)
