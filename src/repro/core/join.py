"""Joins: ``J``/``J+`` and ScaleJoin (paper §2.1, §4, Appendix D Operator 3).

``J(WA, WS, 2, f_SK, WT, S, f_J)`` matches pairs of tuples, one per input
stream, falling in same-boundary window instances of the same key
(Definition 1).  ScaleJoin is the ``J+`` used throughout the evaluation
(Q3-Q6): ``f_MK`` returns *all* ``K`` virtual keys, every instance counts
every tuple, each tuple is *stored* round-robin under exactly one key
(``c % K``), and each instance compares incoming tuples against the tuples
stored under its keys — disjoint-parallel and skew-resilient.

Two execution paths:
  * the general ``operator.tick`` scan path (Operator 3 transcribed into the
    vectorized f_U contract) — the semantic oracle;
  * ``tick_fast`` — blocked whole-tick compare: incoming-block x stored-ring
    plus the in-block cross-stream upper triangle, exactly once per pair.
    ``kernels/window_join`` is its Pallas twin for the intra-chip domain.

``f_J`` is a vectorized predicate ``f(payload_L[..., PL], payload_R[..., PR])
-> bool[...]``; ``band_predicate`` builds the Q3 benchmark predicate and
``hedge_predicate`` the Q6 NYSE one.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import tuples as T
from repro.core.operator import (OperatorDef, Outputs, Tup, _emit,
                                 _empty_outputs)
from repro.core.windows import SINGLE, WindowSpec


def band_predicate(width: float = 10.0, attrs: int = 2) -> Callable:
    """Q3 predicate: |phi_L[i] - phi_R[i]| <= width for the first ``attrs``."""
    def f_j(pl, pr):
        d = jnp.abs(pl[..., :attrs] - pr[..., :attrs])
        return jnp.all(d <= width, axis=-1)
    return f_j


def hedge_predicate(lo: float = -1.05, hi: float = -0.95) -> Callable:
    """Q6 NYSE predicate on payload ``[id, nd]`` (nd precomputed at ingress):
    different company and ND_R / ND_L in [lo, hi] (negative correlation)."""
    def f_j(pl, pr):
        ratio = pr[..., 1] / jnp.where(pl[..., 1] == 0, 1e-9, pl[..., 1])
        return (pl[..., 0] != pr[..., 0]) & (ratio >= lo) & (ratio <= hi)
    return f_j


def _directed(f_j, pay_new, src_new, pay_stored):
    """Apply f_J with stream-consistent argument order (L first)."""
    lr = f_j(pay_new, pay_stored)   # new is L, stored is R
    rl = f_j(pay_stored, pay_new)   # stored is L, new is R
    return jnp.where(src_new == 0, lr, rl)


def scalejoin_def(window: WindowSpec, k_virt: int, f_j: Callable, *,
                  payload_width: int, ring: int, out_cap: int = 256,
                  name: str = "scalejoin") -> OperatorDef:
    """Operator 3 on the general O+ path (WT=single, WA=delta, I=2).

    zeta per key: tuple ring (tau/payload/stream), per-key store cursor n,
    and the global round-robin counter c (replicated per key — every key
    counts every tuple, Operator 3 L10-11).
    """
    if window.wt != SINGLE:
        raise ValueError("ScaleJoin uses WT=single")

    def init_zeta():
        return {
            "tau": jnp.full((k_virt, 1, ring), -1, jnp.int32),
            "pay": jnp.zeros((k_virt, 1, ring, payload_width), jnp.float32),
            "stream": jnp.zeros((k_virt, 1, ring), jnp.int32),
            "n": jnp.zeros((k_virt, 1), jnp.int32),     # per-key store cursor
            "c": jnp.zeros((k_virt, 1), jnp.int32),     # global tuple counter
        }

    def f_u(zeta_s, tup: Tup, win_l, mask):
        # zeta_s leaves are slot-sliced: tau/pay/stream [K, ring(,P)], n/c [K]
        k = zeta_s["tau"].shape[0]
        key_ids = jnp.arange(k)
        # purge stale opposite tuples (Operator 3 L18-19)
        fresh = zeta_s["tau"] + window.ws >= tup.tau
        live = (zeta_s["tau"] >= 0) & fresh
        tau = jnp.where(live, zeta_s["tau"], -1)
        # match against opposite-stream stored tuples (L20-21)
        opp = live & (zeta_s["stream"] != tup.source)
        hit = opp & _directed(f_j, tup.payload, tup.source, zeta_s["pay"])
        out_pay = jnp.concatenate([
            jnp.broadcast_to(tup.payload, (k, ring, tup.payload.shape[-1])),
            zeta_s["pay"]], axis=-1)
        # store round-robin: the key with c % K == k stores t (L22-23)
        store = (jnp.mod(zeta_s["c"], k_virt) == key_ids)
        pos = jnp.mod(zeta_s["n"], ring)
        new = {
            "tau": tau.at[key_ids, pos].set(
                jnp.where(store, tup.tau, tau[key_ids, pos])),
            "pay": zeta_s["pay"].at[key_ids, pos].set(
                jnp.where(store[:, None], tup.payload,
                          zeta_s["pay"][key_ids, pos])),
            "stream": zeta_s["stream"].at[key_ids, pos].set(
                jnp.where(store, tup.source, zeta_s["stream"][key_ids, pos])),
            "n": zeta_s["n"] + store.astype(jnp.int32),
            "c": zeta_s["c"] + 1,
        }
        return new, out_pay, hit

    return OperatorDef(window=window, n_inputs=2, k_virt=k_virt,
                       payload_out=2 * payload_width, init_zeta=init_zeta,
                       f_u=f_u, f_o=None, f_s=None, out_cap=out_cap,
                       lazy_expiry=True, name=name)


def band_join_counts(st: "FastJoinState", ready: T.TupleBatch,
                     window: WindowSpec, *, band: float = 10.0,
                     n_attrs: int = 2, backend: str = None):
    """Counting-only band-join tick via the dispatched ``window_join`` kernel.

    The Pallas twin of ``tick_fast`` phase 1 under full responsibility
    (every key row live): per-incoming-tuple match counts against the stored
    rings plus the live-comparison total — the Q3/Q6 throughput accounting
    path, with the backend (``xla`` ref oracle on CPU, Pallas on TPU) picked
    by the kernel dispatcher.  Returns ``(counts i32[B, K], comparisons)``.

    The kernel has no validity input, so invalid/control lanes (the padding
    of a static ScaleGate batch) are neutralized by pushing their tau past
    every stored tuple's freshness horizon — they match nothing and count
    no comparisons, same as ``tick_fast``'s ``live_in`` mask.  The kernel
    applies the identical trick to sublane-align the incoming block (B is
    padded to a multiple of 8 with INF_TIME lanes), so any ready-batch
    size dispatches cleanly on every backend.
    """
    from repro.core.watermark import INF_TIME
    from repro.kernels.window_join.ops import window_join_op

    live = ready.valid & ~ready.is_control
    tau = jnp.where(live, ready.tau, INF_TIME)
    return window_join_op(tau, ready.source, ready.payload,
                          st.tau, st.stream, st.pay, ws=window.ws,
                          band=band, n_attrs=n_attrs, backend=backend)


# ---------------------------------------------------------------------------
# Blocked fast path (the TPU execution; kernels/window_join is its twin)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FastJoinState:
    tau: jax.Array      # i32[K, R] stored event times (-1 = empty)
    pay: jax.Array      # f32[K, R, P]
    stream: jax.Array   # i32[K, R]
    n: jax.Array        # i32[K] per-key store cursor
    c: jax.Array        # i32[] global round-robin tuple counter
    comparisons: jax.Array  # f32[] comparisons in the LAST tick (per-tick
    #                         delta: cumulative sums break under the
    #                         per-instance vmap + disjoint-writer merge)


def fast_join_init(k_virt: int, ring: int, payload_width: int) -> FastJoinState:
    return FastJoinState(
        tau=jnp.full((k_virt, ring), -1, jnp.int32),
        pay=jnp.zeros((k_virt, ring, payload_width), jnp.float32),
        stream=jnp.zeros((k_virt, ring), jnp.int32),
        n=jnp.zeros((k_virt,), jnp.int32),
        c=jnp.zeros((), jnp.int32),
        comparisons=jnp.zeros((), jnp.float32),
    )


def tick_fast(window: WindowSpec, f_j: Callable, st: FastJoinState,
              ready: T.TupleBatch, resp: jax.Array, out_cap: int,
              emit: bool = True, k_global: int = None,
              k_offset=0) -> Tuple[FastJoinState, Outputs]:
    """Whole-tick ScaleJoin: block-compare + in-block triangle + scatter store.

    Two layouts:
      * monolithic (default): ``st`` holds all K_virt rows, ``resp`` masks
        this instance's responsibility (reference executor).
      * sliced (``k_global``/``k_offset`` set): ``st`` holds only this
        instance's contiguous row block — the owner-computes layout of
        vsn.shard_tick, where work partitions perfectly (each pair compared
        by exactly one instance, zero duplicated compute).

    Requires ``ready.batch <= k_global`` (one store row per tuple per tick).
    """
    k_virt, ring = st.tau.shape
    kg = k_global if k_global is not None else k_virt
    b = ready.batch
    p = ready.payload.shape[-1]
    assert b <= kg, "fast path stores at most one tuple per key per tick"
    live_in = ready.valid & ~ready.is_control

    rank = jnp.cumsum(live_in.astype(jnp.int32)) - live_in.astype(jnp.int32)
    store_key_g = jnp.mod(st.c + rank, kg)             # global key ids
    in_slice = (store_key_g >= k_offset) & (store_key_g < k_offset + k_virt)
    store_key = jnp.clip(store_key_g - k_offset, 0, k_virt - 1)

    # --- phase 1: incoming block vs stored rings (resp rows only) ---------
    fresh = (st.tau[None] + window.ws >= ready.tau[:, None, None])
    stored_live = (st.tau[None] >= 0) & fresh          # [B, K, R]
    opp = stored_live & (st.stream[None] != ready.source[:, None, None])
    pred = _directed(f_j, ready.payload[:, None, None, :],
                     ready.source[:, None, None], st.pay[None])
    hit1 = opp & pred & resp[None, :, None] & live_in[:, None, None]
    comps1 = jnp.sum((opp & resp[None, :, None] &
                      live_in[:, None, None]).astype(jnp.float32))

    # --- phase 2: in-block cross-stream upper triangle ---------------------
    ii = jnp.arange(b)
    earlier = ii[None, :] < ii[:, None]                # j earlier than i
    cross = ready.source[:, None] != ready.source[None, :]
    within = ready.tau[:, None] - ready.tau[None, :] <= window.ws
    pred2 = _directed(f_j, ready.payload[:, None, :],
                      ready.source[:, None], ready.payload[None])
    owner = resp[store_key] & in_slice                 # owner of earlier tuple
    hit2 = (earlier & cross & within & pred2 & owner[None, :] &
            live_in[:, None] & live_in[None, :])
    comps2 = jnp.sum((earlier & cross & owner[None, :] & live_in[:, None] &
                      live_in[None, :]).astype(jnp.float32))

    # --- outputs ------------------------------------------------------------
    outs = _empty_outputs(out_cap, 2 * p)
    if emit:
        # Observation 1: output tau = right boundary = incoming tau + WA.
        pay1 = jnp.concatenate(
            [jnp.broadcast_to(ready.payload[:, None, None, :],
                              (b, k_virt, ring, p)),
             jnp.broadcast_to(st.pay[None], (b, k_virt, ring, p))], axis=-1)
        tau1 = jnp.broadcast_to((ready.tau + window.wa)[:, None, None],
                                (b, k_virt, ring))
        outs = _emit(outs, tau1.reshape(-1),
                     pay1.reshape(-1, 2 * p), hit1.reshape(-1))
        pay2 = jnp.concatenate(
            [jnp.broadcast_to(ready.payload[:, None, :], (b, b, p)),
             jnp.broadcast_to(ready.payload[None], (b, b, p))], axis=-1)
        tau2 = jnp.broadcast_to((ready.tau + window.wa)[:, None], (b, b))
        outs = _emit(outs, tau2.reshape(-1),
                     pay2.reshape(-1, 2 * p), hit2.reshape(-1))

    # --- phase 3: store (round-robin, one key per tuple) -------------------
    pos = jnp.mod(st.n[store_key] + 0, ring)
    row = jnp.where(live_in & in_slice, store_key, k_virt)  # drop others
    st = FastJoinState(
        tau=st.tau.at[row, pos].set(ready.tau, mode="drop"),
        pay=st.pay.at[row, pos].set(ready.payload, mode="drop"),
        stream=st.stream.at[row, pos].set(ready.source, mode="drop"),
        n=st.n.at[row].add(1, mode="drop"),
        c=st.c + jnp.sum(live_in.astype(jnp.int32)),
        comparisons=comps1 + comps2,
    )
    return st, outs
