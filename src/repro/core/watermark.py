"""Watermark tracking (paper §2.3, Definitions 2-3).

Two modes, both supported:

* **implicit** — every physical input stream is timestamp-sorted; the
  watermark of a merge point is ``min_i max_m tau_i^m`` (Definition 3), i.e.
  the minimum over sources of the latest timestamp seen from that source.
  Implicit watermarks additionally establish a *total order* on the merged
  stream, enabling order-sensitive analysis (ScaleJoin).
* **explicit** — sources periodically emit watermark values (carried here as
  tuple metadata); the merge point keeps the latest per source and takes the
  min.

Both reduce to the same state: ``per_source_frontier[i]`` plus
``W = min_i frontier[i]``.  Sources that are *removed* (ESG
``removeSources``) are flushed by setting their frontier to ``+inf`` so they
never hold the watermark back (§6 "Removing existing sources"); sources that
are *added* start at the safe lower bound ``gamma`` of Lemma 3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INF_TIME = jnp.iinfo(jnp.int32).max


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WatermarkState:
    frontier: jax.Array      # i32[n_sources] latest tau (or explicit wm) per source
    active: jax.Array        # bool[n_sources] source membership (ESG sources set)

    @property
    def n_sources(self) -> int:
        return self.frontier.shape[0]

    def value(self) -> jax.Array:
        """W = min over *active* sources of their frontier (Definition 3)."""
        eff = jnp.where(self.active, self.frontier, INF_TIME)
        return jnp.min(eff)


def init_watermark(n_sources: int, active=None) -> WatermarkState:
    if active is None:
        active = jnp.ones((n_sources,), bool)
    return WatermarkState(
        frontier=jnp.zeros((n_sources,), jnp.int32),
        active=jnp.asarray(active, bool),
    )


def observe(state: WatermarkState, source: jax.Array, tau: jax.Array,
            valid: jax.Array) -> WatermarkState:
    """Fold a batch of (source, tau) observations into the frontier.

    Frontiers only move forward (watermarks are non-decreasing, §2.3).
    """
    upd = jnp.where(valid, tau, -1)
    new_frontier = state.frontier.at[source].max(upd, mode="drop")
    return dataclasses.replace(state, frontier=new_frontier)


def add_sources(state: WatermarkState, mask: jax.Array, gamma) -> WatermarkState:
    """ESG ``addSources``: new sources start at the Lemma-3 safe bound gamma."""
    frontier = jnp.where(mask & ~state.active,
                         jnp.asarray(gamma, jnp.int32), state.frontier)
    return WatermarkState(frontier=frontier, active=state.active | mask)


def remove_sources(state: WatermarkState, mask: jax.Array) -> WatermarkState:
    """ESG ``removeSources``: flush — the leaving source stops gating W."""
    return dataclasses.replace(state, active=state.active & ~mask)
