"""Watermark tracking (paper §2.3, Definitions 2-3).

Two modes, both supported:

* **implicit** — every physical input stream is timestamp-sorted; the
  watermark of a merge point is ``min_i max_m tau_i^m`` (Definition 3), i.e.
  the minimum over sources of the latest timestamp seen from that source.
  Implicit watermarks additionally establish a *total order* on the merged
  stream, enabling order-sensitive analysis (ScaleJoin).
* **explicit** — sources periodically emit watermark values (carried here as
  tuple metadata); the merge point keeps the latest per source and takes the
  min.

Both reduce to the same state: ``per_source_frontier[i]`` plus
``W = min_i frontier[i]``.  Sources that are *removed* (ESG
``removeSources``) are flushed by setting their frontier to ``+inf`` so they
never hold the watermark back (§6 "Removing existing sources"); sources that
are *added* start at the safe lower bound ``gamma`` of Lemma 3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INF_TIME = jnp.iinfo(jnp.int32).max


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WatermarkState:
    frontier: jax.Array      # i32[n_sources] latest tau (or explicit wm) per source
    active: jax.Array        # bool[n_sources] source membership (ESG sources set)

    @property
    def n_sources(self) -> int:
        return self.frontier.shape[0]

    def value(self) -> jax.Array:
        """W = min over *active* sources of their frontier (Definition 3)."""
        eff = jnp.where(self.active, self.frontier, INF_TIME)
        return jnp.min(eff)


def init_watermark(n_sources: int, active=None) -> WatermarkState:
    if active is None:
        active = jnp.ones((n_sources,), bool)
    return WatermarkState(
        frontier=jnp.zeros((n_sources,), jnp.int32),
        active=jnp.asarray(active, bool),
    )


def observe(state: WatermarkState, source: jax.Array, tau: jax.Array,
            valid: jax.Array) -> WatermarkState:
    """Fold a batch of (source, tau) observations into the frontier.

    Frontiers only move forward (watermarks are non-decreasing, §2.3).
    """
    upd = jnp.where(valid, tau, -1)
    new_frontier = state.frontier.at[source].max(upd, mode="drop")
    return dataclasses.replace(state, frontier=new_frontier)


def observe_explicit(state: WatermarkState, values: jax.Array,
                     mask: jax.Array) -> WatermarkState:
    """Explicit-watermark mode: fold reported per-source watermark values.

    The hierarchical ingest tier (repro.ingest) runs the merge one level up:
    each leaf ScaleGate *reports* its own watermark ``W_leaf`` alongside its
    ready batch, and the root tracks ``frontier[leaf] = max seen W_leaf``
    instead of folding per-tuple taus — a leaf that forwarded nothing this
    round still advances the root watermark (liveness), and the report
    dominates any forwarded tau (a leaf only forwards ``tau <= W_leaf``).
    Frontiers stay non-decreasing (§2.3).
    """
    values = jnp.asarray(values, jnp.int32)
    frontier = jnp.where(mask, jnp.maximum(state.frontier, values),
                         state.frontier)
    return dataclasses.replace(state, frontier=frontier)


def fold_reports(state: WatermarkState, reports: jax.Array,
                 mask: jax.Array):
    """Device-side frontier reduction for the fused root merge.

    Folds the leaves' reported watermarks into the frontier
    (``observe_explicit``) and reduces to the gate value in the same traced
    program: returns ``(state', eff, W)`` where ``eff`` is the per-leaf
    effective frontier (INF on inactive leaves — the stacked kernel's
    report tile) and ``W = min(eff)`` is Definition 3 one level up.  The
    whole reduction stays on device, so the root merge never reads a
    watermark back to host inside its per-round hot path.
    """
    st = observe_explicit(state, reports, mask)
    eff = jnp.where(st.active, st.frontier, INF_TIME)
    return st, eff, jnp.min(eff)


def clamp_frontier(state: WatermarkState, mask: jax.Array,
                   gamma) -> WatermarkState:
    """Rebalance clamp (Lemma 3, applied one level up): when a merge point's
    source *gains* a sub-stream whose safe lower bound ``gamma`` is below the
    frontier already established for it, the frontier must drop to ``gamma``
    — future tuples on that source are only guaranteed ``tau >= gamma``.
    Safe for the merge point's own watermark monotonicity as long as
    ``gamma >= W`` (the caller's obligation; Lemma 3 guarantees it when
    ``gamma`` is an active source's frontier, since every active frontier
    is ``>= W = min_i frontier[i]``)."""
    gamma = jnp.asarray(gamma, jnp.int32)
    frontier = jnp.where(mask, jnp.minimum(state.frontier, gamma),
                         state.frontier)
    return dataclasses.replace(state, frontier=frontier)


def add_sources(state: WatermarkState, mask: jax.Array, gamma) -> WatermarkState:
    """ESG ``addSources``: new sources start at the Lemma-3 safe bound gamma."""
    frontier = jnp.where(mask & ~state.active,
                         jnp.asarray(gamma, jnp.int32), state.frontier)
    return WatermarkState(frontier=frontier, active=state.active | mask)


def remove_sources(state: WatermarkState, mask: jax.Array) -> WatermarkState:
    """ESG ``removeSources``: flush — the leaving source stops gating W."""
    return dataclasses.replace(state, active=state.active & ~mask)


# ------------------------------------------------- checkpoint export/import --
def export_np(state: WatermarkState) -> dict:
    """Host-side snapshot of the frontier (checkpoint leaf dict).  Picklable
    plain numpy — safe to ship across process-worker channels."""
    import numpy as np
    return {"frontier": np.asarray(state.frontier),
            "active": np.asarray(state.active)}


def import_np(d: dict) -> WatermarkState:
    return WatermarkState(frontier=jnp.asarray(d["frontier"], jnp.int32),
                          active=jnp.asarray(d["active"], bool))
