"""Shared neural-net layers: RMSNorm, RoPE, SwiGLU (pure-functional)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: [..., S] (absolute, for KV-cache decode)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """Logits against the (possibly tied) embedding table [V, D]."""
    return jnp.einsum("...d,vd->...v", x, table)


def init_dense(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
