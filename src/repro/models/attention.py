"""GQA attention: blocked online-softmax (jnp flash), qk-norm, sliding
window, KV cache.  ``kernels/flash_attention`` is the Pallas twin for real
TPU runs; this XLA path is what the dry-run lowers (DESIGN.md §4) and its
FLOPs/bytes match the kernel's, so the roofline terms are representative.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm, rope
from repro.models.sharding import axis_resolves, shard

NEG_INF = -1e30


def init_attn(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": init_dense(ks[0], (d, cfg.q_dim), dtype=dtype),
        "wk": init_dense(ks[1], (d, cfg.kv_dim), dtype=dtype),
        "wv": init_dense(ks[2], (d, cfg.kv_dim), dtype=dtype),
        "wo": init_dense(ks[3], (cfg.q_dim, d), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_scale"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def blocked_attention(q, k, v, *, q_offset, window: Optional[int] = None,
                      chunk: int = 1024, unroll: bool = False):
    """Causal flash attention in jnp: scan over KV chunks, online softmax.

    q: [B, Sq, H, Dh]; k, v: [B, Skv, KV, Dh] (GQA: H = KV * G).
    ``q_offset``: absolute position of q[0] on the KV timeline (decode: Skv-1
    for single-token, prefill/train: 0).  Never materializes [Sq, Skv].
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    chunk = min(chunk, skv)
    assert skv % chunk == 0
    qg = q.reshape(b, sq, kv, g, dh)
    scale = dh ** -0.5
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry
        kc, vc, c0 = inputs                      # [B, C, KV, Dh], offset
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kc).astype(jnp.float32)
        s = s * scale
        k_pos = c0 + jnp.arange(chunk)
        mask = q_pos[:, None] >= k_pos[None, :]              # causal
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        upd = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vc.dtype), vc)
        acc_new = acc * corr[..., 0][..., None] + upd.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    n_chunks = skv // chunk
    kcs = k.reshape(b, n_chunks, chunk, kv, dh).swapaxes(0, 1)
    vcs = v.reshape(b, n_chunks, chunk, kv, dh).swapaxes(0, 1)
    offs = jnp.arange(n_chunks) * chunk
    m0 = jnp.full((b, sq, kv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g, 1), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, dh), jnp.float32)
    if unroll:
        # analysis mode: a python loop makes every chunk visible to
        # cost_analysis (scan bodies are counted once by XLA)
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            xc = jax.tree.map(lambda a: a[i], (kcs, vcs, offs))
            carry, _ = step(carry, xc)
        m, l, acc = carry
    else:
        # checkpoint per KV chunk: backward recomputes the chunk's logits
        # instead of saving them (flash-backward memory discipline).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                      (kcs, vcs, offs))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention(q, k, v, *, q_offset, window=None):
    """Single-query attention with full (but tiny: Sq=1) logits.

    The GSPMD-friendly decode path: with the KV cache sharded over the
    sequence axis ("kv_seq" -> model), the QK^T einsum is local per shard,
    softmax reduces with scalar-sized all-reduces, and the PV contraction
    ends in one [B,H,Dh] psum — a few KB of collective per step instead of
    broadcasting cache chunks (DESIGN.md §6).
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, dh)
    scale = dh ** -0.5
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k).astype(jnp.float32) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def attn_forward(p, x, positions, cfg: ModelConfig, *,
                 window: Optional[int] = None, cache=None,
                 chunk: int = 1024):
    """x: [B, S, D].  With ``cache`` (decode): append S new positions to the
    cache at ``positions`` and attend over the full timeline."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if axis_resolves("heads"):
        # heads divide TP: pin the clean head-parallel layout.  Otherwise
        # leave q/k/v to GSPMD propagation — pinning P(dp, None, ...) would
        # force an all-gather of the projection outputs (§Perf A3).
        q = shard(q, "batch", "seq", "heads", "head_dim")

    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_scale"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        ck, cv = cache["k"], cache["v"]
        pos0 = positions[0]
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos0, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos0, 1)
        if axis_resolves("kv_seq") or axis_resolves("kv_heads"):
            ck = shard(ck, "batch", "kv_seq", "kv_heads", "head_dim")
            cv = shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")
        cache = {"k": ck, "v": cv}
        if s == 1:
            out = decode_attention(q, ck, cv, q_offset=pos0, window=window)
        else:
            out = blocked_attention(q, ck, cv, q_offset=pos0, window=window,
                                    chunk=chunk, unroll=cfg.analysis_unroll)
    else:
        if axis_resolves("kv_heads"):
            k = shard(k, "batch", "seq", "kv_heads", "head_dim")
            v = shard(v, "batch", "seq", "kv_heads", "head_dim")
        out = blocked_attention(q, k, v, q_offset=0, window=window,
                                chunk=min(chunk, s),
                                unroll=cfg.analysis_unroll)
    out = out.reshape(b, s, cfg.q_dim)
    out = jnp.einsum("bsq,qd->bsd", out, p["wo"])
    return shard(out, "batch", "seq", "embed"), cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
