"""RWKV6 "Finch" blocks: data-dependent-decay linear attention (attn-free).

Time-mix: token-shift lerp, r/k/v/g projections, per-channel decay
``w = exp(-exp(w0 + lora(x)))`` and the matrix-state recurrence of
``kernels/linear_scan`` (with bonus u); channel-mix: token-shift + squared
ReLU FFN.  The lax.scan training path is the kernel's oracle; decode carries
per-layer (shift, wkv-state).

State per layer: shift_tm/shift_cm: [B, D]; wkv: [B, H, Dk, Dv].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rms_norm
from repro.models.scan_utils import chunked_scan
from repro.models.sharding import shard

LORA_R = 64


def n_rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head


def init_time_mix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": init_dense(ks[0], (d, d), dtype=dtype),
        "w_k": init_dense(ks[1], (d, d), dtype=dtype),
        "w_v": init_dense(ks[2], (d, d), dtype=dtype),
        "w_g": init_dense(ks[3], (d, d), dtype=dtype),
        "w_o": init_dense(ks[4], (d, d), dtype=dtype),
        "w0": jnp.full((d,), -1.0, jnp.float32),       # decay bias
        "w_lora_a": init_dense(ks[5], (d, LORA_R), dtype=dtype),
        "w_lora_b": init_dense(ks[6], (LORA_R, d), scale=0.01, dtype=dtype),
        "u": init_dense(ks[7], (d,), scale=0.5, dtype=jnp.float32),
        "ln_scale": jnp.zeros((d,), dtype),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype), "mu_r": jnp.full((d,), 0.5, dtype),
        "w_k": init_dense(ks[0], (d, f), dtype=dtype),
        "w_v": init_dense(ks[1], (f, d), dtype=dtype),
        "w_r": init_dense(ks[2], (d, d), dtype=dtype),
    }


def _token_shift(x, shift_state):
    """x[t-1] stream: prepend the carried last token (decode-composable)."""
    prev = jnp.concatenate([shift_state.astype(x.dtype)[:, None], x[:, :-1]],
                           axis=1)
    return prev, x[:, -1].astype(jnp.float32)


def time_mix_forward(p, x, cfg: ModelConfig, shift_state, wkv_state):
    b, s, d = x.shape
    h = n_rwkv_heads(cfg)
    hd = cfg.rwkv_head
    prev, new_shift = _token_shift(x, shift_state)

    def lerp(mu):
        return x + (prev - x) * mu

    r = jnp.einsum("bsd,de->bse", lerp(p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,de->bse", lerp(p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,de->bse", lerp(p["mu_v"]), p["w_v"])
    g = jnp.einsum("bsd,de->bse", lerp(p["mu_g"]), p["w_g"])
    # data-dependent decay (the "Finch" contribution)
    lora = jnp.einsum("bsd,dr->bsr", lerp(p["mu_w"]), p["w_lora_a"])
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(lora), p["w_lora_b"])
    w = jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))  # (0,1)

    # heads: [B, S, H, hd]
    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, h, hd)
    u = p["u"].reshape(h, hd)

    def step(state, inp):
        rt, kt, vt, wt = inp          # [B,H,hd] each
        kv = kt[..., :, None] * vt[..., None, :]       # [B,H,hd,hd]
        yt = jnp.einsum("bhk,bhkv->bhv", rt,
                        state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, yt

    xs = (rh.swapaxes(0, 1), kh.swapaxes(0, 1), vh.swapaxes(0, 1),
          wh.swapaxes(0, 1))
    wkv_state, ys = chunked_scan(step, wkv_state, xs, chunk=256)
    y = ys.swapaxes(0, 1).reshape(b, s, d)             # [B,S,D]
    y = rms_norm(y.astype(x.dtype), p["ln_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", y, p["w_o"])
    return shard(y, "batch", "seq", "embed"), new_shift, wkv_state


def channel_mix_forward(p, x, cfg: ModelConfig, shift_state):
    prev, new_shift = _token_shift(x, shift_state)
    xk = x + (prev - x) * p["mu_k"]
    xr = x + (prev - x) * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]))
    return shard(r * v, "batch", "seq", "embed"), new_shift


def init_rwkv_state(cfg: ModelConfig, batch: int):
    h = n_rwkv_heads(cfg)
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "shift_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, h, cfg.rwkv_head, cfg.rwkv_head),
                         jnp.float32),
    }
