"""Public step functions: train_step / prefill_step / decode_step.

These are what the launcher jits and the dry-run lowers.  All three take
the *same* pytrees on every arch (params, batch, caches) so the 40
(arch x shape) dry-run cells share one code path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.sharding import shard
from repro.optim import adamw


def make_train_batch_shapes(cfg: ModelConfig, global_batch: int, seq: int):
    if cfg.frontend == "token":
        inputs = jax.ShapeDtypeStruct((global_batch, seq), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((global_batch, seq, cfg.d_model),
                                      jnp.bfloat16)
    return {
        "inputs": inputs,
        "labels": jax.ShapeDtypeStruct((global_batch, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((global_batch, seq), jnp.float32),
    }


def train_step(params, opt_state, batch: Dict[str, Any], *,
               cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
               chunk: int = 1024):
    """Forward/backward (+ microbatch grad accumulation) + AdamW update."""
    inputs, labels, mask = batch["inputs"], batch["labels"], batch["mask"]
    b, s = labels.shape
    positions = jnp.arange(s)
    m = cfg.n_microbatches

    def loss_one(p, inp, lab, msk):
        loss, aux = transformer.loss_fn(p, cfg, inp, lab, msk, positions,
                                        chunk=chunk)
        return loss, aux

    if m == 1:
        (loss, aux), grads = jax.value_and_grad(loss_one, has_aux=True)(
            params, inputs, labels, mask)
    else:
        assert b % m == 0
        mb = b // m
        resh = lambda x: x.reshape((m, mb) + x.shape[1:])
        micro = jax.tree.map(resh, (inputs, labels, mask))

        def acc_body(carry, xs):
            g_acc, l_acc, a_acc = carry
            inp, lab, msk = xs
            (l, a), g = jax.value_and_grad(loss_one, has_aux=True)(
                params, inp, lab, msk)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + l, a_acc + a), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss, aux), _ = jax.tree.map(lambda x: x, jax.lax.scan(
            acc_body, (zeros, jnp.zeros(()), jnp.zeros(())), micro))
        grads = jax.tree.map(lambda g: g / m, grads)
        loss = loss / m

    # §Perf A2: the cross-replica gradient reduce-scatter (ZeRO-1) moves
    # bf16 instead of f32 — local microbatch accumulation stays f32, the
    # wire bytes halve.  (int8 error-feedback compression is a further 2x:
    # optim/compress.py, selectable in train.py.)
    grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    params, opt_state, om = adamw.apply_updates(params, grads, opt_state,
                                                opt_cfg)
    metrics = {"loss": loss, "dropped": aux, **om}
    return params, opt_state, metrics


def prefill_step(params, inputs, *, cfg: ModelConfig, chunk: int = 1024):
    """Full-sequence forward building the KV cache (inference prefill)."""
    if cfg.frontend == "token":
        b, s = inputs.shape
    else:
        b, s, _ = inputs.shape
    positions = jnp.arange(s)
    logits, caches, states, _ = transformer.forward(
        params, cfg, inputs, positions, caches=None, states=None, chunk=chunk)
    # prefill emits the last-position logits + (train-path) caches are not
    # materialized by forward(); serving uses decode_state_from_prefill.
    return logits[:, -1]


def prefill_with_cache(params, inputs, caches, states, *, cfg: ModelConfig,
                       chunk: int = 1024):
    """Prefill that also fills the decode caches (serving path)."""
    if cfg.frontend == "token":
        b, s = inputs.shape
    else:
        b, s, _ = inputs.shape
    positions = jnp.arange(s)
    logits, caches, states, _ = transformer.forward(
        params, cfg, inputs, positions, caches=caches, states=states,
        chunk=chunk)
    return logits[:, -1], caches, states


def decode_step(params, caches, states, token, pos, *, cfg: ModelConfig,
                chunk: int = 1024):
    """One new token against a KV cache / recurrent state (serve_step).

    token: [B] ids (or [B, D] stub embeddings); pos: scalar position.
    """
    if cfg.frontend == "token":
        inputs = token[:, None]
    else:
        inputs = token[:, None, :]
    positions = pos + jnp.arange(1)
    logits, caches, states, _ = transformer.forward(
        params, cfg, inputs, positions, caches=caches, states=states,
        chunk=chunk)
    return logits[:, -1], caches, states


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation (dry-run)."""
    return jax.eval_shape(
        lambda k: transformer.init_params(k, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_opt(params):
    return jax.eval_shape(adamw.init_opt, params)
