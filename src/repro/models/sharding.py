"""Sharding annotations, decoupled from model code.

Model code calls ``shard(x, "batch", "seq", None)`` with *logical* axis
names; a run installs a mesh + logical->mesh rules (MaxText-style) via
``use_rules``.  Without an installed context the calls are no-ops, so the
same model runs on one CPU device (smoke tests) and on the production mesh
(dry-run / launch) unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# default logical -> mesh-axis rules; pod is folded into data-parallel.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,            # long-context decode shards the KV timeline
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": "model",            # flattened H*Dh projection dim
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "state": "model",          # rwkv/ssm recurrent state channels
    "layers": None,
    "opt": "data",             # ZeRO-1 optimizer-state sharding axis
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> dict:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[dict] = None):
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    _state.mesh = mesh
    _state.rules = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def resolve(*logical: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec under the current rules."""
    rules = current_rules()
    mesh = current_mesh()
    names = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for ax in logical:
        r = rules.get(ax) if ax is not None else None
        if r is None:
            out.append(None)
        elif isinstance(r, tuple):
            keep = tuple(a for a in r if a in names)
            out.append(keep if keep else None)
        else:
            out.append(r if r in names else None)
    return P(*out)


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint under the installed mesh (no-op otherwise).

    A spec that resolves to all-None is treated as *no opinion* rather than
    "replicate": forcing replication on activations whose producer einsum
    left them usefully sharded inserts giant all-gathers (§Perf A3)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve(*logical)
    if all(ax is None for ax in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def axis_resolves(logical: str) -> bool:
    """True if this logical axis maps to a real mesh axis under the
    current rules (lets model code skip constraints that would otherwise
    force replication — §Perf A3)."""
    mesh = current_mesh()
    if mesh is None:
        return False
    return resolve(logical) != (None,) if False else \
        tuple(resolve(logical))[0] is not None


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical))
