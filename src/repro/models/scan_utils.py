"""Memory-bounded sequential scans (gradient checkpointing over time).

A plain ``lax.scan`` saves every step's residuals for backward — for a
4k-token recurrence with a [B, H, Dk, Dv] state that is tens of GB.
``chunked_scan`` nests two scans: the outer one checkpoints each chunk
(so backward saves only per-chunk carries) and the inner one is recomputed
chunk-by-chunk during backprop.  Backward memory drops from O(T) to
O(T/C + C) saved states — the standard recipe flash-attention backward
uses, applied to the SSM/RWKV time scans and the KV-chunk scan.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def chunked_scan(f: Callable, init, xs, *, chunk: int, remat: bool = True):
    """Like ``jax.lax.scan(f, init, xs)`` with remat-per-chunk backward.

    xs leaves: [T, ...]; T % chunk == 0.  Returns (carry, ys) with ys
    stacked back to [T, ...].
    """
    t = jax.tree.leaves(xs)[0].shape[0]
    if chunk >= t:
        return jax.lax.scan(f, init, xs)
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def run_chunk(carry, xc):
        return jax.lax.scan(f, carry, xc)

    if remat:
        run_chunk = jax.checkpoint(run_chunk)

    carry, ys = jax.lax.scan(run_chunk, init, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((t,) + a.shape[2:]), ys)
    return carry, ys
