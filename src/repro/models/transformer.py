"""Decoder stack assembly: block dispatch by arch kind, scan-over-layers
(+ remat), embeddings/unembed, losses.  One code path serves all 10 assigned
architectures via ModelConfig.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, moe as moe_mod, rwkv as rwkv_mod, ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import embed, init_dense, rms_norm, swiglu, unembed
from repro.models.sharding import shard

BIG_WINDOW = 1 << 30


def layer_windows(cfg: ModelConfig) -> Optional[jax.Array]:
    """gemma3 5:1 local:global pattern -> per-layer window sizes."""
    if cfg.window_pattern is None:
        return None
    local, every = cfg.window_pattern
    idx = jnp.arange(cfg.n_layers)
    return jnp.where((idx + 1) % every == 0, BIG_WINDOW, local).astype(jnp.int32)


# --------------------------------------------------------------------------
# per-layer init / forward
# --------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.zeros((d,), dtype),
                         "norm2": jnp.zeros((d,), dtype)}
    if cfg.kind == "rwkv":
        p["tm"] = rwkv_mod.init_time_mix(ks[0], cfg, dtype)
        p["cm"] = rwkv_mod.init_channel_mix(ks[1], cfg, dtype)
        return p
    p["attn"] = attention.init_attn(ks[0], cfg, dtype)
    if cfg.kind == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
        p["norm1b"] = jnp.zeros((d,), dtype)
    if cfg.kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = {
            "wg": init_dense(ks[3], (d, cfg.d_ff), dtype=dtype),
            "wu": init_dense(ks[4], (d, cfg.d_ff), dtype=dtype),
            "wd": init_dense(ks[5], (cfg.d_ff, d), dtype=dtype),
        }
    return p


def block_forward(p, x, positions, cfg: ModelConfig, *, window=None,
                  cache=None, state=None, chunk: int = 1024):
    """One decoder block.  Returns (x, new_cache, new_state, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.kind == "rwkv":
        h, new_shift_tm, wkv = rwkv_mod.time_mix_forward(
            p["tm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg,
            state["shift_tm"], state["wkv"])
        x = x + h
        h, new_shift_cm = rwkv_mod.channel_mix_forward(
            p["cm"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg,
            state["shift_cm"])
        x = x + h
        new_state = {"shift_tm": new_shift_tm, "shift_cm": new_shift_cm,
                     "wkv": wkv}
        return x, cache, new_state, aux

    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    attn_out, cache = attention.attn_forward(
        p["attn"], h, positions, cfg, window=window, cache=cache, chunk=chunk)
    if cfg.kind == "hybrid":
        hs = rms_norm(x, p["norm1b"], cfg.norm_eps)
        ssm_out, state = ssm_mod.ssm_forward(p["ssm"], hs, cfg,
                                             None if state is None else state)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out

    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.kind == "moe":
        ffn_out, dropped = moe_mod.moe_forward(p["moe"], h, cfg)
        aux = aux + dropped.astype(jnp.float32)
    else:
        ffn_out = swiglu(h, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"])
        ffn_out = shard(ffn_out, "batch", "seq", "embed")
    x = x + ffn_out
    return x, cache, state, aux


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embedding": init_dense(k_emb, (cfg.padded_vocab, cfg.d_model),
                                scale=0.02, dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_dense(k_out, (cfg.padded_vocab, cfg.d_model),
                                       scale=0.02, dtype=dtype)
    if cfg.scan_layers:
        def one(k):
            return init_layer(k, cfg, dtype)
        params["layers"] = jax.vmap(one)(
            jax.random.split(k_layers, cfg.n_layers))
    else:
        params["layers"] = [
            init_layer(k, cfg, dtype)
            for k in jax.random.split(k_layers, cfg.n_layers)]
    return params


def forward(params, cfg: ModelConfig, inputs, positions, *, caches=None,
            states=None, chunk: int = 1024):
    """inputs: tokens [B, S] (frontend="token") or precomputed frontend
    embeddings [B, S, D] (audio/vlm backbones, per the assignment's stub).

    Returns (logits, new_caches, new_states, aux)."""
    if cfg.frontend == "token":
        x = embed(inputs, params["embedding"])
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", "seq", "embed")
    windows = layer_windows(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.kind == "rwkv" and states is None:
        # training starts from zero recurrent state (streams reset per seq)
        one = rwkv_mod.init_rwkv_state(cfg, x.shape[0])
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)

    def run_block(x, layer_p, window, cache, state):
        return block_forward(layer_p, x, positions, cfg, window=window,
                             cache=cache, state=state, chunk=chunk)

    if cfg.remat:
        run_block = jax.checkpoint(run_block)

    if cfg.scan_layers:
        def body(x, xs):
            layer_p, window, cache, state = xs
            x, cache, state, aux = run_block(x, layer_p, window, cache, state)
            return x, (cache, state, aux)

        windows_xs = (windows if windows is not None
                      else jnp.full((cfg.n_layers,), BIG_WINDOW, jnp.int32))
        xs = (params["layers"], windows_xs, caches, states)
        x, (caches, states, auxs) = jax.lax.scan(body, x, xs)
        aux_total = jnp.sum(auxs)
    else:
        # unrolled python loop (debug / roofline analysis mode); caches and
        # states keep their stacked [L, ...] layout.
        layers = params["layers"]
        if not isinstance(layers, (list, tuple)):
            layers = [jax.tree.map(lambda a: a[i], layers)
                      for i in range(cfg.n_layers)]
        new_caches, new_states = [], []
        for i, layer_p in enumerate(layers):
            w = None if windows is None else windows[i]
            c = (None if caches is None
                 else jax.tree.map(lambda a: a[i], caches))
            s = (None if states is None
                 else jax.tree.map(lambda a: a[i], states))
            x, c, s, aux = run_block(x, layer_p, w, c, s)
            new_caches.append(c)
            new_states.append(s)
            aux_total = aux_total + aux
        stack = lambda parts: (None if parts[0] is None else
                               jax.tree.map(lambda *xs: jnp.stack(xs), *parts))
        caches, states = stack(new_caches), stack(new_states)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embedding"])
    logits = unembed(x, table)
    return shard(logits, "batch", "seq", "vocab"), caches, states, aux_total


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked [L, ...] KV caches / recurrent states for decode."""
    dtype = jnp.dtype(cfg.dtype)
    caches = states = None
    if cfg.kind == "rwkv":
        states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            rwkv_mod.init_rwkv_state(cfg, batch))
    else:
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            attention.init_cache(cfg, batch, max_seq, dtype))
        if cfg.kind == "hybrid":
            states = jnp.zeros(
                (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                 cfg.head_dim), jnp.float32)
    return caches, states


def loss_fn(params, cfg: ModelConfig, inputs, labels, mask, positions,
            chunk: int = 1024):
    logits, _, _, aux = forward(params, cfg, inputs, positions, chunk=chunk)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / n, aux
