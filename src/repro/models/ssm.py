"""Mamba-style selective-SSM heads (hymba's parallel-head hybrid).

SSD/mamba2-like parameterization matching the ``kernels/linear_scan``
recurrence: per head, state S: [n_state, head_dim],

    S_t = exp(-softplus(dt_t)) * S_{t-1} + B_t^T x_t
    y_t = C_t @ S_t,   gated by silu(z_t)

Simplifications vs. the HF checkpoint (recorded in DESIGN.md §9): no
depthwise conv1d pre-filter, scalar-per-head decay broadcast over state.
Training path: jax.lax.scan over time (the ref oracle of the Pallas
kernel); decode path: one recurrence step against the carried state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_dense
from repro.models.scan_utils import chunked_scan
from repro.models.sharding import shard


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h, pdim, s = cfg.ssm_heads, cfg.head_dim, cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_x": init_dense(ks[0], (d, h * pdim), dtype=dtype),
        "w_z": init_dense(ks[1], (d, h * pdim), dtype=dtype),
        "w_b": init_dense(ks[2], (d, h * s), dtype=dtype),
        "w_c": init_dense(ks[3], (d, h * s), dtype=dtype),
        "w_dt": init_dense(ks[4], (d, h), dtype=dtype),
        "w_out": init_dense(ks[5], (h * pdim, d), dtype=dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
    }


def _proj(p, x, cfg: ModelConfig):
    b, sq, d = x.shape
    h, pdim, s = cfg.ssm_heads, cfg.head_dim, cfg.ssm_state
    xv = jnp.einsum("btd,dp->btp", x, p["w_x"]).reshape(b, sq, h, pdim)
    z = jnp.einsum("btd,dp->btp", x, p["w_z"]).reshape(b, sq, h, pdim)
    bb = jnp.einsum("btd,dp->btp", x, p["w_b"]).reshape(b, sq, h, s)
    cc = jnp.einsum("btd,dp->btp", x, p["w_c"]).reshape(b, sq, h, s)
    dt = jnp.einsum("btd,dh->bth", x, p["w_dt"]).astype(jnp.float32)
    decay = jnp.exp(-jax.nn.softplus(dt + p["a_log"][None, None]))  # (0,1)
    return xv, z, bb, cc, decay


def _ssd_chunked(xv, bb, cc, decay, state, chunk: int):
    """Chunked SSD (mamba2) evaluation of the scalar-per-head recurrence.

    §Perf B1: the per-token scan streams the [n_state, head_dim] state
    through HBM every step; this form computes each chunk with [C, C]
    masked matmuls (MXU food) and touches the state only at chunk
    boundaries — O(T/C) state traffic instead of O(T).

    Log-space decays keep everything bounded: within-chunk factors are
    exp(L_t - L_s) with t >= s and L non-increasing, so every exponent
    is <= 0.  xv: [B,T,H,P]; bb/cc: [B,T,H,S]; decay: [B,T,H] in (0,1).
    """
    b, t, h, pdim = xv.shape
    ns = bb.shape[-1]
    c = min(chunk, t)
    assert t % c == 0
    n = t // c
    f32 = jnp.float32
    xc = xv.reshape(b, n, c, h, pdim).astype(f32)
    bc = bb.reshape(b, n, c, h, ns).astype(f32)
    ccx = cc.reshape(b, n, c, h, ns).astype(f32)
    logw = jnp.log(jnp.clip(decay.reshape(b, n, c, h), 1e-20, 1.0))
    lcum = jnp.cumsum(logw, axis=2)                       # [B,N,C,H]

    # intra-chunk: G[t,s] = (C_t . B_s) * exp(L_t - L_s), s <= t
    gmat = jnp.einsum("bnthi,bnshi->bnhts", ccx, bc)
    dt = lcum[..., :, None, :] - lcum[..., None, :, :]    # [B,N,C,C,H]
    mask = jnp.tril(jnp.ones((c, c), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(dt), 0.0)
    gmat = gmat * jnp.moveaxis(dec, -1, 2)                # [B,N,H,C,C]
    y_intra = jnp.einsum("bnhts,bnshp->bnthp", gmat, xc)

    # chunk-boundary states: S_end = e^{L_C} S_0 + sum_s e^{L_C - L_s} B_s x_s
    tail = jnp.exp(lcum[..., -1:, :] - lcum)              # [B,N,C,H]
    kx = jnp.einsum("bnshi,bnsh,bnshp->bnhip", bc, tail, xc)
    a_full = jnp.exp(lcum[:, :, -1])                      # [B,N,H]

    def carry_fn(s0, inp):
        af, kxn = inp                                     # [B,H], [B,H,S,P]
        s1 = s0 * af[..., None, None] + kxn
        return s1, s0                                     # emit chunk-start

    (state, s_starts) = jax.lax.scan(
        carry_fn, state.astype(f32),
        (a_full.swapaxes(0, 1), kx.swapaxes(0, 1)))
    s_starts = s_starts.swapaxes(0, 1)                    # [B,N,H,S,P]

    # inter-chunk: y += exp(L_t) * C_t . S_chunk_start
    y_inter = jnp.einsum("bnthi,bnth,bnhip->bnthp",
                         ccx, jnp.exp(lcum), s_starts)
    y = (y_intra + y_inter).reshape(b, t, h, pdim)
    return y, state


def ssm_forward(p, x, cfg: ModelConfig, state=None, chunk: int = 128):
    """x: [B, S, D] -> (y, new_state).  state: [B, H, n_state, head_dim].

    Training/prefill use the chunked SSD path; single-token decode uses the
    plain recurrence step."""
    b, sq, d = x.shape
    h, pdim, ns = cfg.ssm_heads, cfg.head_dim, cfg.ssm_state
    xv, z, bb, cc, decay = _proj(p, x, cfg)

    if state is None:
        state = jnp.zeros((b, h, ns, pdim), jnp.float32)

    if sq == 1:
        at = decay[:, 0]
        state = (state * at[..., None, None] +
                 bb[:, 0][..., None] * xv[:, 0][..., None, :])
        y = jnp.einsum("bhs,bhsp->bhp", cc[:, 0], state)[:, None]
    elif sq % min(chunk, sq) == 0:
        y, state = _ssd_chunked(xv, bb, cc, decay, state, chunk)
    else:
        def step(s, inp):
            xt, bt, ct, at = inp
            s = s * at[..., None, None] + bt[..., None] * xt[..., None, :]
            yt = jnp.einsum("bhs,bhsp->bhp", ct, s)
            return s, yt
        xs = (xv.swapaxes(0, 1), bb.swapaxes(0, 1), cc.swapaxes(0, 1),
              decay.swapaxes(0, 1))
        state, ys = chunked_scan(step, state, xs, chunk=256)
        y = ys.swapaxes(0, 1)
    y = (y.astype(jnp.float32) *
         jax.nn.silu(z.astype(jnp.float32))).reshape(b, sq, h * pdim)
    y = jnp.einsum("btp,pd->btd", y.astype(x.dtype), p["w_out"])
    return shard(y, "batch", "seq", "embed"), state
