"""Model / run configuration for the assigned architecture zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, deepseek-style
    dispatch: str = "vsn"        # "vsn" (all-gather+mask) | "sn" (all-to-all)
    capacity_factor: float = 1.25  # SN dispatch only


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None             # default d_model // n_heads
    kind: str = "dense"          # dense | moe | rwkv | hybrid
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # gemma3: (local_window, global_every): 5 local : 1 global
    window_pattern: Optional[Tuple[int, int]] = None
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0           # hymba mamba-head state size
    ssm_heads: int = 0           # hymba parallel mamba heads
    rwkv_head: int = 64          # rwkv6 head size
    tie_embeddings: bool = True
    frontend: str = "token"      # token | embedding_stub (vlm/audio backbones)
    norm_eps: float = 1e-6
    # --- runtime knobs (shared by train/serve) ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    n_microbatches: int = 1
    # roofline analysis mode: unroll the attention KV-chunk loop so XLA's
    # cost_analysis (which counts while-loop bodies once) sees every chunk
    analysis_unroll: bool = False

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded so the vocab axis shards evenly (tp=16);
        labels never reference padding ids (hymba: 32001 -> 32016)."""
        return -(-self.vocab // 16) * 16

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters (attention + ffn/moe + embeddings)."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.n_heads:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.kind == "rwkv":
            per_layer += 6 * d * d + 2 * d * f  # time-mix + channel-mix
        elif self.kind == "hybrid":
            ssm_inner = self.ssm_heads * self.head_dim
            per_layer += 2 * d * ssm_inner + 2 * ssm_inner * self.ssm_state
            per_layer += 3 * d * f
        elif self.kind == "moe":
            m = self.moe
            per_layer += d * m.n_experts  # router
            per_layer += (m.n_experts + m.n_shared) * 3 * d * m.d_ff_expert
        else:
            per_layer += 3 * d * f
        return emb + l * per_layer + 2 * d * l  # + norms

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k + shared only)."""
        if self.kind != "moe":
            return self.param_count()
        d, l, m = self.d_model, self.n_layers, self.moe
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                     + d * m.n_experts
                     + (m.top_k + m.n_shared) * 3 * d * m.d_ff_expert)
        return emb + l * per_layer + 2 * d * l
