"""Mixture-of-Experts FFN with the paper's two dispatch disciplines.

Theorem 1 at pod scale (DESIGN.md §3): a token routed to top-k experts is a
multi-key tuple (f_MK = router).

* ``dispatch="sn"`` — shared-nothing: the GShard/Switch dispatch-combine
  einsum pair.  Each token is *copied* into the capacity buffer of every
  expert it routes to (duplication factor ~ top_k) and the SPMD partitioner
  moves the copies across the expert axis (all-to-all family) — the
  SPE-default baseline, like the paper's Flink.
* ``dispatch="vsn"`` — virtual shared-nothing: shard_map owner-computes.
  Tokens never move: each (data, expert)-shard already observes its data
  shard's token block (the replicated view = shared TB), masks in the tokens
  routed to *its* experts, computes, and the partial outputs meet in one
  psum over the expert axis.  No duplication, no capacity-drop skew from
  cross-shard imbalance, deterministic.

Both paths share the router and per-expert SwiGLU weights; capacity
overflow is counted (``aux["dropped"]``), never silent.  Shared experts
(deepseek-style) are plain TP MLPs applied outside the dispatch.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, swiglu
from repro.models.sharding import current_mesh, resolve, shard


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "router": init_dense(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "wg": init_dense(ks[1], (m.n_experts, d, m.d_ff_expert), dtype=dtype),
        "wu": init_dense(ks[2], (m.n_experts, d, m.d_ff_expert), dtype=dtype),
        "wd": init_dense(ks[3], (m.n_experts, m.d_ff_expert, d), dtype=dtype),
    }
    if m.n_shared:
        f = m.d_ff_expert * m.n_shared
        p["shared_wg"] = init_dense(ks[4], (d, f), dtype=dtype)
        p["shared_wu"] = init_dense(ks[5], (d, f), dtype=dtype)
        p["shared_wd"] = init_dense(ks[6], (f, d), dtype=dtype)
    return p


def _route(x, router, top_k: int):
    """Router: returns (weights [N, k], experts [N, k]) with renormalized
    softmax over the selected experts (deepseek/qwen3 convention)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w.astype(jnp.float32), idx


def _expert_ffn(xe, wg, wu, wd):
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


# --------------------------------------------------------------------------
# SN: GShard dispatch/combine einsums (token copies cross the expert axis)
# --------------------------------------------------------------------------

def _sn_moe(p, x2, cfg: ModelConfig):
    """Sort-based dispatch: each (token, choice) pair is *copied* into its
    expert's capacity buffer (duplication = top_k, Theorem 1); the copies
    cross the expert axis under GSPMD (all-to-all family)."""
    m = cfg.moe
    n, d = x2.shape
    e = m.n_experts
    cap = max(int(m.top_k * n * m.capacity_factor / e), 1)

    w, idx = _route(x2, p["router"], m.top_k)            # [N,k]
    nk = n * m.top_k
    flat_e = idx.reshape(nk)
    flat_t = jnp.repeat(jnp.arange(n), m.top_k)
    flat_w = w.reshape(nk)

    order = jnp.argsort(flat_e, stable=True)             # FIFO per expert
    se, stok, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(flat_e, length=e)
    start = jnp.cumsum(counts) - counts                  # exclusive prefix
    pos = jnp.arange(nk) - start[se]                     # slot within expert
    keep = pos < cap
    dropped = nk - jnp.sum(keep.astype(jnp.int32))

    slot = jnp.where(keep, se * cap + pos, e * cap)      # overflow -> drop
    xe_flat = jnp.zeros((e * cap, d), x2.dtype).at[slot].set(
        x2[stok], mode="drop")
    xe = shard(xe_flat.reshape(e, cap, d), "experts", None, "embed")
    he = _expert_ffn(xe, p["wg"], p["wu"], p["wd"])
    he = shard(he, "experts", None, "embed")
    he_flat = he.reshape(e * cap, d)
    contrib = he_flat[jnp.minimum(slot, e * cap - 1)].astype(jnp.float32)
    contrib = contrib * (sw * keep)[:, None]
    y = jnp.zeros((n, d), jnp.float32).at[stok].add(contrib)
    return y.astype(x2.dtype), dropped


# --------------------------------------------------------------------------
# VSN: owner-computes over the shared token block (shard_map + psum)
# --------------------------------------------------------------------------

def _vsn_body(x_loc, router, wg, wu, wd, *, cfg: ModelConfig, axis: str,
              n_shards: int):
    m = cfg.moe
    n, d = x_loc.shape
    e_loc = m.n_experts // n_shards
    shard_id = jax.lax.axis_index(axis)
    lo = shard_id * e_loc
    cap = max(int(m.top_k * n * m.capacity_factor / m.n_experts), 1)

    w, idx = _route(x_loc, router, m.top_k)              # [N,k] global ids
    # responsibility mask: my experts only (f_mu(key) == j, Alg. 4 L23)
    local = (idx >= lo) & (idx < lo + e_loc)             # [N,k]
    # [E_loc, N]: which tokens hit my expert el
    hit = jnp.zeros((e_loc, n), bool)
    wmat = jnp.zeros((e_loc, n), jnp.float32)
    for kk in range(m.top_k):                            # top_k is small/static
        sel = jnp.where(local[:, kk], idx[:, kk] - lo, e_loc)
        oh = jax.nn.one_hot(sel, e_loc, dtype=jnp.float32).T  # [E_loc, N]
        hit = hit | (oh > 0)
        wmat = wmat + oh * w[:, kk][None, :]

    order = jnp.argsort(~hit, axis=1, stable=True)       # routed-first, FIFO
    take = order[:, :cap]                                # [E_loc, C]
    took = jnp.take_along_axis(hit, take, axis=1)        # [E_loc, C]
    dropped = jnp.sum(hit) - jnp.sum(took)
    xe = x_loc[take] * took[..., None].astype(x_loc.dtype)
    he = _expert_ffn(xe, wg, wu, wd)                     # [E_loc, C, D]
    we = jnp.take_along_axis(wmat, take, axis=1) * took  # [E_loc, C]
    y = jnp.zeros((n, d), jnp.float32)
    y = y.at[take.reshape(-1)].add(
        (he.astype(jnp.float32) * we[..., None]).reshape(-1, d))
    # partial outputs meet across the expert axis: the one collective.
    # §Perf A1: reduce in bf16 — each token receives <= top_k non-zero
    # partials, so bf16 accumulation is safe and halves the wire bytes.
    y = jax.lax.psum(y.astype(jnp.bfloat16), axis)
    return y.astype(x_loc.dtype), jax.lax.psum(dropped, axis)


def _vsn_moe(p, x2, cfg: ModelConfig):
    mesh = current_mesh()
    m = cfg.moe
    if mesh is None:
        # single-device smoke path: same math, one "shard" with all experts
        import numpy as np
        from jax.sharding import Mesh
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
        axis = "model"
        dp_spec = P()
    else:
        axis = "model"
        dp_spec = resolve("batch")

    n_shards = mesh.shape[axis]
    body = functools.partial(_vsn_body, cfg=cfg, axis=axis,
                             n_shards=n_shards)
    x_spec = P(dp_spec[0] if len(dp_spec) else None, None)
    e_spec = P(axis, None, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), e_spec, e_spec, e_spec),
        out_specs=(x_spec, P()),
        check_vma=False)
    return fn(x2, p["router"], p["wg"], p["wu"], p["wd"])


def moe_forward(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, dropped_count)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    m = cfg.moe
    if m.dispatch == "sn":
        y, dropped = _sn_moe(p, x2, cfg)
    else:
        y, dropped = _vsn_moe(p, x2, cfg)
    if m.n_shared:
        y = y + swiglu(x2, p["shared_wg"], p["shared_wu"], p["shared_wd"])
    return y.reshape(b, s, d), dropped
