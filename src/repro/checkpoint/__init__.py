"""Fault-tolerance layer: atomic-manifest checkpoints + streaming snapshots.

``checkpoint`` is the storage substrate (async saves, atomic manifest
commit, shape-checked restore); ``stream`` aligns it with the streaming
runtime (epoch-consistent tick-boundary capture of pipeline + ingest-tier
state, manifest-carried ``RuntimeConfig`` for identical-stack rebuild).
"""

from repro.checkpoint.checkpoint import (Checkpointer, latest_step,
                                         read_manifest, restore,
                                         restore_latest, save, wait)
from repro.checkpoint.stream import StreamCheckpointer

__all__ = [
    "Checkpointer", "StreamCheckpointer", "latest_step", "read_manifest",
    "restore", "restore_latest", "save", "wait",
]
