"""Fault-tolerant sharded checkpointing: async save, atomic manifests, resume.

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per pytree leaf plus a
``MANIFEST.json`` written *last* (the commit point): a crash mid-save leaves
no manifest and the step is invisible to ``latest_step`` — restart resumes
from the previous complete step (tested by the kill-drill in
tests/test_checkpoint.py).  Saves run on a background thread (training never
blocks on I/O); ``wait()`` joins before the next save of the same dir.

At real multi-pod scale each host writes only its local shards of the
addressable arrays and host 0 commits the manifest after a barrier; the
single-host layout here is the degenerate case of that protocol (the
manifest records the expected leaf set, which is what the barrier checks).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "MANIFEST.json"
_pending: dict = {}


def _leaf_paths(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _to_storable(arr: np.ndarray):
    """bf16/f8 have no stable npy codec: store as uint views + dtype tag."""
    if arr.dtype.kind == "V" or str(arr.dtype) not in (
            "float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool"):
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else
                        np.uint16 if arr.dtype.itemsize == 2 else
                        np.uint32), str(arr.dtype)
    return arr, str(arr.dtype)


def _from_storable(arr: np.ndarray, dtype_tag: str):
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc.)
    want = np.dtype(dtype_tag)
    if arr.dtype != want:
        return arr.view(want)
    return arr


def save(ckpt_dir: str, step: int, tree: Any, *, async_: bool = True,
         extra: Optional[dict] = None):
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(l) for l in leaves]   # device->host before fork

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        dtype_tags = []
        for i, arr in enumerate(host_leaves):
            store, tag = _to_storable(arr)
            dtype_tags.append(tag)
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), store)
        manifest = {
            "step": step,
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": dtype_tags,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)                      # atomic commit

    if async_:
        wait(ckpt_dir)
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        _pending[ckpt_dir] = t
    else:
        _write()


def wait(ckpt_dir: str):
    t = _pending.pop(ckpt_dir, None)
    if t is not None:
        t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step with a committed manifest (incomplete saves invisible)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
            continue
        try:
            s = int(name.split("_")[1])
        except ValueError:
            continue
        best = s if best is None else max(best, s)
    return best


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    out = []
    import jax.numpy as jnp
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        arr = _from_storable(arr, manifest["dtypes"][i])
        assert list(arr.shape) == list(ref.shape), f"leaf {i} shape mismatch"
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, like: Any):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, like)
