"""Fault-tolerant sharded checkpointing: async save, atomic manifests, resume.

Layout: ``<dir>/step_<N>/`` holds one ``.npy`` per pytree leaf plus a
``MANIFEST.json`` written *last* (the commit point): a crash mid-save leaves
no manifest and the step is invisible to ``latest_step`` — restart resumes
from the previous complete step (tested by the kill-drills in
tests/test_substrate.py and tests/test_checkpoint_restore.py).  Saves run on
a background thread (the pipeline never blocks on I/O); ``wait()`` joins
before the next save of the same ``Checkpointer``.

``Checkpointer`` owns its pending-save thread, so independent runtimes
checkpointing concurrently (even into the same directory tree) never race on
shared module state.  The module-level ``save/wait/...`` functions are kept
as thin wrappers over a lock-guarded per-directory registry for existing
callers (launch/train.py, the substrate tests).

At real multi-pod scale each host writes only its local shards of the
addressable arrays and host 0 commits the manifest after a barrier; the
single-host layout here is the degenerate case of that protocol (the
manifest records the expected leaf set, which is what the barrier checks).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_MANIFEST = "MANIFEST.json"


def _to_storable(arr: np.ndarray):
    """bf16/f8 have no stable npy codec: store as uint views + dtype tag."""
    if arr.dtype.kind == "V" or str(arr.dtype) not in (
            "float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool"):
        return arr.view(np.uint8 if arr.dtype.itemsize == 1 else
                        np.uint16 if arr.dtype.itemsize == 2 else
                        np.uint32), str(arr.dtype)
    return arr, str(arr.dtype)


def _from_storable(arr: np.ndarray, dtype_tag: str):
    import ml_dtypes  # noqa: F401  (registers bfloat16 etc.)
    want = np.dtype(dtype_tag)
    if arr.dtype != want:
        return arr.view(want)
    return arr


class Checkpointer:
    """Per-instance checkpoint manager: one pending async save at a time,
    atomic manifest commits, shape-checked restore.

    Each instance owns its own pending-save thread and lock; two runtimes
    with their own ``Checkpointer`` never serialize (or race) through shared
    module state.
    """

    def __init__(self, ckpt_dir: str):
        self.dir = str(ckpt_dir)
        self._pending: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, *, async_: bool = True,
             extra: Optional[dict] = None):
        """Snapshot ``tree`` as step ``step``.  Leaves are materialized to
        host *before* returning (donation-safe: the caller may overwrite the
        device buffers immediately); the disk write happens on a background
        thread unless ``async_=False``."""
        leaves, _ = jax.tree.flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]  # device->host now

        def _write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            dtype_tags = []
            for i, arr in enumerate(host_leaves):
                store, tag = _to_storable(arr)
                dtype_tags.append(tag)
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), store)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "shapes": [list(a.shape) for a in host_leaves],
                "dtypes": dtype_tags,
                "extra": extra or {},
            }
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)                     # atomic commit

        if async_:
            t = threading.Thread(target=_write, daemon=True)
            with self._lock:
                # publish and start atomically: anything wait() pops from
                # _pending is guaranteed to have been started
                prev, self._pending = self._pending, t
                t.start()
            if prev is not None:
                prev.join()        # one pending save at a time
        else:
            _write()

    def wait(self):
        """Join the in-flight async save, if any."""
        with self._lock:
            t, self._pending = self._pending, None
        if t is not None:
            t.join()

    # ---------------------------------------------------------- restore --
    def latest_step(self) -> Optional[int]:
        return latest_step(self.dir)

    def manifest(self, step: int) -> dict:
        """The committed manifest of ``step`` (includes caller ``extra``)."""
        path = os.path.join(self.dir, f"step_{step:08d}", _MANIFEST)
        with open(path) as f:
            return json.load(f)

    def restore(self, step: int, like: Any) -> Any:
        return restore(self.dir, step, like)

    def restore_latest(self, like: Any):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like)


# -------------------------------------------------- module-level wrappers --
# Back-compat facade over a lock-guarded per-directory registry.  New code
# should construct a Checkpointer (api.build_runtime does) — the registry
# exists so legacy callers keyed only by dir keep working without sharing
# unguarded global state.
_registry: dict = {}
_registry_lock = threading.Lock()


def _for_dir(ckpt_dir: str) -> Checkpointer:
    with _registry_lock:
        ck = _registry.get(ckpt_dir)
        if ck is None:
            ck = _registry[ckpt_dir] = Checkpointer(ckpt_dir)
        return ck


def save(ckpt_dir: str, step: int, tree: Any, *, async_: bool = True,
         extra: Optional[dict] = None):
    _for_dir(ckpt_dir).save(step, tree, async_=async_, extra=extra)


def wait(ckpt_dir: str):
    _for_dir(ckpt_dir).wait()


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step with a committed manifest (incomplete saves invisible)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
            continue
        try:
            s = int(name.split("_")[1])
        except ValueError:
            continue
        best = s if best is None else max(best, s)
    return best


def read_manifest(ckpt_dir: str, step: int) -> dict:
    return _for_dir(ckpt_dir).manifest(step)


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    out = []
    import jax.numpy as jnp
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        arr = _from_storable(arr, manifest["dtypes"][i])
        assert list(arr.shape) == list(ref.shape), f"leaf {i} shape mismatch"
        out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, like: Any):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, like)
