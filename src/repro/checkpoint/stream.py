"""StreamCheckpointer: epoch-consistent tick-boundary capture of a runtime.

Binds the storage substrate (``repro.checkpoint.checkpoint``) to the
streaming stack: at the tick boundary *before* tick S is dispatched it
captures

* the pipeline state — ScaleGate stash, watermark/epoch tables, and the
  (possibly mesh-sharded) per-instance state sigma — via
  ``pipeline.export_state()``, materialized to host **synchronously** so
  the very next dispatch may donate the device buffers;
* the ingest-tier cut for that exact boundary, when the source is an
  ``IngestTier`` — the tier's barrier "snap" round already pinned every
  leaf gate, the root gate, and the router's frontier/assignment to the
  boundary (``IngestTier.pop_snapshot``), so the assembled checkpoint is
  consistent across ingest hosts, the replicated root, and the sharded
  pipeline *by construction*, not by quiescing the stream.

The checkpoint's meaning: "state after every tick < S; resume the source
at ``source_ticks``".  Exactly-once restore = this state + replaying the
source from that frontier (``io.sources.ReplaySource.from_tick``) +
treating the victim's outputs below S as committed
(``CollectSink.results(before_tick=S)``).

The array tree goes through ``Checkpointer.save`` (async write, atomic
manifest commit); everything JSON-able — the serialized ``RuntimeConfig``,
stream dims, tier routing metadata — rides in the manifest's ``extra`` so
``api.resume_runtime`` can rebuild an *identical* stack before touching a
single ``.npy``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer


def _leaf_key(leaf_id: int) -> str:
    return f"{int(leaf_id):05d}"


class StreamCheckpointer:
    """Tick-boundary snapshots of ``pipeline`` (+ optional ingest ``tier``)
    into ``checkpointer``, every ``every`` pipeline ticks.

    With a tier, timing is *tier-driven*: the tier must be constructed with
    ``snapshot_every=every`` (``api.build_runtime`` does), and a checkpoint
    lands exactly when the tier produced the matching barrier cut — so with
    ``super_batch=K`` choose ``every`` a multiple of K, or boundary ticks
    land mid-group and the cut is skipped (never captured inconsistently).
    """

    def __init__(self, checkpointer: Checkpointer, every: int, pipeline,
                 tier=None, config=None):
        self.ckpt = checkpointer
        self.every = int(every)
        self.pipeline = pipeline
        self.tier = tier
        self.config = config          # RuntimeConfig (or None)
        self.saved_steps: List[int] = []

    # ----------------------------------------------------------- capture --
    def maybe_save(self, next_tick: int, frontier: np.ndarray) -> Optional[int]:
        """Called by the runtime at the boundary before dispatching tick
        ``next_tick`` (``frontier`` = host frontier before it).  Returns the
        step saved, or None when this boundary is not due."""
        tier_snap = None
        if self.tier is not None:
            tier_snap = self.tier.pop_snapshot(next_tick)
            if tier_snap is None:
                return None
        elif not (self.every > 0 and next_tick > 0
                  and next_tick % self.every == 0):
            return None
        # host copy NOW: the dispatch right after this call donates sg/sigma
        from repro import obs as _obs
        with _obs.span("checkpoint.capture"):
            pipe_np = jax.tree.map(np.asarray, self.pipeline.export_state())
        tree: Dict[str, Any] = {"pipe": pipe_np}
        stash = pipe_np["sg"].stash
        extra: Dict[str, Any] = {
            "step": int(next_tick),
            "kmax": int(stash.keys.shape[-1]),
            "payload_width": int(stash.payload.shape[-1]),
            "frontier": np.asarray(frontier, np.int64).tolist(),
            "source_ticks": int(next_tick),
            "config": (self.config.to_json()
                       if self.config is not None else None),
            "tier": None,
        }
        if tier_snap is not None:
            tree["tier"] = {
                "frontier": np.asarray(tier_snap["frontier"], np.int64),
                "leaves": {_leaf_key(lid): st
                           for lid, st in tier_snap["leaf_states"].items()},
                "root": tier_snap["root"]["sg"],
            }
            extra["source_ticks"] = int(tier_snap["source_ticks"])
            extra["tier"] = {
                "leaves": [int(l) for l in tier_snap["leaves"]],
                "assignment": [int(a) for a in tier_snap["assignment"]],
                "next_leaf_id": int(tier_snap["next_leaf_id"]),
                "source_ticks": int(tier_snap["source_ticks"]),
                "emitted_rounds": int(tier_snap["emitted_rounds"]),
                "tuples_in": int(tier_snap["tuples_in"]),
                "root_meta": tier_snap["root"]["meta"],
            }
        self.ckpt.save(int(next_tick), tree, async_=True, extra=extra)
        self.saved_steps.append(int(next_tick))
        _obs.event("checkpoint", step=int(next_tick),
                   tiered=tier_snap is not None)
        _obs.counter_inc("checkpoint.saves")
        return int(next_tick)

    def wait(self) -> None:
        self.ckpt.wait()


def like_tree(pipeline, extra: dict, *, n_sources: int, leaf_cap: int,
              root_cap: int, max_leaves: int, out_pad: int,
              root_device: bool) -> Dict[str, Any]:
    """A restore template matching what ``maybe_save`` wrote: the rebuilt
    pipeline's own exported state (``ensure_gate_for`` first so the gate
    shapes exist) plus zero-state ScaleGate templates for every tier gate
    recorded in the manifest ``extra``."""
    from repro.core import scalegate
    from repro.ingest.root import RootMerge

    kmax = int(extra["kmax"])
    pw = int(extra["payload_width"])
    pipeline.ensure_gate_for(kmax, pw)
    like: Dict[str, Any] = {
        "pipe": jax.tree.map(np.asarray, pipeline.export_state())}
    tmeta = extra.get("tier")
    if tmeta is not None:
        like["tier"] = {
            "frontier": np.zeros((n_sources,), np.int64),
            "leaves": {_leaf_key(lid): scalegate.template_np(
                n_sources, leaf_cap, kmax, pw)
                for lid in tmeta["leaves"]},
            "root": scalegate.template_np(
                max_leaves,
                RootMerge.effective_cap(root_cap, out_pad, root_device),
                kmax, pw),
        }
    return like


def tier_restore_dict(tree: Dict[str, Any], tmeta: dict) -> Dict[str, Any]:
    """Reassemble the ``IngestTier(restore=...)`` payload from a restored
    checkpoint tree + the manifest's tier metadata (all arrays to numpy:
    leaf states may cross a spawn-process boundary)."""
    t = jax.tree.map(np.asarray, tree["tier"])
    return {
        "leaves": [int(l) for l in tmeta["leaves"]],
        "assignment": [int(a) for a in tmeta["assignment"]],
        "next_leaf_id": int(tmeta["next_leaf_id"]),
        "frontier": np.asarray(t["frontier"], np.int64),
        "source_ticks": int(tmeta["source_ticks"]),
        "emitted_rounds": int(tmeta["emitted_rounds"]),
        "tuples_in": int(tmeta["tuples_in"]),
        "leaf_states": {int(k): v for k, v in t["leaves"].items()},
        "root": {"sg": t["root"], "meta": tmeta["root_meta"]},
    }
