"""Flight recorder: fixed-size ring buffer of structured runtime events.

Every event is a flat dict with a ``kind`` plus caller fields (tick ids,
watermarks, queue high-water marks, reconfig epochs, backpressure stalls,
leaf failures...), stamped with monotonic time ``t`` (perf_counter, for
intra-process ordering), ``wall`` (for cross-process ordering), ``pid`` and
thread name. The ring holds the last ``cap`` events; a crash or chaos-drill
failure dumps it to JSON so failures come with a timeline instead of a
stack trace.

Clock handshake: ``wall`` is *derived* — ``t + clock_offset`` with the
offset (``time.time() - time.perf_counter()``) captured once at recorder
construction — so a process's wall stamps inherit perf_counter's
monotonicity instead of time.time()'s step jitter.  A child process ships
its offset alongside drained events (``repro.obs.drain_payload`` attaches
``{"clock": {"pid", "offset"}}``); ``ingest`` renormalizes each shipped
event's ``wall`` from its raw ``t`` and the shipped offset, so the merged
timeline sorts monotonically across processes.

Dump format (``dump_json``)::

    {"dumped_unix": ..., "reason": "...", "pid": ...,
     "n_events": N, "events": [{"kind": ..., "t": ..., "wall": ...,
                                "pid": ..., "thread": ..., **fields}, ...],
     "exemplars": [...]}        # v2: per-tuple timelines when present
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional


class FlightRecorder:
    def __init__(self, cap: int = 4096, enabled: bool = True):
        self.enabled = enabled
        self.events: deque = deque(maxlen=cap)
        self._pid = os.getpid()
        # one-time perf->wall offset: wall stamps below are t + offset,
        # monotone within the process by construction
        self.clock_offset = time.time() - time.perf_counter()

    def record(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        t = time.perf_counter()
        fields["kind"] = kind
        fields["t"] = t
        fields["wall"] = t + self.clock_offset
        fields["pid"] = self._pid
        fields["thread"] = threading.current_thread().name
        self.events.append(fields)

    # -- cross-process shipping ---------------------------------------------
    def drain(self) -> List[Dict]:
        out = []
        while self.events:
            out.append(self.events.popleft())
        return out

    def ingest(self, events: List[Dict],
               clock_offset: Optional[float] = None) -> None:
        """Fold events shipped from a child process.  When the child's
        perf->wall ``clock_offset`` is known (shipped in the payload clock
        handshake), each event's ``wall`` is renormalized from its raw
        ``t`` — idempotent, and a no-op for legacy payloads without it."""
        if not self.enabled:
            return
        if clock_offset is not None:
            for e in events:
                if "t" in e:
                    e["wall"] = e["t"] + clock_offset
        self.events.extend(events)

    # -- export --------------------------------------------------------------
    def timeline(self) -> List[Dict]:
        """Events sorted by wall clock (stable across processes)."""
        return sorted(self.events, key=lambda e: e.get("wall", 0.0))

    def dump(self, reason: str = "on_demand",
             exemplars: Optional[List[Dict]] = None) -> Dict:
        d = {
            "dumped_unix": time.time(),
            "reason": reason,
            "pid": self._pid,
            "n_events": len(self.events),
            "events": self.timeline(),
        }
        if exemplars:
            d["exemplars"] = exemplars
        return d

    def dump_json(self, path: str, reason: str = "on_demand",
                  exemplars: Optional[List[Dict]] = None) -> str:
        """Write the ring to ``path`` (dirs created); returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.dump(reason, exemplars=exemplars), f, indent=1,
                      default=repr)
        return path
