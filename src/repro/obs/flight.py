"""Flight recorder: fixed-size ring buffer of structured runtime events.

Every event is a flat dict with a ``kind`` plus caller fields (tick ids,
watermarks, queue high-water marks, reconfig epochs, backpressure stalls,
leaf failures...), stamped with monotonic time ``t`` (perf_counter, for
intra-process ordering), ``wall`` (time.time, for cross-process ordering —
child processes have different perf_counter origins), ``pid`` and thread
name. The ring holds the last ``cap`` events; a crash or chaos-drill
failure dumps it to JSON so failures come with a timeline instead of a
stack trace.

Dump format (``dump_json``)::

    {"dumped_unix": ..., "reason": "...", "pid": ...,
     "n_events": N, "events": [{"kind": ..., "t": ..., "wall": ...,
                                "pid": ..., "thread": ..., **fields}, ...]}
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional


class FlightRecorder:
    def __init__(self, cap: int = 4096, enabled: bool = True):
        self.enabled = enabled
        self.events: deque = deque(maxlen=cap)
        self._pid = os.getpid()

    def record(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        fields["kind"] = kind
        fields["t"] = time.perf_counter()
        fields["wall"] = time.time()
        fields["pid"] = self._pid
        fields["thread"] = threading.current_thread().name
        self.events.append(fields)

    # -- cross-process shipping ---------------------------------------------
    def drain(self) -> List[Dict]:
        out = []
        while self.events:
            out.append(self.events.popleft())
        return out

    def ingest(self, events: List[Dict]) -> None:
        if not self.enabled:
            return
        self.events.extend(events)

    # -- export --------------------------------------------------------------
    def timeline(self) -> List[Dict]:
        """Events sorted by wall clock (stable across processes)."""
        return sorted(self.events, key=lambda e: e.get("wall", 0.0))

    def dump(self, reason: str = "on_demand") -> Dict:
        return {
            "dumped_unix": time.time(),
            "reason": reason,
            "pid": self._pid,
            "n_events": len(self.events),
            "events": self.timeline(),
        }

    def dump_json(self, path: str, reason: str = "on_demand") -> str:
        """Write the ring to ``path`` (dirs created); returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.dump(reason), f, indent=1, default=repr)
        return path
