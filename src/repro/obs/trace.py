"""Span tracer: nested spans, per-stage latency quantiles, cross-process
propagation.

A span is opened with ``Tracer.span(name)`` (context manager). On close it
(1) folds its duration into the registry histogram ``span.<name>`` — the
per-tick stage-latency breakdown the controller/serving tier reads — and
(2) appends a finished-span record to a bounded ring for export/debug.
Nesting is tracked per-thread: the parent name is joined into the record so
a dump reads ``runtime.dispatch/pipeline.step``.

Disabled cost: when the tracer is off, ``span()`` returns a singleton
null context manager — one attribute load + two no-op calls, no
allocation — so instrumented hot paths stay within the <2% gate.

Cross-process: a child tracer's finished spans are shipped as plain dicts
(``drain()``) over the ingest channels and folded into the parent with
``ingest()`` (durations re-observed into the parent registry, records
tagged with the child pid).
"""

from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional

from .registry import MetricsRegistry


class _NullSpan:
    """Singleton no-op context manager returned when tracing is off."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "path", "t0", "_local")

    def __init__(self, tracer: "Tracer", name: str, local):
        self.tracer = tracer
        self.name = name
        self._local = local
        parent = local.stack[-1].path if local.stack else ""
        self.path = f"{parent}/{name}" if parent else name
        self.t0 = 0.0

    def __enter__(self):
        self._local.stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        stack = self._local.stack
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._finish(self, dur)
        return False


class Tracer:
    """Per-process span tracer writing into a shared MetricsRegistry."""

    def __init__(self, registry: MetricsRegistry, enabled: bool = True,
                 span_cap: int = 2048, sampler=None):
        self.registry = registry
        self.enabled = enabled
        self.finished: deque = deque(maxlen=span_cap)
        self._tls = threading.local()
        self._pid = os.getpid()
        # optional HeadSampler: thins the finished-record ring only —
        # the span.* histogram observation below always runs, so stage
        # quantiles stay exact under sampling
        self.sampler = sampler

    def _local(self):
        local = self._tls
        if not hasattr(local, "stack"):
            local.stack = []
        return local

    def span(self, name: str):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, self._local())

    def _finish(self, span: _Span, dur: float) -> None:
        self.registry.observe(f"span.{span.name}", dur)
        if self.sampler is not None and not self.sampler.admit_span(
                span.name):
            return
        self.finished.append({
            "name": span.name,
            "path": span.path,
            "dur_s": dur,
            "t_end": time.perf_counter(),
            "wall_end": time.time(),
            "pid": self._pid,
        })

    # -- cross-process shipping ---------------------------------------------
    def drain(self) -> List[Dict]:
        """Pop all finished-span records (child-side shipping)."""
        out = []
        while self.finished:
            out.append(self.finished.popleft())
        return out

    def ingest(self, spans: List[Dict],
               wall_offset: float = 0.0) -> None:
        """Fold spans shipped from a child process into this tracer:
        re-observe durations into the registry and keep the records.
        ``wall_offset`` (parent_wall - child_wall at handshake) shifts the
        child's ``wall_end`` stamps into the parent clock domain so merged
        timelines sort monotonically."""
        for s in spans:
            self.registry.observe(f"span.{s['name']}", s["dur_s"])
            if wall_offset and "wall_end" in s:
                s["wall_end"] = s["wall_end"] + wall_offset
            self.finished.append(s)

    def stage_latency_ms(self) -> Dict[str, Dict[str, float]]:
        """Per-stage latency breakdown {stage: {p50,p90,p99,mean}} in ms,
        derived from the span.* histograms."""
        out = {}
        for name, h in sorted(self.registry.histograms.items()):
            if not name.startswith("span.") or h.count == 0:
                continue
            out[name[len("span."):]] = {
                "p50": h.quantile(0.50) * 1e3,
                "p90": h.quantile(0.90) * 1e3,
                "p99": h.quantile(0.99) * 1e3,
                "mean": h.sum / h.count * 1e3,
                "count": float(h.count),
            }
        return out
