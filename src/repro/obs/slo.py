"""SLO rule engine over registry quantile sketches.

Rules evaluate *windowed* views of a registry histogram (e.g. the p99 of
``span.runtime.drain`` over the last 30s) rather than full-run quantiles,
so a latency regression mid-run breaches promptly instead of being diluted
by a long healthy prefix.  Windowing works on the sketch itself: the engine
keeps a short deque of (timestamp, bucket-counts) snapshots per rule and
evaluates quantiles over the bucket-count *deltas* inside the window —
O(buckets) per evaluation, no per-observation state.

Two rule kinds:

- ``threshold``: windowed q-quantile of the metric > ``threshold``.
- ``burn_rate``: the fraction of windowed observations above ``threshold``
  divided by the error ``budget`` (allowed violating fraction) must stay
  below ``burn_limit`` — the standard burn-rate alert shape (a burn rate
  of 1.0 consumes exactly the budget; >1 burns it faster).

Breaches are recorded as (unsampled) flight events + ``slo.breach.*``
counters, trigger a cooldown-gated flight dump, and are surfaced to the
runtime so ``LiveMetrics.slo_breaches`` reaches
``controller.observe_live`` — closing the signal→reaction loop.
"""

from __future__ import annotations

import bisect
import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional

from .registry import BUCKET_BOUNDS, _N_BUCKETS, MetricsRegistry


@dataclass
class SloRule:
    """One SLO rule over a registry histogram (JSON-serializable)."""
    name: str
    metric: str                       # histogram name, e.g. "span.runtime.drain"
    threshold: float                  # seconds (or metric unit)
    kind: str = "threshold"           # "threshold" | "burn_rate"
    quantile: float = 0.99            # threshold rules: windowed quantile
    window_s: float = 30.0
    budget: float = 0.01              # burn_rate: allowed violating fraction
    burn_limit: float = 1.0           # burn_rate: breach when burn >= limit
    min_count: int = 8                # min windowed observations to evaluate
    cooldown_s: float = 5.0           # min seconds between breaches

    def to_dict(self) -> Dict:
        return {
            "name": self.name, "metric": self.metric,
            "threshold": self.threshold, "kind": self.kind,
            "quantile": self.quantile, "window_s": self.window_s,
            "budget": self.budget, "burn_limit": self.burn_limit,
            "min_count": self.min_count, "cooldown_s": self.cooldown_s,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "SloRule":
        names = {f for f in cls.__dataclass_fields__}  # type: ignore[attr-defined]
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclass(frozen=True)
class SloBreach:
    """One breach observation handed to ``controller.observe_live``."""
    rule: str
    metric: str
    kind: str
    value: float                      # observed quantile / burn rate
    threshold: float                  # breached limit (threshold / burn_limit)
    t: float                          # wall time of detection

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "metric": self.metric, "kind": self.kind,
                "value": self.value, "threshold": self.threshold,
                "t": self.t}


class _RuleState:
    __slots__ = ("rule", "window", "last_breach_t", "breaches")

    def __init__(self, rule: SloRule):
        self.rule = rule
        # (t, counts-copy, count) snapshots bounding the rule's window
        self.window: deque = deque()
        self.last_breach_t = -math.inf
        self.breaches = 0


def _windowed_quantile(deltas: List[int], total: int, q: float) -> float:
    """q-quantile (geometric bucket midpoint) over bucket-count deltas."""
    rank = max(1, math.ceil(q * total))
    acc = 0
    for i, c in enumerate(deltas):
        acc += c
        if acc >= rank:
            if i == 0:
                return BUCKET_BOUNDS[0]
            if i >= _N_BUCKETS:
                return BUCKET_BOUNDS[-1]
            return math.sqrt(BUCKET_BOUNDS[i - 1] * BUCKET_BOUNDS[i])
    return BUCKET_BOUNDS[-1]           # pragma: no cover


def _violating_fraction(deltas: List[int], total: int,
                        threshold: float) -> float:
    """Fraction of windowed observations whose bucket lies above the
    threshold (bucket granularity: the bucket containing the threshold
    counts as violating only above its upper bound)."""
    first_bad = bisect.bisect_right(BUCKET_BOUNDS, threshold)
    bad = sum(deltas[first_bad:])
    return bad / total


class SloEngine:
    """Evaluates a set of ``SloRule``s against one registry."""

    def __init__(self, rules: List[SloRule]):
        self._states = [_RuleState(r) for r in rules]
        self.total_breaches = 0

    @classmethod
    def from_dicts(cls, dicts: List[Dict]) -> "SloEngine":
        return cls([SloRule.from_dict(d) for d in dicts])

    @property
    def rules(self) -> List[SloRule]:
        return [st.rule for st in self._states]

    def evaluate(self, registry: MetricsRegistry,
                 now: Optional[float] = None) -> List[SloBreach]:
        """Evaluate every rule once; returns new breaches (cooldown-gated
        per rule).  Cheap when metrics are absent or under min_count."""
        t = time.time() if now is None else now
        breaches: List[SloBreach] = []
        for st in self._states:
            rule = st.rule
            h = registry.histograms.get(rule.metric)
            if h is None or h.count == 0:
                continue
            # append the current sketch state, expire beyond the window
            st.window.append((t, list(h.counts), h.count))
            while (len(st.window) > 2
                   and t - st.window[1][0] > rule.window_s):
                st.window.popleft()
            base_t, base_counts, base_count = st.window[0]
            n = h.count - base_count
            if len(st.window) == 1 or t - base_t > 4 * rule.window_s:
                # first sight of this metric (no in-window baseline yet):
                # fall back to the full-sketch view so a run shorter than
                # the window still evaluates
                base_counts = [0] * len(h.counts)
                n = h.count
            if n < rule.min_count:
                continue
            deltas = [c - b for c, b in zip(h.counts, base_counts)]
            if rule.kind == "burn_rate":
                frac = _violating_fraction(deltas, n, rule.threshold)
                burn = frac / max(rule.budget, 1e-12)
                breached = burn >= rule.burn_limit
                value, limit = burn, rule.burn_limit
            else:
                value = _windowed_quantile(deltas, n, rule.quantile)
                breached = value > rule.threshold
                limit = rule.threshold
            if breached and t - st.last_breach_t >= rule.cooldown_s:
                st.last_breach_t = t
                st.breaches += 1
                self.total_breaches += 1
                breaches.append(SloBreach(
                    rule=rule.name, metric=rule.metric, kind=rule.kind,
                    value=float(value), threshold=float(limit), t=t))
        return breaches

    def snapshot(self) -> Dict:
        """Per-rule breach totals (mirrored into the registry by Obs)."""
        return {st.rule.name: {"breaches": st.breaches,
                               "metric": st.rule.metric,
                               "kind": st.rule.kind}
                for st in self._states}
