"""MetricsRegistry: counters, gauges, and quantile-sketch histograms.

One registry instance backs the whole observability layer: the tracer folds
span durations into it, the flight recorder and ``MetricsBus`` mirror their
counts into it, and the export side renders it as a *versioned-schema*
snapshot — JSON (``snapshot()``, validated by ``validate_snapshot``) and
Prometheus text exposition (``to_prometheus()``).

The histogram is a geometric-bucket sketch (ratio 2^(1/8) ≈ 9% bucket
width, quantile error ≤ ~4.5% after midpoint interpolation): recording is
O(1) (one ``bisect`` over a precomputed bound table), memory is fixed, and
a long run never grows state — the property ``MetricsBus`` leans on to cap
its per-tick retention while keeping exact totals and full-run quantiles.

Cross-process: a child registry ships counter *deltas* (``drain_counters``)
over the ingest channels; the parent folds them in with
``merge_counters`` — see ``repro.obs.__init__.drain_payload``.
"""

from __future__ import annotations

import bisect
import math
import threading
import time
from typing import Dict, List, Optional

SCHEMA_VERSION = 1

# geometric bucket bounds: 1e-7 .. ~1.8e5, ratio 2**(1/8)  (~324 buckets)
_RATIO = 2.0 ** 0.125
_N_BUCKETS = 324
BUCKET_BOUNDS: List[float] = [1e-7 * _RATIO ** i for i in range(_N_BUCKETS)]


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-memory geometric-bucket quantile sketch over values > 0
    (zero/negative values land in the first bucket).  Unit-agnostic."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * (_N_BUCKETS + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        self.counts[bisect.bisect_left(BUCKET_BOUNDS, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Approximate q-quantile: geometric midpoint of the bucket holding
        rank ceil(q * count), clamped to the observed [min, max]."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                if i == 0:
                    mid = BUCKET_BOUNDS[0]
                elif i >= _N_BUCKETS:
                    mid = BUCKET_BOUNDS[-1]
                else:
                    mid = math.sqrt(BUCKET_BOUNDS[i - 1] * BUCKET_BOUNDS[i])
                return min(max(mid, self.min), self.max)
        return self.max                            # pragma: no cover

    def merge(self, other: "Histogram") -> None:
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


class MetricsRegistry:
    """Named counters/gauges/histograms with get-or-create accessors.
    Mutators are GIL-atomic on the instrument objects; creation takes a
    lock (instruments are created once, updated hot)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self._shipped: Dict[str, float] = {}    # drain_counters watermark

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            with self._lock:
                c = self.counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            with self._lock:
                g = self.gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            with self._lock:
                h = self.histograms.setdefault(name, Histogram())
        return h

    # convenience mutators (the instrumented call sites use these)
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    # -- cross-process shipping ---------------------------------------------
    def drain_counters(self) -> Dict[str, float]:
        """Counter deltas since the last drain (child-side shipping)."""
        out = {}
        for name, c in list(self.counters.items()):
            delta = c.value - self._shipped.get(name, 0.0)
            if delta:
                out[name] = delta
                self._shipped[name] = c.value
        return out

    def merge_counters(self, deltas: Dict[str, float]) -> None:
        for name, d in deltas.items():
            self.counter(name).inc(d)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict:
        """The versioned-schema metrics snapshot (see ``snapshot_schema``)."""
        hists = {}
        for name, h in sorted(self.histograms.items()):
            hists[name] = {
                "count": h.count,
                "sum": h.sum,
                "min": (0.0 if h.count == 0 else h.min),
                "max": (0.0 if h.count == 0 else h.max),
                "p50": h.quantile(0.50),
                "p90": h.quantile(0.90),
                "p99": h.quantile(0.99),
            }
        return {
            "schema_version": SCHEMA_VERSION,
            "generated_unix": time.time(),
            "counters": {n: c.value for n, c in sorted(self.counters.items())},
            "gauges": {n: g.value for n, g in sorted(self.gauges.items())},
            "histograms": hists,
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition of the same registry state (metric
        names sanitized: dots/dashes become underscores)."""
        def sane(name: str) -> str:
            return "".join(ch if (ch.isalnum() or ch == "_") else "_"
                           for ch in name)

        lines = []
        for name, c in sorted(self.counters.items()):
            n = sane(name)
            lines += [f"# TYPE {n} counter", f"{n} {c.value:g}"]
        for name, g in sorted(self.gauges.items()):
            n = sane(name)
            lines += [f"# TYPE {n} gauge", f"{n} {g.value:g}"]
        for name, h in sorted(self.histograms.items()):
            n = sane(name)
            lines += [f"# TYPE {n} summary",
                      f"{n}_count {h.count}", f"{n}_sum {h.sum:g}"]
            for q in (0.50, 0.90, 0.99):
                lines.append(f'{n}{{quantile="{q}"}} {h.quantile(q):g}')
        return "\n".join(lines) + "\n"


# ------------------------------------------------------- schema contract --

_HIST_KEYS = ("count", "sum", "min", "max", "p50", "p90", "p99")


def snapshot_schema() -> Dict:
    """JSON-Schema document for ``MetricsRegistry.snapshot()`` — committed
    behavior: bump ``SCHEMA_VERSION`` on any breaking change."""
    num = {"type": "number"}
    return {
        "$schema": "http://json-schema.org/draft-07/schema#",
        "title": f"repro.obs metrics snapshot v{SCHEMA_VERSION}",
        "type": "object",
        "required": ["schema_version", "generated_unix", "counters",
                     "gauges", "histograms"],
        "properties": {
            "schema_version": {"type": "integer", "const": SCHEMA_VERSION},
            "generated_unix": num,
            "counters": {"type": "object", "additionalProperties": num},
            "gauges": {"type": "object", "additionalProperties": num},
            "histograms": {
                "type": "object",
                "additionalProperties": {
                    "type": "object",
                    "required": list(_HIST_KEYS),
                    "properties": {k: num for k in _HIST_KEYS},
                },
            },
        },
    }


def validate_snapshot(snap: Dict) -> None:
    """Structural validation of a snapshot against the schema contract
    (dependency-free implementation of exactly what ``snapshot_schema``
    declares; raises ``ValueError`` on the first violation)."""
    if not isinstance(snap, dict):
        raise ValueError(f"snapshot must be an object, got {type(snap)}")
    for key in ("schema_version", "generated_unix", "counters", "gauges",
                "histograms"):
        if key not in snap:
            raise ValueError(f"snapshot missing required key {key!r}")
    if snap["schema_version"] != SCHEMA_VERSION:
        raise ValueError(f"schema_version {snap['schema_version']!r} != "
                         f"{SCHEMA_VERSION}")
    if not isinstance(snap["generated_unix"], (int, float)):
        raise ValueError("generated_unix must be a number")
    for section in ("counters", "gauges"):
        if not isinstance(snap[section], dict):
            raise ValueError(f"{section} must be an object")
        for name, v in snap[section].items():
            if not isinstance(v, (int, float)):
                raise ValueError(f"{section}[{name!r}] must be a number, "
                                 f"got {type(v)}")
    if not isinstance(snap["histograms"], dict):
        raise ValueError("histograms must be an object")
    for name, h in snap["histograms"].items():
        if not isinstance(h, dict):
            raise ValueError(f"histograms[{name!r}] must be an object")
        for k in _HIST_KEYS:
            if k not in h:
                raise ValueError(f"histograms[{name!r}] missing {k!r}")
            if not isinstance(h[k], (int, float)):
                raise ValueError(f"histograms[{name!r}][{k!r}] must be a "
                                 f"number")
