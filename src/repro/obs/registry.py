"""MetricsRegistry: counters, gauges, and quantile-sketch histograms.

One registry instance backs the whole observability layer: the tracer folds
span durations into it, the flight recorder and ``MetricsBus`` mirror their
counts into it, and the export side renders it as a *versioned-schema*
snapshot — JSON (``snapshot()``, validated by ``validate_snapshot``) and
Prometheus text exposition (``to_prometheus()``).

The histogram is a geometric-bucket sketch (ratio 2^(1/8) ≈ 9% bucket
width, quantile error ≤ ~4.5% after midpoint interpolation): recording is
O(1) (one ``bisect`` over a precomputed bound table), memory is fixed, and
a long run never grows state — the property ``MetricsBus`` leans on to cap
its per-tick retention while keeping exact totals and full-run quantiles.

Cross-process: a child registry ships counter *deltas* (``drain_counters``)
over the ingest channels; the parent folds them in with
``merge_counters`` — see ``repro.obs.__init__.drain_payload``.
"""

from __future__ import annotations

import bisect
import math
import threading
import time
from typing import Dict, List, Optional

SCHEMA_VERSION = 2
# v1: counters/gauges/histograms.  v2 adds "sampling" (head-sampler
# metadata) and "exemplars" (per-tuple timelines); v1 payloads still
# validate (the new sections are optional for schema_version == 1).
_LEGACY_SCHEMA_VERSIONS = (1,)

# geometric bucket bounds: 1e-7 .. ~1.8e5, ratio 2**(1/8)  (~324 buckets)
_RATIO = 2.0 ** 0.125
_N_BUCKETS = 324
BUCKET_BOUNDS: List[float] = [1e-7 * _RATIO ** i for i in range(_N_BUCKETS)]


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-memory geometric-bucket quantile sketch over values > 0
    (zero/negative values land in the first bucket).  Unit-agnostic."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self):
        self.counts = [0] * (_N_BUCKETS + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v: float) -> None:
        self.counts[bisect.bisect_left(BUCKET_BOUNDS, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        """Approximate q-quantile: geometric midpoint of the bucket holding
        rank ceil(q * count), clamped to the observed [min, max]."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                if i == 0:
                    mid = BUCKET_BOUNDS[0]
                elif i >= _N_BUCKETS:
                    mid = BUCKET_BOUNDS[-1]
                else:
                    mid = math.sqrt(BUCKET_BOUNDS[i - 1] * BUCKET_BOUNDS[i])
                return min(max(mid, self.min), self.max)
        return self.max                            # pragma: no cover

    def merge(self, other: "Histogram") -> None:
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)


class MetricsRegistry:
    """Named counters/gauges/histograms with get-or-create accessors.
    Mutators are GIL-atomic on the instrument objects; creation takes a
    lock (instruments are created once, updated hot)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self._shipped: Dict[str, float] = {}    # drain_counters watermark

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            with self._lock:
                c = self.counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            with self._lock:
                g = self.gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            with self._lock:
                h = self.histograms.setdefault(name, Histogram())
        return h

    # convenience mutators (the instrumented call sites use these)
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).record(v)

    # -- cross-process shipping ---------------------------------------------
    def drain_counters(self) -> Dict[str, float]:
        """Counter deltas since the last drain (child-side shipping)."""
        out = {}
        for name, c in list(self.counters.items()):
            delta = c.value - self._shipped.get(name, 0.0)
            if delta:
                out[name] = delta
                self._shipped[name] = c.value
        return out

    def merge_counters(self, deltas: Dict[str, float]) -> None:
        for name, d in deltas.items():
            self.counter(name).inc(d)

    # -- export --------------------------------------------------------------
    def snapshot(self, sampling: Optional[Dict] = None,
                 exemplars: Optional[List] = None) -> Dict:
        """The versioned-schema metrics snapshot (see ``snapshot_schema``).

        Taken under the registry lock so an in-run scrape never sees a
        torn instrument table; GIL-atomic mutators keep individual values
        coherent and counters monotone across scrapes.  ``sampling`` /
        ``exemplars`` are the v2 sections filled in by ``Obs.snapshot``
        (defaults keep a bare-registry snapshot schema-valid).
        """
        with self._lock:
            hists = {}
            for name, h in sorted(self.histograms.items()):
                hists[name] = {
                    "count": h.count,
                    "sum": h.sum,
                    "min": (0.0 if h.count == 0 else h.min),
                    "max": (0.0 if h.count == 0 else h.max),
                    "p50": h.quantile(0.50),
                    "p90": h.quantile(0.90),
                    "p99": h.quantile(0.99),
                }
            counters = {n: c.value for n, c in sorted(self.counters.items())}
            gauges = {n: g.value for n, g in sorted(self.gauges.items())}
        return {
            "schema_version": SCHEMA_VERSION,
            "generated_unix": time.time(),
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
            "sampling": dict(sampling) if sampling else {},
            "exemplars": list(exemplars) if exemplars else [],
        }

    def to_prometheus(self, sampling: Optional[Dict] = None) -> str:
        """Prometheus text exposition of the same registry state (metric
        names sanitized; HELP strings and label values escaped per the
        text-format spec; every family — including histogram sketches,
        rendered as summaries — carries a ``# TYPE`` line).  ``sampling``
        metadata (from the head sampler) renders as labeled
        ``obs_sampled_total{kind=...,what=...}`` series."""
        lines = []
        with self._lock:
            counters = sorted((n, c.value) for n, c in self.counters.items())
            gauges = sorted((n, g.value) for n, g in self.gauges.items())
            hists = []
            for name, h in sorted(self.histograms.items()):
                hists.append((name, h.count, h.sum,
                              [(q, h.quantile(q)) for q in (0.50, 0.90,
                                                            0.99)]))
        for name, v in counters:
            n = _sane_metric_name(name)
            lines += [f"# HELP {n} {_escape_help(f'counter {name}')}",
                      f"# TYPE {n} counter", f"{n} {v:g}"]
        for name, v in gauges:
            n = _sane_metric_name(name)
            lines += [f"# HELP {n} {_escape_help(f'gauge {name}')}",
                      f"# TYPE {n} gauge", f"{n} {v:g}"]
        for name, count, total, quants in hists:
            n = _sane_metric_name(name)
            lines += [f"# HELP {n} "
                      f"{_escape_help(f'quantile sketch {name}')}",
                      f"# TYPE {n} summary",
                      f"{n}_count {count}", f"{n}_sum {total:g}"]
            for q, qv in quants:
                lines.append(
                    f'{n}{{quantile="{_escape_label_value(str(q))}"}} '
                    f"{qv:g}")
        if sampling:
            lines += ["# HELP obs_sampled_total exact attempt/kept totals "
                      "per sampled kind",
                      "# TYPE obs_sampled_total counter"]
            for what in ("events", "spans"):
                for kind, st in sorted(sampling.get(what, {}).items()):
                    k = _escape_label_value(kind)
                    w = _escape_label_value(what)
                    lines.append(f'obs_sampled_total{{what="{w}",'
                                 f'kind="{k}",outcome="attempted"}} '
                                 f'{st["attempts"]:g}')
                    lines.append(f'obs_sampled_total{{what="{w}",'
                                 f'kind="{k}",outcome="kept"}} '
                                 f'{st["kept"]:g}')
        return "\n".join(lines) + "\n"


def _sane_metric_name(name: str) -> str:
    return "".join(ch if (ch.isalnum() or ch == "_") else "_"
                   for ch in name)


def _escape_help(s: str) -> str:
    """HELP-string escaping per the Prometheus text format: backslash and
    newline only."""
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label_value(s: str) -> str:
    """Label-value escaping per the Prometheus text format: backslash,
    double-quote, and newline."""
    return (s.replace("\\", "\\\\").replace('"', '\\"')
             .replace("\n", "\\n"))


# ------------------------------------------------------- schema contract --

_HIST_KEYS = ("count", "sum", "min", "max", "p50", "p90", "p99")


def snapshot_schema() -> Dict:
    """JSON-Schema document for ``MetricsRegistry.snapshot()`` — committed
    behavior: bump ``SCHEMA_VERSION`` on any breaking change.

    v2 adds ``sampling`` (head-sampler metadata object) and ``exemplars``
    (array of per-tuple timelines).  v1 snapshots — which lack both —
    still validate for cross-process folding of payloads produced by
    older children; see ``validate_snapshot``.
    """
    num = {"type": "number"}
    return {
        "$schema": "http://json-schema.org/draft-07/schema#",
        "title": f"repro.obs metrics snapshot v{SCHEMA_VERSION}",
        "type": "object",
        "required": ["schema_version", "generated_unix", "counters",
                     "gauges", "histograms", "sampling", "exemplars"],
        "properties": {
            "schema_version": {
                "type": "integer",
                "enum": sorted((*_LEGACY_SCHEMA_VERSIONS, SCHEMA_VERSION)),
            },
            "generated_unix": num,
            "counters": {"type": "object", "additionalProperties": num},
            "gauges": {"type": "object", "additionalProperties": num},
            "histograms": {
                "type": "object",
                "additionalProperties": {
                    "type": "object",
                    "required": list(_HIST_KEYS),
                    "properties": {k: num for k in _HIST_KEYS},
                },
            },
            "sampling": {"type": "object"},
            "exemplars": {"type": "array"},
        },
    }


def validate_snapshot(snap: Dict) -> None:
    """Structural validation of a snapshot against the schema contract
    (dependency-free implementation of exactly what ``snapshot_schema``
    declares; raises ``ValueError`` on the first violation).

    Accepts the current version and the legacy v1 layout (for which the
    v2-only ``sampling``/``exemplars`` sections are optional)."""
    if not isinstance(snap, dict):
        raise ValueError(f"snapshot must be an object, got {type(snap)}")
    for key in ("schema_version", "generated_unix", "counters", "gauges",
                "histograms"):
        if key not in snap:
            raise ValueError(f"snapshot missing required key {key!r}")
    version = snap["schema_version"]
    if version != SCHEMA_VERSION and version not in _LEGACY_SCHEMA_VERSIONS:
        raise ValueError(f"schema_version {version!r} not in "
                         f"{(*_LEGACY_SCHEMA_VERSIONS, SCHEMA_VERSION)}")
    if not isinstance(snap["generated_unix"], (int, float)):
        raise ValueError("generated_unix must be a number")
    if version >= 2:
        for key in ("sampling", "exemplars"):
            if key not in snap:
                raise ValueError(f"v{version} snapshot missing required "
                                 f"key {key!r}")
    if "sampling" in snap and not isinstance(snap["sampling"], dict):
        raise ValueError("sampling must be an object")
    if "exemplars" in snap and not isinstance(snap["exemplars"], list):
        raise ValueError("exemplars must be an array")
    for section in ("counters", "gauges"):
        if not isinstance(snap[section], dict):
            raise ValueError(f"{section} must be an object")
        for name, v in snap[section].items():
            if not isinstance(v, (int, float)):
                raise ValueError(f"{section}[{name!r}] must be a number, "
                                 f"got {type(v)}")
    if not isinstance(snap["histograms"], dict):
        raise ValueError("histograms must be an object")
    for name, h in snap["histograms"].items():
        if not isinstance(h, dict):
            raise ValueError(f"histograms[{name!r}] must be an object")
        for k in _HIST_KEYS:
            if k not in h:
                raise ValueError(f"histograms[{name!r}] missing {k!r}")
            if not isinstance(h[k], (int, float)):
                raise ValueError(f"histograms[{name!r}][{k!r}] must be a "
                                 f"number")
