"""In-run HTTP scrape endpoint for the observability plane.

A stdlib ``ThreadingHTTPServer`` on a daemon thread serving the live
registry while ticks are in flight:

- ``/metrics`` and ``/metrics.prom`` — Prometheus text exposition
  (``text/plain; version=0.0.4``).
- ``/metrics.json`` and ``/snapshot`` — the versioned JSON snapshot
  (schema v2: counters/gauges/histograms + sampling metadata + exemplar
  timelines).
- ``/healthz`` — liveness probe (``ok``).

Consistency: both renderers go through ``Obs.snapshot()`` /
``MetricsRegistry.to_prometheus()``, which hold the registry lock while
iterating, so a scrape never observes a torn instrument table and exact
counters are monotone non-decreasing across scrapes.  Port 0 binds an
ephemeral port; the bound port is exposed as ``ObsServer.port``.

The server is deliberately tiny: no auth, no TLS, bound to localhost by
default — it is a development/CI scrape surface, not a public API.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

_PROM_CTYPE = "text/plain; version=0.0.4; charset=utf-8"
_JSON_CTYPE = "application/json"


class ObsServer:
    """Threaded scrape endpoint over one ``Obs`` instance."""

    def __init__(self, obs, port: int = 0, host: str = "127.0.0.1"):
        self.obs = obs
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            # silence per-request stderr logging (scrapes are hot-path)
            def log_message(self, fmt, *args):  # noqa: D102
                pass

            def _reply(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802
                path = self.path.split("?", 1)[0]
                try:
                    if path in ("/metrics", "/metrics.prom"):
                        body = outer.obs.to_prometheus().encode()
                        self._reply(200, body, _PROM_CTYPE)
                    elif path in ("/metrics.json", "/snapshot"):
                        body = json.dumps(outer.obs.snapshot(),
                                          default=repr).encode()
                        self._reply(200, body, _JSON_CTYPE)
                    elif path == "/healthz":
                        self._reply(200, b"ok\n", "text/plain")
                    else:
                        self._reply(404, b"not found\n", "text/plain")
                except (BrokenPipeError, ConnectionResetError):
                    pass               # scraper went away mid-reply

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "ObsServer":
        t = threading.Thread(target=self._httpd.serve_forever,
                             kwargs={"poll_interval": 0.1},
                             name=f"obs-serve-{self.port}", daemon=True)
        t.start()
        self._thread = t
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"
