"""repro.obs — unified observability: tracing, flight recorder, metrics.

One ``Obs`` object bundles the three instruments sharing a registry:

- ``obs.registry`` — counters / gauges / quantile-sketch histograms with a
  versioned-schema snapshot (JSON + Prometheus text); see ``registry.py``.
- ``obs.tracer`` — nested spans; per-stage latency quantiles land in
  ``span.*`` histograms; see ``trace.py``.
- ``obs.flight`` — ring buffer of structured events, JSON-dumped on
  crash/chaos failure or on demand; see ``flight.py``.

A process-global current ``Obs`` is installed with ``install(ObsConfig)``
(or ``set_current`` for an existing instance). Instrumented call sites use
the module-level helpers ``span()`` / ``event()`` / ``counter_inc()`` /
``gauge_set()``: when nothing is installed (the default) they are a single
global load + ``is None`` test, so the off path costs nanoseconds.

Cross-process propagation: a child ingest-leaf process installs its own
``Obs`` (config travels in the worker cfg dict), instruments locally, and
ships ``drain_payload()`` dicts piggybacked on ``LeafOut.obs`` over the
existing channels; the parent folds them in with ``ingest_payload()``.
Thread-mode leaves share the parent's global ``Obs`` directly and must
*not* ship payloads (that would double-count).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .registry import (MetricsRegistry, SCHEMA_VERSION, snapshot_schema,
                       validate_snapshot)
from .trace import Tracer, _NULL_SPAN
from .flight import FlightRecorder

__all__ = [
    "ObsConfig", "Obs", "install", "get", "set_current",
    "span", "event", "counter_inc", "gauge_set", "observe",
    "drain_payload", "ingest_payload",
    "MetricsRegistry", "Tracer", "FlightRecorder",
    "SCHEMA_VERSION", "snapshot_schema", "validate_snapshot",
]


@dataclass
class ObsConfig:
    """Observability knobs carried by ``RuntimeConfig`` (JSON-serializable).

    ``enabled`` turns the layer on (registry + flight recorder); ``trace``
    additionally turns on span timing — the separately-gated cost tier
    (<2% without, <10% with, per the q1 bench row). ``dump_dir`` set makes
    the runtime dump the flight ring there on crash; ``export_dir`` set
    makes ``Runtime.run``/launchers write ``metrics.json`` +
    ``metrics.prom`` there on completion.
    """
    enabled: bool = False
    trace: bool = False
    flight: bool = True
    flight_cap: int = 4096
    span_cap: int = 2048
    dump_dir: Optional[str] = None
    export_dir: Optional[str] = None

    def to_dict(self) -> Dict:
        return {
            "enabled": self.enabled, "trace": self.trace,
            "flight": self.flight, "flight_cap": self.flight_cap,
            "span_cap": self.span_cap, "dump_dir": self.dump_dir,
            "export_dir": self.export_dir,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ObsConfig":
        names = {f for f in cls.__dataclass_fields__}  # type: ignore[attr-defined]
        return cls(**{k: v for k, v in d.items() if k in names})


class Obs:
    """Bundle of registry + tracer + flight recorder for one process."""

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg or ObsConfig(enabled=True)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(self.registry, enabled=self.cfg.trace,
                             span_cap=self.cfg.span_cap)
        self.flight = FlightRecorder(cap=self.cfg.flight_cap,
                                     enabled=self.cfg.flight)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict:
        return self.registry.snapshot()

    def export(self, out_dir: str) -> Dict[str, str]:
        """Write metrics.json + metrics.prom (+ flight.json when the ring
        has events) under ``out_dir``; returns {artifact: path}."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {}
        snap = self.snapshot()
        jp = os.path.join(out_dir, "metrics.json")
        with open(jp, "w") as f:
            json.dump(snap, f, indent=1)
        paths["metrics_json"] = jp
        pp = os.path.join(out_dir, "metrics.prom")
        with open(pp, "w") as f:
            f.write(self.registry.to_prometheus())
        paths["metrics_prom"] = pp
        if self.flight.events:
            paths["flight_json"] = self.flight.dump_json(
                os.path.join(out_dir, "flight.json"), reason="export")
        return paths

    def dump_flight(self, reason: str, path: Optional[str] = None) -> Optional[str]:
        """Dump the flight ring to ``path`` or ``cfg.dump_dir``; returns
        the written path (None when no destination is configured)."""
        if path is None:
            if not self.cfg.dump_dir:
                return None
            path = os.path.join(self.cfg.dump_dir,
                                f"flight-{os.getpid()}.json")
        return self.flight.dump_json(path, reason=reason)


# ------------------------------------------------ process-global current --

_current: Optional[Obs] = None


def install(cfg: Optional[ObsConfig] = None) -> Obs:
    """Create and install a fresh ``Obs`` as the process-global current
    (regardless of ``cfg.enabled`` — callers gate on that themselves)."""
    global _current
    _current = Obs(cfg)
    return _current


def set_current(obs: Optional[Obs]) -> Optional[Obs]:
    """Install an existing ``Obs`` (or None to disable); returns the
    previous one so callers can restore it (the overhead bench does)."""
    global _current
    prev = _current
    _current = obs
    return prev


def get() -> Optional[Obs]:
    return _current


# ------------------------------------- near-free instrumentation helpers --

def span(name: str):
    """Open a tracing span on the current Obs; no-op singleton if obs or
    tracing is off (one global load + None test on the off path)."""
    o = _current
    if o is None or not o.tracer.enabled:
        return _NULL_SPAN
    return o.tracer.span(name)


def event(kind: str, **fields) -> None:
    o = _current
    if o is None:
        return
    o.flight.record(kind, **fields)


def counter_inc(name: str, n: float = 1.0) -> None:
    o = _current
    if o is None:
        return
    o.registry.inc(name, n)


def gauge_set(name: str, v: float) -> None:
    o = _current
    if o is None:
        return
    o.registry.set_gauge(name, v)


def observe(name: str, v: float) -> None:
    o = _current
    if o is None:
        return
    o.registry.observe(name, v)


# --------------------------------------------- cross-process propagation --

def drain_payload() -> Optional[Dict]:
    """Child-side: pop everything recorded since the last drain into one
    plain-dict payload (None when obs is off or nothing new)."""
    o = _current
    if o is None:
        return None
    payload = {}
    counters = o.registry.drain_counters()
    if counters:
        payload["counters"] = counters
    spans = o.tracer.drain()
    if spans:
        payload["spans"] = spans
    events = o.flight.drain()
    if events:
        payload["events"] = events
    return payload or None


def ingest_payload(payload: Optional[Dict]) -> None:
    """Parent-side: fold a child's drained payload into the current Obs."""
    o = _current
    if o is None or not payload:
        return
    if "counters" in payload:
        o.registry.merge_counters(payload["counters"])
    if "spans" in payload:
        o.tracer.ingest(payload["spans"])
    if "events" in payload:
        o.flight.ingest(payload["events"])
