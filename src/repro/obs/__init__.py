"""repro.obs — unified observability: tracing, flight recorder, metrics.

One ``Obs`` object bundles the instruments sharing a registry:

- ``obs.registry`` — counters / gauges / quantile-sketch histograms with a
  versioned-schema snapshot (JSON + Prometheus text); see ``registry.py``.
- ``obs.tracer`` — nested spans; per-stage latency quantiles land in
  ``span.*`` histograms; see ``trace.py``.
- ``obs.flight`` — ring buffer of structured events, JSON-dumped on
  crash/chaos failure or on demand; see ``flight.py``.
- ``obs.sampler`` (optional) — adaptive head sampler thinning span/event
  *detail* while counters and histograms stay exact; see ``sample.py``.
- ``obs.timeline`` (optional) — sampled per-tuple exemplar timelines
  (admission → leaf push → root merge → stage → dispatch → drain → emit);
  see ``sample.py``.
- ``obs.slo`` (optional) — threshold/burn-rate rules over registry
  quantiles whose breaches feed ``controller.observe_live`` and trigger
  flight dumps; see ``slo.py``.
- ``obs.server`` (optional) — in-run HTTP scrape endpoint for the
  Prometheus text + JSON snapshot; see ``serve.py``.

A process-global current ``Obs`` is installed with ``install(ObsConfig)``
(or ``set_current`` for an existing instance). Instrumented call sites use
the module-level helpers ``span()`` / ``event()`` / ``counter_inc()`` /
``gauge_set()``: when nothing is installed (the default) they are a single
global load + ``is None`` test, so the off path costs nanoseconds.

Cross-process propagation: a child ingest-leaf process installs its own
``Obs`` (config travels in the worker cfg dict), instruments locally, and
ships ``drain_payload()`` dicts piggybacked on ``LeafOut.obs`` over the
existing channels; the parent folds them in with ``ingest_payload()``.
Payloads carry the child's perf→wall ``clock`` offset so merged timelines
renormalize into one monotone clock domain.  Thread-mode leaves share the
parent's global ``Obs`` directly and must *not* ship payloads (that would
double-count).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from .registry import (MetricsRegistry, SCHEMA_VERSION, snapshot_schema,
                       validate_snapshot)
from .trace import Tracer, _NULL_SPAN
from .flight import FlightRecorder
from .sample import ExemplarTimelines, HeadSampler, is_exemplar
from .slo import SloBreach, SloEngine, SloRule

__all__ = [
    "ObsConfig", "Obs", "install", "get", "set_current",
    "span", "event", "counter_inc", "gauge_set", "observe", "exemplars",
    "drain_payload", "ingest_payload",
    "MetricsRegistry", "Tracer", "FlightRecorder",
    "HeadSampler", "ExemplarTimelines", "is_exemplar",
    "SloRule", "SloBreach", "SloEngine",
    "SCHEMA_VERSION", "snapshot_schema", "validate_snapshot",
]


@dataclass
class ObsConfig:
    """Observability knobs carried by ``RuntimeConfig`` (JSON-serializable).

    ``enabled`` turns the layer on (registry + flight recorder); ``trace``
    additionally turns on span timing — the separately-gated cost tier
    (<2% without, <10% with, per the q1 bench row). ``dump_dir`` set makes
    the runtime dump the flight ring there on crash; ``export_dir`` set
    makes ``Runtime.run``/launchers write ``metrics.json`` +
    ``metrics.prom`` there on completion.

    Live-plane knobs (all default-off so the base tiers cost nothing):
    ``serve_port`` starts the in-run scrape endpoint (0 = ephemeral);
    ``event_sample``/``span_sample``/``sample_rates`` thin flight-event /
    finished-span *detail* (counters and histograms stay exact);
    ``event_budget_per_s`` > 0 turns on adaptive backoff under load;
    ``exemplar_rate`` > 0 samples per-tuple end-to-end timelines
    (``exemplar_cap`` bounds the store); ``slo_rules`` is a list of
    ``SloRule.to_dict()`` dicts evaluated live by the runtime.
    """
    enabled: bool = False
    trace: bool = False
    flight: bool = True
    flight_cap: int = 4096
    span_cap: int = 2048
    dump_dir: Optional[str] = None
    export_dir: Optional[str] = None
    serve_port: Optional[int] = None
    event_sample: float = 1.0
    span_sample: float = 1.0
    sample_rates: Optional[Dict[str, float]] = None
    event_budget_per_s: float = 0.0
    exemplar_rate: float = 0.0
    exemplar_cap: int = 64
    slo_rules: Optional[List[Dict]] = None

    def to_dict(self) -> Dict:
        return {
            "enabled": self.enabled, "trace": self.trace,
            "flight": self.flight, "flight_cap": self.flight_cap,
            "span_cap": self.span_cap, "dump_dir": self.dump_dir,
            "export_dir": self.export_dir,
            "serve_port": self.serve_port,
            "event_sample": self.event_sample,
            "span_sample": self.span_sample,
            "sample_rates": self.sample_rates,
            "event_budget_per_s": self.event_budget_per_s,
            "exemplar_rate": self.exemplar_rate,
            "exemplar_cap": self.exemplar_cap,
            "slo_rules": self.slo_rules,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ObsConfig":
        names = {f for f in cls.__dataclass_fields__}  # type: ignore[attr-defined]
        return cls(**{k: v for k, v in d.items() if k in names})

    def wants_sampler(self) -> bool:
        return (self.event_sample < 1.0 or self.span_sample < 1.0
                or bool(self.sample_rates) or self.event_budget_per_s > 0.0)


class Obs:
    """Bundle of registry + tracer + flight recorder (+ sampler, exemplar
    timelines, SLO engine, scrape server) for one process."""

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg or ObsConfig(enabled=True)
        self.registry = MetricsRegistry()
        self.flight = FlightRecorder(cap=self.cfg.flight_cap,
                                     enabled=self.cfg.flight)
        self.sampler: Optional[HeadSampler] = None
        if self.cfg.wants_sampler():
            self.sampler = HeadSampler(
                event_sample=self.cfg.event_sample,
                span_sample=self.cfg.span_sample,
                rates=self.cfg.sample_rates,
                budget_per_s=self.cfg.event_budget_per_s)
        self.tracer = Tracer(self.registry, enabled=self.cfg.trace,
                             span_cap=self.cfg.span_cap,
                             sampler=self.sampler)
        self.timeline: Optional[ExemplarTimelines] = None
        if self.cfg.exemplar_rate > 0.0:
            off = self.flight.clock_offset
            self.timeline = ExemplarTimelines(
                self.cfg.exemplar_rate, cap=self.cfg.exemplar_cap,
                clock=lambda: time.perf_counter() + off)
        self.slo: Optional[SloEngine] = None
        if self.cfg.slo_rules:
            self.slo = SloEngine.from_dicts(self.cfg.slo_rules)
        self.server = None

    # -- scrape server -------------------------------------------------------
    def start_server(self, port: Optional[int] = None,
                     host: str = "127.0.0.1"):
        """Start the in-run scrape endpoint (idempotent); returns it."""
        if self.server is None:
            from .serve import ObsServer
            p = self.cfg.serve_port if port is None else port
            self.server = ObsServer(self, port=int(p or 0),
                                    host=host).start()
            self.registry.set_gauge("obs.serve_port", self.server.port)
        return self.server

    def stop_server(self) -> None:
        if self.server is not None:
            self.server.stop()
            self.server = None

    # -- SLO evaluation ------------------------------------------------------
    def evaluate_slo(self, now: Optional[float] = None) -> List[SloBreach]:
        """Run the SLO engine once (no-op without rules).  Breaches are
        recorded as *unsampled* flight events + ``slo.breach.*`` counters
        and trigger a flight dump (when ``dump_dir`` is set); the caller
        (the runtime's drain loop) forwards them to
        ``controller.observe_live`` via ``LiveMetrics.slo_breaches``."""
        if self.slo is None:
            return []
        breaches = self.slo.evaluate(self.registry, now=now)
        for b in breaches:
            # direct ring write: breaches must never be sampled away
            self.flight.record("slo_breach", rule=b.rule, metric=b.metric,
                               slo_kind=b.kind, value=b.value,
                               threshold=b.threshold)
            self.registry.inc("slo.breaches")
            self.registry.inc(f"slo.breach.{b.rule}")
            self.dump_flight(reason=f"slo_breach:{b.rule}")
        return breaches

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict:
        """The schema-v2 snapshot: registry sections + sampling metadata +
        exemplar timelines (lock-consistent against in-flight ticks)."""
        return self.registry.snapshot(
            sampling=(self.sampler.snapshot() if self.sampler else None),
            exemplars=(self.timeline.snapshot() if self.timeline else None))

    def to_prometheus(self) -> str:
        return self.registry.to_prometheus(
            sampling=(self.sampler.snapshot() if self.sampler else None))

    def export(self, out_dir: str) -> Dict[str, str]:
        """Write metrics.json + metrics.prom (+ flight.json when the ring
        has events) under ``out_dir``; returns {artifact: path}."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {}
        snap = self.snapshot()
        jp = os.path.join(out_dir, "metrics.json")
        with open(jp, "w") as f:
            json.dump(snap, f, indent=1, default=repr)
        paths["metrics_json"] = jp
        pp = os.path.join(out_dir, "metrics.prom")
        with open(pp, "w") as f:
            f.write(self.to_prometheus())
        paths["metrics_prom"] = pp
        if self.flight.events:
            paths["flight_json"] = self.flight.dump_json(
                os.path.join(out_dir, "flight.json"), reason="export",
                exemplars=(self.timeline.snapshot()
                           if self.timeline else None))
        return paths

    def dump_flight(self, reason: str, path: Optional[str] = None) -> Optional[str]:
        """Dump the flight ring (+ exemplar timelines) to ``path`` or
        ``cfg.dump_dir``; returns the written path (None when no
        destination is configured)."""
        if path is None:
            if not self.cfg.dump_dir:
                return None
            name = f"flight-{os.getpid()}.json"
            if reason.startswith("slo_breach"):
                name = f"flight-slo-{os.getpid()}.json"
            path = os.path.join(self.cfg.dump_dir, name)
        return self.flight.dump_json(
            path, reason=reason,
            exemplars=(self.timeline.snapshot() if self.timeline else None))


# ------------------------------------------------ process-global current --

_current: Optional[Obs] = None


def install(cfg: Optional[ObsConfig] = None) -> Obs:
    """Create and install a fresh ``Obs`` as the process-global current
    (regardless of ``cfg.enabled`` — callers gate on that themselves)."""
    global _current
    _current = Obs(cfg)
    return _current


def set_current(obs: Optional[Obs]) -> Optional[Obs]:
    """Install an existing ``Obs`` (or None to disable); returns the
    previous one so callers can restore it (the overhead bench does)."""
    global _current
    prev = _current
    _current = obs
    return prev


def get() -> Optional[Obs]:
    return _current


# ------------------------------------- near-free instrumentation helpers --

def span(name: str):
    """Open a tracing span on the current Obs; no-op singleton if obs or
    tracing is off (one global load + None test on the off path)."""
    o = _current
    if o is None or not o.tracer.enabled:
        return _NULL_SPAN
    return o.tracer.span(name)


def event(kind: str, **fields) -> None:
    o = _current
    if o is None:
        return
    if o.sampler is not None and not o.sampler.admit_event(kind):
        return
    o.flight.record(kind, **fields)


def counter_inc(name: str, n: float = 1.0) -> None:
    o = _current
    if o is None:
        return
    o.registry.inc(name, n)


def gauge_set(name: str, v: float) -> None:
    o = _current
    if o is None:
        return
    o.registry.set_gauge(name, v)


def observe(name: str, v: float) -> None:
    o = _current
    if o is None:
        return
    o.registry.observe(name, v)


def exemplars() -> Optional[ExemplarTimelines]:
    """The current exemplar-timeline store, or None when off — call sites
    hoist this out of per-tuple loops."""
    o = _current
    return None if o is None else o.timeline


# --------------------------------------------- cross-process propagation --

def drain_payload() -> Optional[Dict]:
    """Child-side: pop everything recorded since the last drain into one
    plain-dict payload (None when obs is off or nothing new).  Non-empty
    payloads carry the child's perf→wall ``clock`` offset (the handshake
    ``ingest_payload`` uses to renormalize timelines) and any exemplar
    mark fragments."""
    o = _current
    if o is None:
        return None
    payload = {}
    counters = o.registry.drain_counters()
    if counters:
        payload["counters"] = counters
    spans = o.tracer.drain()
    if spans:
        payload["spans"] = spans
    events = o.flight.drain()
    if events:
        payload["events"] = events
    if o.timeline is not None:
        marks = o.timeline.drain_marks()
        if marks:
            payload["exemplars"] = marks
    if payload:
        payload["clock"] = {"pid": os.getpid(),
                            "offset": o.flight.clock_offset}
    return payload or None


def ingest_payload(payload: Optional[Dict]) -> None:
    """Parent-side: fold a child's drained payload into the current Obs,
    renormalizing child wall stamps through the shipped clock offset so
    merged timelines stay monotone."""
    o = _current
    if o is None or not payload:
        return
    clock = payload.get("clock") or {}
    offset = clock.get("offset")
    if "counters" in payload:
        o.registry.merge_counters(payload["counters"])
    if "spans" in payload:
        spans = payload["spans"]
        if offset is not None:
            for s in spans:
                if "t_end" in s:
                    s["wall_end"] = s["t_end"] + offset
        o.tracer.ingest(spans)
    if "events" in payload:
        o.flight.ingest(payload["events"], clock_offset=offset)
    if "exemplars" in payload and o.timeline is not None:
        o.timeline.ingest_marks(payload["exemplars"])
