"""Adaptive head-based sampling for span/event detail + exemplar timelines.

Two concerns live here, both feeding the schema-v2 snapshot:

``HeadSampler`` decides, at the *head* of each span/event, whether its
detail record (the flight-ring event dict / the tracer's finished-span
dict) is kept.  Sampling thins **detail only**: registry counters and the
``span.*`` duration histograms are always updated, so exact totals and the
quantiles the SLO engine reads stay bit-identical to an unsampled run.
Admission is a deterministic stride test (`attempt_n % stride == 0`), so
two runs over the same stream keep the same records.  In adaptive mode
(``event_budget_per_s > 0``) the sampler measures the recent attempt rate
per kind and scales each kind's admit rate down when the aggregate rate
exceeds the budget (and back up, capped at the configured rate, when it
falls below) — full tracing survives 10x event rates without the ring and
payload shipping costs growing 10x.

``ExemplarTimelines`` maintains a small set of *exemplar tuples* whose
(src, tau) identity deterministically hashes under ``exemplar_rate``; every
stage that sees a tuple batch (tier admission, leaf push, root merge,
runtime stage/dispatch/drain/emit) independently applies the same predicate
and stamps a wall-clock mark, so the end-to-end timeline needs **no
cross-process coordination** — child marks ship in ``LeafOut.obs`` payloads
and are clock-offset-normalized at ingest (see ``flight.py`` for the
offset handshake).  Completed timelines surface in ``RunReport`` and the
flight dump.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

# exemplar predicate: a tuple (src, tau) is an exemplar iff
#   ((tau * _MIX + src) % stride) == 0    with stride = round(1/rate)
# _MIX is a large odd prime so consecutive taus of one source spread out.
_MIX = 1000003

# stage order used to sort marks inside one timeline (wall clocks across
# processes agree only to offset-normalization precision; the logical
# stage order is authoritative for equal-ish timestamps)
STAGES = ("admit", "leaf_push", "root_merge", "stage", "dispatch",
          "drain", "emit")
_STAGE_RANK = {s: i for i, s in enumerate(STAGES)}

_MIN_RATE = 1.0 / 1024.0      # adaptive floor: never fully blind
_WINDOW = 64                  # attempts between adaptive rate re-checks


def _stride(rate: float) -> int:
    """Admit-1-in-N stride for a rate in (0, 1]; rate<=0 disables."""
    if rate >= 1.0:
        return 1
    if rate <= 0.0:
        return 0                # sentinel: drop everything
    return max(1, int(round(1.0 / rate)))


class _KindState:
    __slots__ = ("attempts", "kept", "rate", "cfg_rate", "stride",
                 "win_t0", "win_n")

    def __init__(self, rate: float):
        self.attempts = 0
        self.kept = 0
        self.cfg_rate = rate          # configured ceiling
        self.rate = rate              # live (adaptively lowered) rate
        self.stride = _stride(rate)
        self.win_t0 = time.perf_counter()
        self.win_n = 0


class HeadSampler:
    """Deterministic per-kind head sampler with an optional rate budget.

    ``event_sample`` / ``span_sample`` are the default keep rates for flight
    events and finished-span records; ``rates`` overrides per kind/name
    (exact match on the event kind or span name).  ``budget_per_s`` > 0
    turns on adaptive mode: whenever a kind's recent attempt rate times its
    live admit rate exceeds its share of the budget, the live rate halves
    (down to 1/1024); when comfortably under, it doubles back toward the
    configured ceiling.
    """

    def __init__(self, event_sample: float = 1.0, span_sample: float = 1.0,
                 rates: Optional[Dict[str, float]] = None,
                 budget_per_s: float = 0.0):
        self.event_sample = float(event_sample)
        self.span_sample = float(span_sample)
        self.rates = dict(rates or {})
        self.budget_per_s = float(budget_per_s)
        self._events: Dict[str, _KindState] = {}
        self._spans: Dict[str, _KindState] = {}

    # -- admission -----------------------------------------------------------
    def _state(self, table: Dict[str, _KindState], kind: str,
               default_rate: float) -> _KindState:
        st = table.get(kind)
        if st is None:
            st = _KindState(self.rates.get(kind, default_rate))
            table[kind] = st
        return st

    def _admit(self, st: _KindState) -> bool:
        n = st.attempts
        st.attempts = n + 1
        if self.budget_per_s > 0.0:
            st.win_n += 1
            if st.win_n >= _WINDOW:
                self._retune(st)
        if st.stride == 0:
            return False
        if (n % st.stride) == 0:
            st.kept += 1
            return True
        return False

    def _retune(self, st: _KindState) -> None:
        now = time.perf_counter()
        dt = now - st.win_t0
        st.win_t0 = now
        st.win_n = 0
        if dt <= 0.0:
            return
        attempt_rate = _WINDOW / dt
        kept_rate = attempt_rate * st.rate
        if kept_rate > self.budget_per_s:
            # back off multiplicatively toward the budget
            st.rate = max(_MIN_RATE,
                          st.rate * (self.budget_per_s / kept_rate))
        elif kept_rate < 0.5 * self.budget_per_s and st.rate < st.cfg_rate:
            st.rate = min(st.cfg_rate, st.rate * 2.0)
        st.stride = _stride(st.rate)

    def admit_event(self, kind: str) -> bool:
        return self._admit(self._state(self._events, kind,
                                       self.event_sample))

    def admit_span(self, name: str) -> bool:
        return self._admit(self._state(self._spans, name,
                                       self.span_sample))

    # -- export --------------------------------------------------------------
    def snapshot(self) -> Dict:
        """Sampling metadata for the v2 snapshot: exact attempt/kept
        totals per kind (attempts are exact even when detail is thinned)."""
        def table(t: Dict[str, _KindState]) -> Dict:
            return {k: {"attempts": st.attempts, "kept": st.kept,
                        "rate": st.rate}
                    for k, st in sorted(t.items())}
        return {
            "event_sample": self.event_sample,
            "span_sample": self.span_sample,
            "budget_per_s": self.budget_per_s,
            "adaptive": self.budget_per_s > 0.0,
            "events": table(self._events),
            "spans": table(self._spans),
        }


# ---------------------------------------------------------- exemplars -----


def is_exemplar(src: int, tau: int, stride: int) -> bool:
    """The shared deterministic exemplar predicate (stride from
    ``_stride(exemplar_rate)``); evaluated independently at every stage."""
    return stride > 0 and ((int(tau) * _MIX + int(src)) % stride) == 0


class ExemplarTimelines:
    """Bounded store of per-tuple end-to-end timelines.

    A timeline is keyed by the tuple identity ``(src, tau)`` and holds
    ``{stage: wall_seconds}`` marks.  Stages before runtime staging mark
    by identity (``mark``); the runtime binds the identity to a tick id
    (``bind_tick``) so dispatch/drain/emit — which only know the tick —
    can mark every exemplar staged into it (``mark_tick``).  A timeline
    completes when its ``emit`` mark lands; completed timelines move to a
    bounded done-deque exposed via ``snapshot()``/``drain()``.
    """

    def __init__(self, rate: float, cap: int = 64, clock=None):
        self.rate = float(rate)
        self.stride = _stride(self.rate)
        self.cap = int(cap)
        # wall-clock source; Obs passes perf_counter + flight clock_offset
        # so marks inherit monotonicity (see flight.py clock handshake)
        self._clock = clock or time.time
        self._lock = threading.Lock()
        self._open: Dict[tuple, Dict] = {}
        self._by_tick: Dict[int, List[tuple]] = {}
        self._done: deque = deque(maxlen=self.cap)

    def is_exemplar(self, src: int, tau: int) -> bool:
        return is_exemplar(src, tau, self.stride)

    def scan(self, srcs, taus, ok, stage: str,
             tick_id: Optional[int] = None) -> None:
        """Vectorized stage stamp over a tuple batch: applies the exemplar
        predicate to every lane where ``ok`` and marks the (few) hits with
        one shared wall stamp.  ``tick_id`` also binds each hit so later
        tick-granular stages (``mark_tick``) reach it."""
        if self.stride <= 0:
            return
        srcs = np.asarray(srcs, dtype=np.int64)
        taus = np.asarray(taus, dtype=np.int64)
        m = np.asarray(ok, dtype=bool) & (
            ((taus * _MIX + srcs) % self.stride) == 0)
        if not m.any():
            return
        w = self._clock()
        for s, t in zip(srcs[m].tolist(), taus[m].tolist()):
            self.mark(s, t, stage, wall=w)
            if tick_id is not None:
                self.bind_tick(s, t, tick_id)

    def mark(self, src: int, tau: int, stage: str,
             wall: Optional[float] = None) -> None:
        """Stamp ``stage`` on the (src, tau) exemplar (opens it if new;
        silently drops when the open set is at capacity)."""
        key = (int(src), int(tau))
        w = self._clock() if wall is None else wall
        with self._lock:
            tl = self._open.get(key)
            if tl is None:
                if len(self._open) >= self.cap:
                    return
                tl = {"src": key[0], "tau": key[1], "marks": {}}
                self._open[key] = tl
            tl["marks"].setdefault(stage, w)

    def bind_tick(self, src: int, tau: int, tick_id: int) -> None:
        key = (int(src), int(tau))
        with self._lock:
            if key in self._open:
                self._open[key]["tick_id"] = int(tick_id)
                self._by_tick.setdefault(int(tick_id), []).append(key)

    def mark_tick(self, tick_id: int, stage: str,
                  wall: Optional[float] = None) -> None:
        """Stamp ``stage`` on every open exemplar bound to ``tick_id``;
        ``emit`` completes and retires the timeline."""
        w = self._clock() if wall is None else wall
        with self._lock:
            keys = self._by_tick.get(int(tick_id))
            if not keys:
                return
            for key in keys:
                tl = self._open.get(key)
                if tl is None:
                    continue
                tl["marks"].setdefault(stage, w)
                if stage == "emit":
                    self._finish_locked(key, tl)
            if stage == "emit":
                self._by_tick.pop(int(tick_id), None)

    def _finish_locked(self, key: tuple, tl: Dict) -> None:
        self._open.pop(key, None)
        tl["timeline"] = sorted(
            ((s, w) for s, w in tl["marks"].items()),
            key=lambda sw: (sw[1], _STAGE_RANK.get(sw[0], len(STAGES))))
        self._done.append(tl)

    # -- cross-process shipping ---------------------------------------------
    def drain_marks(self) -> List[Dict]:
        """Child-side: ship open-mark fragments ({src, tau, marks}) and
        clear them; the parent folds them with ``ingest_marks``."""
        with self._lock:
            out = [{"src": tl["src"], "tau": tl["tau"],
                    "marks": dict(tl["marks"])}
                   for tl in self._open.values()]
            self._open.clear()
            self._by_tick.clear()
            return out

    def ingest_marks(self, frags: List[Dict],
                     wall_offset: float = 0.0) -> None:
        """Parent-side: fold child mark fragments, shifting child walls by
        ``wall_offset`` (parent_wall - child_wall) so merged timelines are
        monotone in the parent's clock domain."""
        for frag in frags:
            for stage, w in frag.get("marks", {}).items():
                self.mark(frag["src"], frag["tau"], stage,
                          wall=w + wall_offset)

    # -- export --------------------------------------------------------------
    def completed(self) -> List[Dict]:
        with self._lock:
            return list(self._done)

    def snapshot(self) -> List[Dict]:
        """Exemplar section for the v2 snapshot: completed timelines plus
        still-open ones (partial marks), bounded by ``cap``."""
        with self._lock:
            done = list(self._done)
            opens = []
            for tl in list(self._open.values())[: self.cap]:
                opens.append({
                    "src": tl["src"], "tau": tl["tau"],
                    "tick_id": tl.get("tick_id"),
                    "timeline": sorted(
                        ((s, w) for s, w in tl["marks"].items()),
                        key=lambda sw: (sw[1],
                                        _STAGE_RANK.get(sw[0],
                                                        len(STAGES)))),
                    "complete": False,
                })
        for tl in done:
            tl.setdefault("complete", True)
        return done + opens
