"""jax version compatibility layer.

The repo targets the current jax API surface but must run on whatever jax
the host ships.  Two moves per release line matter to us:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` (0.4.x) to a
  top-level ``jax.shard_map`` export (>= 0.6).
* the replication-check kwarg was renamed ``check_rep`` (0.4.x/0.5) ->
  ``check_vma`` (>= 0.6, after the varying-manual-axes rework).

Callers import ``shard_map`` from here and pass modern (``check_vma``)
kwargs through :func:`shard_map_kwargs`, which rewrites them for the
installed jax.  Nothing else in the repo touches the experimental
namespace directly.
"""

from __future__ import annotations

import inspect
from typing import Any, Dict

import jax

JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:3])

try:                                       # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                        # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)
# modern name -> legacy name, applied only when the installed jax wants it
_KWARG_RENAMES = {"check_vma": "check_rep"}


def shard_map_kwargs(**kwargs: Any) -> Dict[str, Any]:
    """Rewrite modern shard_map kwargs for the installed jax.

    ``check_vma`` is renamed to ``check_rep`` on jax versions predating the
    varying-manual-axes rework; kwargs the installed shard_map does not
    accept at all are dropped (they are all behavior-preserving checks).
    """
    out: Dict[str, Any] = {}
    for name, value in kwargs.items():
        if name not in _SHARD_MAP_PARAMS and name in _KWARG_RENAMES:
            name = _KWARG_RENAMES[name]
        if name in _SHARD_MAP_PARAMS:
            out[name] = value
    return out


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """Version-adaptive ``jax.shard_map`` (modern kwarg spelling)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **shard_map_kwargs(**kwargs))
