"""The unified runtime-config API: one dataclass, one facade, one resume.

Every launcher, benchmark, and drill used to assemble the stack by hand —
operator here, mesh there, ingest tier, controller, runtime, each with its
own flag zoo.  ``RuntimeConfig`` is the single declarative description of
a run (operator + windows, parallelism + mesh, ingest tier, runtime knobs,
fault tolerance) and ``build_runtime`` is the one constructor:

    cfg = RuntimeConfig(n_sources=4, ingest_hosts=2,
                        checkpoint_dir="/tmp/ck", checkpoint_every=8)
    rt = build_runtime(cfg, source)
    report = rt.run()

The config is JSON-serializable and rides inside every checkpoint
manifest, which is what makes restore *closed*: ``resume_runtime`` reads
the manifest, rebuilds the identical stack from the embedded config,
restores pipeline + ingest-tier state from the latest complete step, and
replays the source from the snapshot's frontier — exactly-once when the
victim's outputs below the restored step are treated as committed
(``CollectSink.results(before_tick=step)``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro import obs as _obs
from repro.checkpoint.checkpoint import Checkpointer
from repro.checkpoint import stream as ckstream
from repro.core.async_runtime import AsyncStreamRuntime, RunReport
from repro.core.windows import WindowSpec
from repro.io.sources import ReplaySource
from repro.obs import ObsConfig


@dataclasses.dataclass
class RuntimeConfig:
    """Declarative description of one streaming run.  JSON-serializable
    (``to_json``/``from_json``) so a checkpoint manifest can carry it and
    ``resume_runtime`` can rebuild an identical stack."""
    # -- operator ----------------------------------------------------------
    op: str = "count"              # registry key: count | longest
    wa: int = 500                  # window advance
    ws: int = 1000                 # window size
    wt: str = "multi"              # window type
    k_virt: int = 256
    out_cap: int = 1024
    extra_slots: int = 2
    # -- parallelism -------------------------------------------------------
    n_max: int = 16
    n_active: int = 2
    stash_cap: int = 256
    mesh_devices: int = 0          # 0 = single-device VSNPipeline
    backend: Optional[str] = None
    # -- sources / ingest tier --------------------------------------------
    n_sources: int = 1
    ingest_hosts: int = 0          # 0 = no tier (source feeds the runtime)
    ingest_worker: str = "thread"  # thread | process | inline
    leaf_cap: int = 128
    root_cap: int = 256
    chan_cap: int = 4
    max_leaves: int = 0            # 0 = IngestTier's default headroom
    out_pad: int = 32
    root_device: bool = False
    # -- runtime -----------------------------------------------------------
    queue_cap: int = 4
    super_batch: int = 1
    controller: str = "none"       # none | threshold | predictive | slo
    capacity_per_instance: float = 4000.0
    # -- serving tier ------------------------------------------------------
    # non-None switches the pipeline to the elastic LLM serving tier: the
    # operator is continuous-batching decode, sigma is the KV slot pool,
    # and scale-up/down is the f_mu rewrite.  Pairs with controller="slo".
    serving: Optional[Any] = None  # ServingConfig | dict
    slo_target_p99_ms: float = 50.0
    # -- fault tolerance ---------------------------------------------------
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0      # pipeline ticks between snapshots
    # -- observability -----------------------------------------------------
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)

    def __post_init__(self):
        if self.checkpoint_every and self.super_batch > 1:
            assert self.checkpoint_every % self.super_batch == 0, (
                "checkpoint_every must be a multiple of super_batch: "
                "boundaries inside a super-batch group are never cut")
        # JSON round-trips (manifest restore) hand obs back as a plain dict
        if isinstance(self.obs, dict):
            self.obs = ObsConfig.from_dict(self.obs)
        if isinstance(self.serving, dict):
            from repro.serving import ServingConfig
            self.serving = ServingConfig.from_dict(self.serving)

    @property
    def effective_max_leaves(self) -> int:
        """What ``IngestTier`` actually allocates for the leaf axis — the
        restore templates need the real array shapes."""
        n = self.ingest_hosts
        return self.max_leaves or max(2 * n, n + 4)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "RuntimeConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


# ---------------------------------------------------------------- pieces --

def make_op(cfg: RuntimeConfig):
    from repro.core import aggregate
    window = WindowSpec(wa=cfg.wa, ws=cfg.ws, wt=cfg.wt)
    kw = dict(k_virt=cfg.k_virt, out_cap=cfg.out_cap,
              extra_slots=cfg.extra_slots, n_inputs=max(cfg.n_sources, 1))
    if cfg.op == "count":
        return aggregate.count_aggregate(window, **kw)
    if cfg.op == "longest":
        return aggregate.longest_aggregate(window, **kw)
    raise ValueError(f"unknown operator {cfg.op!r}")


def make_pipeline(cfg: RuntimeConfig):
    from repro.core.runtime import MeshPipeline, VSNPipeline
    if cfg.serving is not None:
        from repro.serving import build_serving_pipeline
        return build_serving_pipeline(cfg.serving,
                                      n_inputs=max(cfg.n_sources, 1),
                                      n_active=cfg.n_active)
    op = make_op(cfg)
    if cfg.mesh_devices:
        from repro.launch.mesh import make_stream_mesh
        mode = "fast-agg" if cfg.op == "count" else "general"
        return MeshPipeline(op, make_stream_mesh(cfg.mesh_devices),
                            stash_cap=cfg.stash_cap, mode=mode,
                            agg_kind="count", backend=cfg.backend,
                            n_max=cfg.n_max, n_active=cfg.n_active)
    return VSNPipeline(op, n_max=cfg.n_max, n_active=cfg.n_active,
                       stash_cap=cfg.stash_cap)


def make_controller(cfg: RuntimeConfig):
    from repro.core.controller import (PredictiveController,
                                       ThresholdController)
    if cfg.controller == "none":
        return None
    if cfg.controller == "slo":
        from repro.serving import SloServingController
        if cfg.serving is None:
            raise ValueError('controller="slo" requires a serving config')
        # the serving pipeline's k_virt is the slot count, its replica
        # ceiling is the serving tier's instance count
        return SloServingController(
            n_max=cfg.serving.n_instances, k_virt=cfg.serving.n_slots,
            target_p99_ms=cfg.slo_target_p99_ms, n_active=cfg.n_active)
    if cfg.controller == "threshold":
        return ThresholdController(
            n_max=cfg.n_max, k_virt=cfg.k_virt,
            capacity_per_instance=cfg.capacity_per_instance,
            n_active=cfg.n_active)
    if cfg.controller == "predictive":
        return PredictiveController(
            n_max=cfg.n_max, k_virt=cfg.k_virt,
            comparisons_per_s_per_instance=3e7, ws_seconds=1.0,
            n_active=cfg.n_active)
    raise ValueError(f"unknown controller {cfg.controller!r}")


def make_tier(cfg: RuntimeConfig, source, *, record: bool = False,
              restore: Optional[Dict] = None):
    from repro.ingest import IngestTier
    return IngestTier(
        source, cfg.n_sources, cfg.ingest_hosts, worker=cfg.ingest_worker,
        leaf_cap=cfg.leaf_cap, root_cap=cfg.root_cap,
        chan_cap=cfg.chan_cap, max_leaves=cfg.effective_max_leaves,
        backend=cfg.backend, record=record,
        schedule=getattr(source, "schedule", None), out_pad=cfg.out_pad,
        root_device=cfg.root_device, snapshot_every=cfg.checkpoint_every,
        restore=restore)


# ---------------------------------------------------------------- facade --

@dataclasses.dataclass
class Runtime:
    """The assembled stack: everything ``build_runtime`` constructed, with
    the run entry point.  ``tier`` is None without an ingest tier;
    ``checkpointer`` is None without fault tolerance configured."""
    config: RuntimeConfig
    pipeline: Any
    runtime: AsyncStreamRuntime
    tier: Any = None
    checkpointer: Optional[ckstream.StreamCheckpointer] = None
    restored_step: Optional[int] = None   # set by resume_runtime

    @property
    def sink(self):
        return self.runtime.sink

    def run(self, max_ticks: Optional[int] = None) -> RunReport:
        report = self.runtime.run(max_ticks=max_ticks)
        o = _obs.get()
        if o is not None and self.config.obs.export_dir:
            o.export(self.config.obs.export_dir)
        return report


def build_runtime(cfg: RuntimeConfig, source, *, pipeline=None, sink=None,
                  controller=None, metrics=None, restore: Optional[Dict] = None,
                  record_tier: bool = False) -> Runtime:
    """Construct IngestTier -> AsyncStreamRuntime -> VSN/Mesh pipeline from
    one config.  ``restore`` (from ``resume_runtime``) installs snapshot
    state into every layer *before* the runtime is built — the runtime
    seeds its epoch shadows and host frontier from the pipeline at
    construction, so ordering is part of the contract, not an accident.
    """
    # observability first: the layers built below record into the global
    # Obs from their constructors onward.  Only install when the config
    # asks for it — callers that installed an Obs themselves (benches,
    # tests) keep theirs.
    if cfg.serving is not None and cfg.checkpoint_dir:
        raise ValueError(
            "serving tier has no checkpoint/restore support yet")
    if cfg.obs.enabled:
        o = _obs.install(cfg.obs)
        if cfg.obs.serve_port is not None:
            # live scrape endpoint: serves /metrics (Prometheus text) and
            # /snapshot (schema-v2 JSON) for the whole run; port 0 binds
            # an ephemeral port, exposed as o.server.port
            o.start_server()
    if pipeline is None:
        pipeline = make_pipeline(cfg)
    if restore is not None:
        pipeline.import_state(restore["pipe"])
    tier = None
    src = source
    if cfg.ingest_hosts:
        tier = make_tier(cfg, source, record=record_tier,
                         restore=(restore or {}).get("tier"))
        src = tier
    if controller is None:
        controller = make_controller(cfg)
    sck = None
    if cfg.checkpoint_dir and cfg.checkpoint_every:
        sck = ckstream.StreamCheckpointer(
            Checkpointer(cfg.checkpoint_dir), cfg.checkpoint_every,
            pipeline, tier=tier, config=cfg)
    rt = AsyncStreamRuntime(
        pipeline, src, sink=sink, controller=controller,
        queue_cap=cfg.queue_cap, metrics=metrics,
        super_batch=cfg.super_batch, checkpointer=sck,
        tick0=(restore or {}).get("tick0", 0))
    return Runtime(config=cfg, pipeline=pipeline, runtime=rt, tier=tier,
                   checkpointer=sck)


def resume_runtime(checkpoint_dir: str, batches, *, sink=None,
                   controller=None, metrics=None,
                   step: Optional[int] = None) -> Runtime:
    """Rebuild and restore the stack from the latest complete checkpoint
    under ``checkpoint_dir`` (or an explicit ``step``).

    ``batches`` is the replay log — the full original stream (a
    ``ReplaySource``, a list of ticks, or a ``.npz`` path recorded by
    ``io.sources.save_stream``); the suffix at or past the snapshot's
    source frontier is replayed, everything before it is already in the
    snapshot.  A crash mid-save left no manifest, so ``latest_step`` lands
    on the previous complete step automatically.
    """
    ck = Checkpointer(checkpoint_dir)
    if step is None:
        step = ck.latest_step()
    if step is None:
        raise FileNotFoundError(
            f"no complete checkpoint under {checkpoint_dir}")
    extra = ck.manifest(step)["extra"]
    cfg = RuntimeConfig.from_json(extra["config"])
    pipeline = make_pipeline(cfg)
    like = ckstream.like_tree(
        pipeline, extra, n_sources=cfg.n_sources, leaf_cap=cfg.leaf_cap,
        root_cap=cfg.root_cap, max_leaves=cfg.effective_max_leaves,
        out_pad=cfg.out_pad, root_device=cfg.root_device)
    tree = ck.restore(step, like)
    restore: Dict[str, Any] = {"pipe": tree["pipe"], "tick0": int(step)}
    if extra.get("tier") is not None:
        restore["tier"] = ckstream.tier_restore_dict(tree, extra["tier"])
    source_ticks = int(extra["source_ticks"])
    if isinstance(batches, str):
        from repro.io.sources import load_stream
        src = load_stream(batches, from_tick=source_ticks)
    elif isinstance(batches, ReplaySource):
        src = batches.from_tick(source_ticks)
    else:
        src = ReplaySource(list(batches),
                           n_inputs=max(cfg.n_sources, 1)).from_tick(
                               source_ticks)
    rt = build_runtime(cfg, src, pipeline=pipeline, sink=sink,
                       controller=controller, metrics=metrics,
                       restore=restore)
    rt.restored_step = int(step)
    return rt
