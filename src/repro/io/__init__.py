"""Live-stream I/O: sources, bounded in-flight queues, sinks, metrics.

The batch drivers pre-stage whole streams on the host; this package is the
runtime-facing edge that turns them into *live* streams: rate-controlled
synthetic sources and file/replay sources produce event-time-stamped
``TupleBatch`` ticks, a bounded queue applies backpressure between the
host ingest thread and the device step, sinks collect outputs and per-tick
latency, and the ``MetricsBus`` aggregates the signals the elasticity
controllers consume (``core.async_runtime`` closes the loop).
"""

from repro.io.metrics import MetricsBus
from repro.io.queues import TIMEOUT, BoundedQueue, QueueClosed
from repro.io.sinks import CollectSink, NullSink
from repro.io.sources import (RateSchedule, ReplaySource, SyntheticSource,
                              load_stream, save_stream)

__all__ = [
    "BoundedQueue", "CollectSink", "MetricsBus", "NullSink", "QueueClosed",
    "RateSchedule", "ReplaySource", "SyntheticSource", "TIMEOUT",
    "load_stream", "save_stream",
]
