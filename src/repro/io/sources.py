"""Stream sources: rate-controlled synthetic generators and file/replay.

A source is an iterable of ``TupleBatch`` ticks plus two bits of shape the
runtime needs up front (``n_inputs``, and optionally a nominal offered
rate).  Event time (``tau``) always comes from the batches themselves —
the paper's streams are event-timed (§2.1), and replaying a recorded
stream must preserve its timestamps exactly, which is what makes the
async-vs-sync and live-vs-static parity checks meaningful.

``RateSchedule`` describes the *offered* load as piecewise-constant phases
(the Q5 abruptly-changing trace).  It serves two masters:

* pacing — a ``SyntheticSource`` with ``pace=True`` sleeps between ticks so
  the wall-clock offered rate tracks the schedule (a live workload);
* determinism — ``rate_hint(tick)`` gives controllers the offered rate as
  a deterministic function of the tick index, so closed-loop drills and
  tests reconfigure at reproducible points with no wall-clock in the loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import tuples as T


@dataclasses.dataclass(frozen=True)
class RateSchedule:
    """Piecewise-constant offered rate: [(n_ticks, tuples_per_s), ...].
    Past the last phase the final rate holds."""
    phases: Tuple[Tuple[int, float], ...]

    def __post_init__(self):
        assert self.phases, "empty rate schedule"

    @property
    def total_ticks(self) -> int:
        return sum(n for n, _ in self.phases)

    def rate_at(self, tick: int) -> float:
        for n, rate in self.phases:
            if tick < n:
                return float(rate)
            tick -= n
        return float(self.phases[-1][1])


class SyntheticSource:
    """Wraps a generator of ``TupleBatch`` ticks (e.g. ``datagen.tweets``)
    with an optional offered-rate schedule.

    ``pace=True`` turns it into a live source: emission of tick i is
    delayed until ``tick_size / rate_at(i)`` seconds after tick i-1, so a
    slow consumer sees queue growth and a fast one sees idle gaps — the
    real signal the backpressure/elasticity loop runs on.  Unpaced, it is
    free-running (benchmarks measure the pipeline, not the sleep)."""

    def __init__(self, batches: Iterable[T.TupleBatch], *, n_inputs: int = 1,
                 schedule: Optional[RateSchedule] = None, pace: bool = False,
                 tick_size: Optional[int] = None):
        self._batches = batches
        self.n_inputs = n_inputs
        self.schedule = schedule
        self.pace = pace and schedule is not None
        self.tick_size = tick_size

    def rate_hint(self, tick: int) -> Optional[float]:
        return self.schedule.rate_at(tick) if self.schedule else None

    def __iter__(self) -> Iterator[T.TupleBatch]:
        next_emit = time.perf_counter()
        for i, b in enumerate(self._batches):
            if self.pace:
                now = time.perf_counter()
                if now < next_emit:
                    time.sleep(next_emit - now)
                n = self.tick_size or b.batch
                next_emit = max(now, next_emit) + n / max(
                    self.schedule.rate_at(i), 1e-9)
            yield b


class ReplaySource:
    """Replays a recorded list of ticks, timestamps intact.  The canonical
    way to feed the exact same stream to an async run, a sync run, and the
    static oracle (the parity contract)."""

    def __init__(self, batches: Sequence[T.TupleBatch], *, n_inputs: int = 1,
                 schedule: Optional[RateSchedule] = None):
        self.batches = list(batches)
        self.n_inputs = n_inputs
        self.schedule = schedule

    def rate_hint(self, tick: int) -> Optional[float]:
        return self.schedule.rate_at(tick) if self.schedule else None

    def __iter__(self) -> Iterator[T.TupleBatch]:
        return iter(self.batches)

    def __len__(self) -> int:
        return len(self.batches)

    def from_tick(self, tick: int) -> "ReplaySource":
        """The suffix stream starting at tick index ``tick`` — the restore
        path replays exactly the ticks at or past the last snapshot's
        frontier (exactly-once: everything before is already reflected in
        the snapshot, everything after is regenerated)."""
        return ReplaySource(self.batches[tick:], n_inputs=self.n_inputs,
                            schedule=self.schedule)


_FIELDS = ("tau", "keys", "payload", "source", "valid", "is_control",
           "ctrl_epoch")


def save_stream(path: str, batches: Sequence[T.TupleBatch], *,
                n_inputs: int = 1) -> None:
    """Persist a tick stream as one ``.npz`` (uniform tick shapes stacked
    on a leading T axis) for later ``load_stream`` replay."""
    batches = list(batches)
    arrays = {f: np.stack([np.asarray(getattr(b, f)) for b in batches])
              for f in _FIELDS}
    np.savez_compressed(path, n_inputs=np.int32(n_inputs), **arrays)


def load_stream(path: str, *, from_tick: int = 0) -> ReplaySource:
    """Load a stream saved by ``save_stream`` as a ``ReplaySource`` (event
    times are whatever was recorded).  ``from_tick`` skips the prefix a
    snapshot already covers — the ``.npz`` record is the replay log the
    exactly-once restore contract leans on."""
    with np.load(path) as z:
        n_inputs = int(z["n_inputs"])
        fields = {f: z[f][from_tick:] for f in _FIELDS}
    n_ticks = fields["tau"].shape[0]
    batches: List[T.TupleBatch] = []
    for t in range(n_ticks):
        batches.append(T.TupleBatch(**{f: jnp.asarray(v[t])
                                       for f, v in fields.items()}))
    return ReplaySource(batches, n_inputs=n_inputs)
