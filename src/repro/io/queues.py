"""Bounded in-flight queue: the backpressure edge between host and device.

The ingest thread stages tick T+1 (``device_put``) while the device runs
tick T; this queue bounds how far ahead it may run.  ``put`` blocks when
the queue is full — a slow consumer therefore stalls the *producer*, never
grows memory (the test contract: depth never exceeds ``cap``), and the
observed depth is itself a load signal the controllers consume (a full
queue means the pipeline is not keeping up with the offered rate).

``get`` disambiguates its three outcomes explicitly:

* an item            — normal delivery (FIFO);
* raises QueueClosed — the queue is closed *and* drained: the stream has
  genuinely ended (items enqueued before ``close`` are always delivered
  first);
* returns TIMEOUT    — the wait timed out with the queue still open: the
  caller may retry, poll something else, or give up.  The sentinel (not
  ``None``, not an exception) keeps "no item yet" distinct from "no item
  ever again" — conflating them made a slow producer look like end-of-
  stream to pollers.

Payloads may be any non-None value (``None`` is reserved to catch
accidental sentinel payloads early).
"""

from __future__ import annotations

import collections
import threading
from typing import Any, Optional


class QueueClosed(Exception):
    """put() after close(), or get() on a closed-and-drained queue."""


class _Timeout:
    """Singleton sentinel: ``get(timeout=...)`` expired, queue still open."""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<BoundedQueue.TIMEOUT>"


TIMEOUT = _Timeout()


class BoundedQueue:
    """Thread-safe FIFO with a hard capacity and blocking put/get.

    A consumer loop is::

        try:
            while True:
                item = q.get()
                ...
        except QueueClosed:
            pass            # stream ended, everything was delivered
    """

    def __init__(self, cap: int):
        assert cap >= 1, cap
        self.cap = cap
        self._items: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        # -- stats (read under no lock: plain ints, monotone) --------------
        self.high_water = 0        # max depth ever observed
        self.total_put = 0
        self.blocked_puts = 0      # puts that had to wait on a full queue

    @property
    def depth(self) -> int:
        return len(self._items)

    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Append ``item``, blocking while full.  ``close()`` from another
        thread wakes a blocked put *immediately* (the wait predicate
        includes the closed flag) and ``QueueClosed`` wins over
        ``TimeoutError`` whenever the queue is closed — a producer stuck
        behind a dead consumer unblocks the instant the tier tears the
        queue down, instead of waiting out its timeout."""
        assert item is not None
        with self._cv:
            if len(self._items) >= self.cap:
                self.blocked_puts += 1
                from repro import obs as _obs
                _obs.event("backpressure_stall", transport="queue",
                           depth=len(self._items), cap=self.cap)
                _obs.counter_inc("queue.blocked_puts")
                if not self._cv.wait_for(
                        lambda: self._closed or len(self._items) < self.cap,
                        timeout=timeout):
                    if self._closed:            # closed during the last slice
                        raise QueueClosed
                    raise TimeoutError("BoundedQueue.put timed out")
            if self._closed:
                raise QueueClosed
            self._items.append(item)
            self.total_put += 1
            self.high_water = max(self.high_water, len(self._items))
            self._cv.notify_all()

    def get(self, timeout: Optional[float] = None) -> Any:
        """Next item (FIFO).  Raises ``QueueClosed`` once closed and
        drained; returns the ``TIMEOUT`` sentinel if ``timeout`` elapses
        with the queue still open."""
        with self._cv:
            if not self._cv.wait_for(
                    lambda: self._closed or self._items, timeout=timeout):
                return TIMEOUT
            if self._items:
                item = self._items.popleft()
                self._cv.notify_all()
                return item
            raise QueueClosed      # closed and drained

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
