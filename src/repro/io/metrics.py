"""MetricsBus: the per-tick signal aggregator the control loop reads.

Every tick the runtime records what it actually observed — tuples ingested,
dispatch-to-ready service latency, per-instance load, queue depth — and the
bus turns that into (a) the ``LiveMetrics`` snapshot fed to the elasticity
controllers (§8.4-§8.5: they see *live* signals, not a pre-staged trace)
and (b) the run report quantiles (throughput, tick latency p50/p99,
detection→switch latency) the benchmarks publish.

Retention is bounded: ``records`` keeps only the last ``retain`` full
``TickRecord``s (a long live run no longer accretes one object per tick
forever) while exact totals (``n_ticks``, ``total_tuples``) and a
fixed-memory quantile sketch of tick latency are maintained for the whole
run — so the run report is still full-run accurate.  While nothing has
been evicted the latency quantiles use the exact per-record percentile
path; after eviction they fall back to the sketch (≤~4.5% bucket error).

The bus is also a thin consumer of the ``repro.obs`` registry: when an
``Obs`` is installed, per-tick signals are mirrored into it
(``bus.ticks``/``bus.tuples`` counters, ``bus.tick_latency`` histogram,
queue-depth gauge) so the exported snapshot and the run report agree.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import numpy as np

from repro.core.controller import LiveMetrics
from repro import obs as _obs
from repro.obs.registry import Histogram


@dataclasses.dataclass
class TickRecord:
    tick_id: int
    n_tuples: int
    latency_s: float               # dispatch -> results-ready wall time
    inst_load: Optional[np.ndarray]
    n_active: int                  # committed active count the load was
    #                                measured under (pairs with inst_load)
    queue_depth: int
    t_done: float                  # wall clock at drain


class MetricsBus:
    def __init__(self, window: int = 64, queue_cap: int = 0,
                 retain: int = 1024):
        self.window = window
        self.queue_cap = queue_cap
        # rolling retention for derived signals; exact run totals live in
        # n_ticks / total_tuples / the latency sketch below
        self.retain = max(retain, window)
        self.records: Deque[TickRecord] = deque(maxlen=self.retain)
        self.n_ticks = 0
        self.t_start: Optional[float] = None
        self.t_end: Optional[float] = None
        self.total_tuples = 0
        self._lat_sketch = Histogram()         # full-run latency (seconds)
        # detection -> switch accounting: a controller decision is
        # "detected" when its Reconfiguration is injected; "switched" when
        # the runtime first observes switched=True for it (Alg. 4's
        # watermark barrier having passed gamma).  Entries carry the rc so
        # record_switch can hand the caller what the switch committed.
        self._pending_detections: List[tuple] = []  # (epoch, t_wall, tick, rc)
        self.detect_to_switch_ms: List[float] = []
        self.detect_to_switch_ticks: List[int] = []
        # detections whose switch never committed (superseded at shutdown
        # or runtime stopped mid-epoch), flushed here by stop()
        self.unresolved_detections: List[tuple] = []

    # -- recording ----------------------------------------------------------
    def start(self):
        self.t_start = time.perf_counter()

    def stop(self):
        self.t_end = time.perf_counter()
        # flush the pending-detection leak: anything still here never
        # observed its switch — surface it instead of dropping it silently
        if self._pending_detections:
            self.unresolved_detections.extend(self._pending_detections)
            self._pending_detections = []
            _obs.event("unresolved_detections",
                       n=len(self.unresolved_detections),
                       ticks=[d[2] for d in self.unresolved_detections])
            _obs.counter_inc("bus.unresolved_detections",
                             len(self.unresolved_detections))

    def record_tick(self, tick_id: int, n_tuples: int, latency_s: float,
                    inst_load: Optional[np.ndarray], queue_depth: int,
                    n_active: int = 0):
        self.records.append(TickRecord(tick_id, n_tuples, latency_s,
                                       inst_load, n_active, queue_depth,
                                       time.perf_counter()))
        self.n_ticks += 1
        self.total_tuples += int(n_tuples)
        self._lat_sketch.record(latency_s)
        o = _obs.get()
        if o is not None:
            reg = o.registry
            reg.inc("bus.ticks")
            reg.inc("bus.tuples", n_tuples)
            reg.observe("bus.tick_latency_s", latency_s)
            reg.set_gauge("bus.queue_depth", queue_depth)
            reg.set_gauge("bus.n_active", n_active)

    def record_detection(self, epoch: int, tick_id: int, rc=None):
        self._pending_detections.append(
            (epoch, time.perf_counter(), tick_id, rc))
        _obs.counter_inc("bus.detections")

    def record_switch(self, tick_id: int):
        """One observed epoch switch resolves EVERY detection made at or
        before its tick: back-to-back reconfigurations coalesce into a
        single switch (prepare_reconfig keeps the latest, Theorem 4), so
        each superseded decision also completed here.  Returns the resolved
        Reconfigurations, oldest first — the LAST one is what the switch
        committed (latest wins)."""
        now = time.perf_counter()
        resolved = [d for d in self._pending_detections if d[2] <= tick_id]
        self._pending_detections = [d for d in self._pending_detections
                                    if d[2] > tick_id]
        for _, t0, tick0, _rc in resolved:
            self.detect_to_switch_ms.append((now - t0) * 1e3)
            self.detect_to_switch_ticks.append(tick_id - tick0)
            _obs.observe("bus.detect_to_switch_s", now - t0)
        if resolved:
            _obs.counter_inc("bus.switches")
        return [rc for _, _, _, rc in resolved if rc is not None]

    # -- derived ------------------------------------------------------------
    def measured_rate_tps(self) -> float:
        """Ingest rate over the recent window (tuples / wall time)."""
        if len(self.records) < 2:
            return 0.0
        recs = list(self.records)[-self.window:]
        if len(recs) < 2:
            return 0.0
        dt = recs[-1].t_done - recs[0].t_done
        n = sum(r.n_tuples for r in recs[1:])
        return n / max(dt, 1e-9)

    def latency_quantiles_ms(self):
        """Full-run tick-latency (p50, p99) in ms.  Exact while no record
        has been evicted; sketch-approximated (≤~4.5%) afterwards."""
        if self.n_ticks == 0:
            return 0.0, 0.0
        if self.n_ticks <= len(self.records):
            lats = np.asarray([r.latency_s for r in self.records]) * 1e3
            return (float(np.percentile(lats, 50)),
                    float(np.percentile(lats, 99)))
        return (self._lat_sketch.quantile(0.50) * 1e3,
                self._lat_sketch.quantile(0.99) * 1e3)

    def throughput_tps(self) -> float:
        if self.t_start is None:
            return 0.0
        dt = (self.t_end or time.perf_counter()) - self.t_start
        return self.total_tuples / max(dt, 1e-9)

    def snapshot(self, rate_hint: Optional[float] = None,
                 queue_depth: int = 0,
                 backlog_tuples: float = 0.0,
                 slo_breaches: tuple = ()) -> LiveMetrics:
        """The controller-facing view of 'now'.  ``rate_hint`` (the offered
        rate, when the source knows it) takes precedence over the measured
        rate so closed-loop drills are deterministic; live deployments pass
        None and get the measured signal.  ``inst_load`` and
        ``n_active_observed`` come from the same record, so a load sample
        is always judged against the active set it was measured under.
        ``slo_breaches`` — new SLO-engine breaches since the last decision
        (repro.obs.slo.SloBreach) — ride along so policies can react to
        objective violations, not just raw load."""
        last = self.records[-1] if self.records else None
        return LiveMetrics(
            rate_tps=(rate_hint if rate_hint is not None
                      else self.measured_rate_tps()),
            inst_load=None if last is None else last.inst_load,
            n_active_observed=0 if last is None else last.n_active,
            queue_depth=queue_depth,
            queue_cap=self.queue_cap,
            backlog_tuples=backlog_tuples,
            tick_latency_s=0.0 if last is None else last.latency_s,
            slo_breaches=tuple(slo_breaches))
