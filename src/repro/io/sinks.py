"""Sink adapters: where the pipeline's per-tick ``Outputs`` land.

Sinks keep *device handles* — accepting an output never forces a host
sync (that would serialize the async loop); materialization happens in
``results()``/``finalize()`` after the run.  ``CollectSink`` is the parity
workhorse (sorted (tau, payload) multiset, the repo-wide output-set
equality currency); ``NullSink`` is the throughput-bench sink.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np


def flatten_outputs(outs) -> List[Tuple[int, tuple]]:
    """(tau, rounded payload tuple) for every valid lane; handles both flat
    and per-instance / per-shard stacked Outputs (any leading dims)."""
    tau = np.asarray(outs.tau).reshape(-1)
    val = np.asarray(outs.valid).reshape(-1)
    pay = np.asarray(outs.payload)
    pay = pay.reshape(-1, pay.shape[-1])
    return [(int(t), tuple(np.round(p, 4)))
            for t, p, ok in zip(tau, pay, val) if ok]


class CollectSink:
    """Retains every tick's output handles; ``results()`` materializes the
    sorted output multiset."""

    def __init__(self):
        self._held = []            # (tick_id, outs_pre, outs_post)
        self.ticks = 0

    def accept(self, tick_id: int, outs_pre, outs_post) -> None:
        self._held.append((tick_id, outs_pre, outs_post))
        self.ticks += 1

    def results(self) -> List[Tuple[int, tuple]]:
        res: List[Tuple[int, tuple]] = []
        for _, o1, o2 in self._held:
            res += flatten_outputs(o1) + flatten_outputs(o2)
        return sorted(res)


class NullSink:
    """Drops outputs (keeps only the latest handle so the final
    ``finalize()`` can fence the device queue) — the throughput sink."""

    def __init__(self):
        self.ticks = 0
        self._last = None

    def accept(self, tick_id: int, outs_pre, outs_post) -> None:
        self.ticks += 1
        self._last = outs_pre

    def finalize(self) -> None:
        if self._last is not None:
            np.asarray(self._last.tau)

    def results(self) -> Optional[list]:
        return None
