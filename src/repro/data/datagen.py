"""Synthetic stream generators mirroring the paper's workloads (§8).

* ``tweets``      — Q1/Q2: per-tuple word lists from a Zipf vocabulary; the
                    wordcount keys are the words, the paircount keys are
                    nearby-word pairs at distance <= B in {3 (L), 10 (M),
                    inf (H)} — the paper's duplication levels.
* ``scalejoin``   — Q3-Q5: two streams, payload attrs uniform in
                    [1, 10000]; the band predicate yields ~1 output per
                    250k comparisons as in [13].
* ``nyse``        — Q6: trades with bursty rate in [0, 8000] t/s, schema
                    <tau, [id, TradePrice, AveragePrice]>; ND precomputed.
* ``token_stream``— LM training pipeline: Zipf tokens framed into
                    (inputs, labels, mask) batches through the windowed
                    batch-assembly operator.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

from repro.core import tuples as T


def _key_of(words: np.ndarray, k_virt: int) -> np.ndarray:
    return (words * 2654435761 % 2**31 % k_virt).astype(np.int32)


def _pair_key(w1, w2, k_virt):
    return ((w1 * 1000003 + w2) * 2654435761 % 2**31 % k_virt).astype(np.int32)


def tweets(rng: np.random.Generator, *, n_ticks: int, tick: int,
           words_per_tweet: int, vocab: int, k_virt: int,
           mode: str = "wordcount", pair_dist: int = 3,
           rate_per_tick: int = 100,
           n_sources: int = 1) -> Iterator[T.TupleBatch]:
    """mode: wordcount | paircount.  Keys materialized into the key set
    (f_MK output), payload[0] = tweet length (for the longest-tweet A+).

    ``n_sources > 1`` spreads the tuples over that many physical input
    streams (multi-host ingest workloads): the global tick is tau-sorted, so
    every per-source sub-stream is timestamp-sorted too — the ScaleGate
    source contract (§2.4) holds per source by construction."""
    tau = 0
    if mode == "wordcount":
        kmax = words_per_tweet
    else:
        d = min(pair_dist, words_per_tweet - 1)
        kmax = sum(min(d, words_per_tweet - 1 - i)
                   for i in range(words_per_tweet))
    for _ in range(n_ticks):
        taus = np.sort(tau + rng.integers(0, rate_per_tick, tick)
                       ).astype(np.int32)
        tau = int(taus.max()) + 1
        words = rng.zipf(1.3, (tick, words_per_tweet)).astype(np.int64) % vocab
        keys = np.full((tick, kmax), -1, np.int32)
        if mode == "wordcount":
            keys[:, :words_per_tweet] = _key_of(words, k_virt)
        else:
            col = 0
            for i in range(words_per_tweet):
                for j in range(i + 1, min(i + 1 + pair_dist,
                                          words_per_tweet)):
                    keys[:, col] = _pair_key(words[:, i], words[:, j], k_virt)
                    col += 1
        payload = np.full((tick, 1), float(words_per_tweet), np.float32)
        source = (rng.integers(0, n_sources, tick).astype(np.int32)
                  if n_sources > 1 else None)
        yield T.make_batch(taus, payload, keys=keys, source=source, kmax=kmax)


def scalejoin(rng: np.random.Generator, *, n_ticks: int, tick: int,
              k_virt: int, rate_t_per_s: float = 2000.0,
              payload_width: int = 4) -> Iterator[T.TupleBatch]:
    """Two timestamp-sorted streams (L/R) with the [13] benchmark payloads
    (attrs uniform in [1, 10000]); f_MK = all virtual keys (Operator 3)."""
    tau = 0
    dt = max(int(1000 * tick / rate_t_per_s), 1)  # ms covered per tick
    keys = np.tile(np.arange(k_virt, dtype=np.int32), (tick, 1))
    for _ in range(n_ticks):
        taus = np.sort(tau + rng.integers(0, dt, tick)).astype(np.int32)
        tau = int(taus.max()) + 1
        src = rng.integers(0, 2, tick).astype(np.int32)
        payload = rng.uniform(1, 10000, (tick, payload_width)
                              ).astype(np.float32)
        yield T.make_batch(taus, payload, keys=keys, source=src, kmax=k_virt)


def nyse(rng: np.random.Generator, *, n_ticks: int, tick: int,
         n_companies: int = 10, k_virt: int = 64) -> Iterator[T.TupleBatch]:
    """Q6-style trades: bursty rate, payload [id, ND] (normalized distance
    precomputed at ingress, cf. §8.6); self-join feeds both streams."""
    tau = 0
    avg = rng.uniform(50, 500, n_companies)
    keys = np.tile(np.arange(k_virt, dtype=np.int32), (tick, 1))
    for t in range(n_ticks):
        rate = max(float(rng.uniform(0, 8000) *
                         (1 + 3 * (rng.random() < 0.05))), 100.0)
        dt = max(int(1000 * tick / rate), 1)
        taus = np.sort(tau + rng.integers(0, dt, tick)).astype(np.int32)
        tau = int(taus.max()) + 1
        ids = rng.integers(0, n_companies, tick)
        price = avg[ids] * rng.normal(1.0, 0.02, tick)
        nd = (price - avg[ids]) / avg[ids]
        payload = np.stack([ids.astype(np.float32),
                            nd.astype(np.float32)], axis=1)
        src = rng.integers(0, 2, tick).astype(np.int32)
        yield T.make_batch(taus, payload, keys=keys, source=src, kmax=k_virt)


def token_batches(rng: np.random.Generator, *, vocab: int, batch: int,
                  seq: int, n_batches: int):
    """Synthetic LM corpus: Zipf unigrams with local bigram structure."""
    for _ in range(n_batches):
        x = rng.zipf(1.2, (batch, seq + 1)).astype(np.int64) % vocab
        x = np.maximum(x, 1)
        yield {
            "inputs": x[:, :-1].astype(np.int32),
            "labels": x[:, 1:].astype(np.int32),
            "mask": np.ones((batch, seq), np.float32),
        }
