"""Backend-dispatched public entry points for the segment_aggregate kernel."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.segment_aggregate.ref import segment_aggregate_ref
from repro.kernels.segment_aggregate.segment_aggregate import (pallas_specs,
                                                               segment_aggregate)


def _xla(keys, slots, vals, acc, *, tile_k=None):
    del tile_k                      # a Pallas tiling knob; XLA fuses freely
    return segment_aggregate_ref(keys, slots, vals, acc)


dispatch.register_kernel("segment_aggregate",
                         pallas=segment_aggregate, xla=_xla)


def _lowering_case():
    from repro.kernels import lowering
    n, w, k, s, tile_k = 128, 2, 256, 4, 128
    return lowering.KernelCase(
        "segment_aggregate",
        fn=functools.partial(segment_aggregate, tile_k=tile_k),
        args=(jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
              jnp.zeros((n, w), jnp.float32),
              jnp.zeros((k, s, w), jnp.float32)),
        specs=pallas_specs(n, w, k, s, tile_k))


dispatch.register_lint("segment_aggregate", _lowering_case)


@functools.partial(jax.jit, static_argnames=("tile_k", "backend"))
def _impl(keys, slots, vals, acc, *, tile_k, backend):
    fn = dispatch.lookup("segment_aggregate", backend)
    return fn(keys, slots, vals, acc, tile_k=tile_k)


def segment_aggregate_op(keys, slots, vals, acc, *, tile_k=128, backend=None):
    return _impl(keys, slots, vals, acc, tile_k=tile_k,
                 backend=dispatch.resolve(backend))


segment_aggregate_ref_op = jax.jit(segment_aggregate_ref)
