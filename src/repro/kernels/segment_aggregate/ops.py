"""Jitted public entry points for the segment_aggregate kernel."""

import functools

import jax

from repro.kernels.segment_aggregate.ref import segment_aggregate_ref
from repro.kernels.segment_aggregate.segment_aggregate import segment_aggregate


@functools.partial(jax.jit, static_argnames=("tile_k", "interpret"))
def segment_aggregate_op(keys, slots, vals, acc, *, tile_k=128,
                         interpret=True):
    return segment_aggregate(keys, slots, vals, acc, tile_k=tile_k,
                             interpret=interpret)


segment_aggregate_ref_op = jax.jit(segment_aggregate_ref)
