"""Pallas TPU kernel: keyed windowed segment-reduce (paper Q1 hot loop).

The wordcount/paircount update phase: N (tuple-hit, key, slot) records are
reduced into the [K, S, W] window-state accumulator.  Intra-chip VSN again:
the hit records live once in HBM (the shared tuple block); the grid programs
each own a contiguous tile of virtual-key rows and *scan the whole block*,
accumulating only the records whose key falls in their tile — the
shared-read/disjoint-write discipline of Theorem 3, with zero scatter
conflicts by construction (a scatter-free formulation: the gather+mask turns
the random scatter into dense VPU selects, which is the TPU-native shape of
the paper's per-key f_R loop).

Shapes
  keys   i32[N]      virtual key per hit (-1 = dead lane)
  slots  i32[N]      window slot per hit
  vals   f32[N, W]   contribution (1.0 for counts)
  acc    f32[K, S, W]  accumulator (donated/read-modify-write)
out
  acc'   f32[K, S, W]

Tiling: grid over K tiles; per step VMEM holds the (N,W) block + a
(TK, S, W) accumulator tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(n_slots, tile_k, keys_ref, slots_ref, vals_ref, acc_ref, out_ref):
    i = pl.program_id(0)
    keys = keys_ref[...]                  # [N]
    slots = slots_ref[...]                # [N]
    vals = vals_ref[...]                  # [N, W]
    lo = i * tile_k

    local = keys - lo                     # key row within this tile
    in_tile = (local >= 0) & (local < tile_k) & (keys >= 0)

    # dense one-hot accumulate: [N, TK*S] contributions -> sum over N.
    # (TK*S is lane-dim friendly; the matmul form feeds the MXU.)
    flat_idx = local * n_slots + slots
    onehot = (flat_idx[:, None] == jnp.arange(tile_k * n_slots)[None, :])
    onehot = jnp.where(in_tile[:, None], onehot, False)
    contrib = jnp.dot(onehot.astype(vals.dtype).T, vals,
                      preferred_element_type=jnp.float32)  # [TK*S, W]
    out_ref[...] = acc_ref[...] + contrib.reshape(acc_ref.shape)


def segment_aggregate(keys, slots, vals, acc, *, tile_k: int = 128,
                      interpret: bool = False):
    n, w = vals.shape
    k, s, w2 = acc.shape
    assert w == w2
    tile_k = min(tile_k, k)
    assert k % tile_k == 0
    grid = (k // tile_k,)

    kern = functools.partial(_kernel, s, tile_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),          # shared hit block
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, w), lambda i: (0, 0)),
            pl.BlockSpec((tile_k, s, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_k, s, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, s, w), acc.dtype),
        interpret=interpret,
    )(keys, slots, vals, acc)
