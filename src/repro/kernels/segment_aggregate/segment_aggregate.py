"""Pallas TPU kernel: keyed windowed segment-reduce (paper Q1 hot loop).

The wordcount/paircount update phase: N (tuple-hit, key, slot) records are
reduced into the [K, S, W] window-state accumulator.  Intra-chip VSN again:
the hit records live once in HBM (the shared tuple block); the grid programs
each own a contiguous tile of virtual-key rows and *scan the whole block*,
accumulating only the records whose key falls in their tile — the
shared-read/disjoint-write discipline of Theorem 3, with zero scatter
conflicts by construction (a scatter-free formulation: the gather+mask turns
the random scatter into dense VPU selects, which is the TPU-native shape of
the paper's per-key f_R loop).

Mosaic-ready layout (ISSUE 5): the hit block is lane-major — keys/slots
enter as rank-2 ``(1, N)`` rows with N padded to a multiple of 128, the
one-hot is built with a rank-2 ``broadcasted_iota`` over ``(TK*S, N)``
(rows = flattened accumulator cells, lanes = hits), and the reduction is a
single ``dot_general`` contracting the lane dim against ``vals [N, W]`` —
the MXU shape, with no rank-1 BlockSpecs and no 1-D iota anywhere.

Shapes
  keys   i32[N]      virtual key per hit (-1 = dead lane)
  slots  i32[N]      window slot per hit
  vals   f32[N, W]   contribution (1.0 for counts)
  acc    f32[K, S, W]  accumulator (donated/read-modify-write)
out
  acc'   f32[K, S, W]

Tiling: grid over K tiles; per step VMEM holds the (N,W) block + a
(TK, S, W) accumulator tile + the (TK*S, N) one-hot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128                     # hit-block lane padding quantum


def _kernel(n_slots, tile_k, keys_ref, slots_ref, vals_ref, acc_ref, out_ref):
    i = pl.program_id(0)
    keys = keys_ref[...]                  # [1, N]
    slots = slots_ref[...]                # [1, N]
    vals = vals_ref[...]                  # [N, W]
    lo = i * tile_k

    local = keys - lo                     # key row within this tile
    in_tile = (local >= 0) & (local < tile_k) & (keys >= 0)

    # dense one-hot accumulate: rows = flattened (key, slot) cells of this
    # tile, lanes = hits; the dot_general contracts the hit lanes on the MXU.
    flat_idx = local * n_slots + slots    # [1, N]
    rows = jax.lax.broadcasted_iota(jnp.int32,
                                    (tile_k * n_slots, keys.shape[1]), 0)
    onehot = (rows == flat_idx) & in_tile           # [TK*S, N]
    contrib = jax.lax.dot_general(
        onehot.astype(vals.dtype), vals, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # [TK*S, W]
    out_ref[...] = acc_ref[...] + contrib.reshape(out_ref.shape)


def pallas_specs(n: int, w: int, k: int, s: int, tile_k: int,
                 dtype=jnp.float32):
    """Grid/Block/out structure, shared with the lowering lint.  The hit
    block is broadcast to every program (same HBM block); the accumulator
    tile walks the key axis.  All specs rank >= 2, hits lane-major."""
    return dict(
        grid=(k // tile_k,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),      # shared hit block
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((n, w), lambda i: (0, 0)),
            pl.BlockSpec((tile_k, s, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_k, s, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, s, w), dtype),
    )


def segment_aggregate(keys, slots, vals, acc, *, tile_k: int = 128,
                      interpret: bool = False):
    n, w = vals.shape
    k, s, w2 = acc.shape
    assert w == w2
    tile_k = min(tile_k, k)
    assert k % tile_k == 0

    # lane-align the hit block: padding lanes carry key -1 (dead) and zero
    # contribution, so every backend reduces the identical value.
    n_pad = -(-n // LANES) * LANES
    if n_pad != n:
        keys = jnp.pad(keys, (0, n_pad - n), constant_values=-1)
        slots = jnp.pad(slots, (0, n_pad - n))
        vals = jnp.pad(vals, ((0, n_pad - n), (0, 0)))

    kern = functools.partial(_kernel, s, tile_k)
    return pl.pallas_call(
        kern,
        **pallas_specs(n_pad, w, k, s, tile_k, acc.dtype),
        interpret=interpret,
    )(keys.reshape(1, n_pad), slots.reshape(1, n_pad), vals, acc)
