"""Pure-jnp oracle for the segment_aggregate kernel."""

import jax.numpy as jnp


def segment_aggregate_ref(keys, slots, vals, acc):
    k = acc.shape[0]
    ok = keys >= 0
    safe_k = jnp.clip(keys, 0, k - 1)
    upd = jnp.where(ok[:, None], vals, 0.0)
    return acc.at[safe_k, slots].add(upd, mode="drop")
