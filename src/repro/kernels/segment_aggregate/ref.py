"""Pure-jnp oracle for the segment_aggregate kernel."""

import jax.numpy as jnp


def segment_aggregate_ref(keys, slots, vals, acc):
    # out-of-range keys (either side) are dead lanes, exactly as the Pallas
    # kernel's in_tile mask drops them — the backends must never diverge.
    k = acc.shape[0]
    ok = (keys >= 0) & (keys < k)
    safe_k = jnp.clip(keys, 0, k - 1)
    upd = jnp.where(ok[:, None], vals, 0.0)
    return acc.at[safe_k, slots].add(upd, mode="drop")
