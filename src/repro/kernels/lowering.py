"""Mosaic-lowering readiness: structural lint + best-effort AOT smoke.

The Pallas interpreter (and the jnp ``xla`` oracles) will happily execute
kernel shapes the Mosaic TPU compiler rejects — rank-1 BlockSpecs and 1-D
iota/``jnp.arange`` are the canonical offenders (ROADMAP: "what the
interpreter hides").  This module makes that class of regression
*structurally impossible to miss* without TPU hardware in CI:

* ``lint_case`` checks a kernel's declared call structure (every
  ``BlockSpec`` block shape and every ``out_shape`` must be rank >= 2) and
  walks the traced kernel jaxpr inside each ``pallas_call`` equation for
  rank-1 ``iota`` — the primitive both ``jnp.arange`` and 1-D
  ``jax.lax.iota`` lower to.  Pure tracing: runs on any host, no TPU.
* ``lowering_smoke`` additionally runs ``jax.jit(...).lower()`` — the full
  Mosaic pipeline — when a TPU backend is actually present (CI keeps a
  ``REPRO_TPU=1`` job stub ready for hardware bring-up).

Each ``kernels/*/ops.py`` registers a ``KernelCase`` factory with
``dispatch.register_lint``; the kernel modules expose their exact
``pallas_specs(...)`` so the linted structure can never drift from the
executed one.  ``tests/test_lowering_lint.py`` runs the lint over every
registered kernel as a tier-1 regression gate.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics: Optional[Tuple[str, ...]] = None):
    """Best-effort ``TPUCompilerParams`` across jax versions (renamed to
    ``CompilerParams`` upstream); ``None`` when the running jax has
    neither — callers then simply omit ``compiler_params``."""
    cls = (getattr(pltpu, "CompilerParams", None)
           or getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        return None
    try:
        return cls(dimension_semantics=dimension_semantics)
    except TypeError:                        # pragma: no cover - old signature
        return None


@dataclasses.dataclass(frozen=True)
class KernelCase:
    """One lintable kernel: its public entry, representative inputs, and
    the spec structure the entry hands to ``pallas_call``."""
    name: str
    fn: Callable                  # full kernel entry; takes ``args`` arrays
    args: tuple                   # representative (small, padded) inputs
    specs: dict                   # grid / in_specs / out_specs / out_shape


@dataclasses.dataclass
class LintReport:
    kernel: str
    errors: List[str]

    @property
    def ok(self) -> bool:
        return not self.errors


def _as_list(x) -> list:
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _spec_errors(case: KernelCase) -> List[str]:
    """Rank-1 BlockSpecs / out_shapes are Mosaic-unlowerable: reject."""
    errs = []
    for field in ("in_specs", "out_specs"):
        for i, bs in enumerate(_as_list(case.specs.get(field, ()))):
            shape = tuple(bs.block_shape)
            if len(shape) < 2:
                errs.append(f"{field}[{i}]: rank-{len(shape)} BlockSpec "
                            f"{shape} (Mosaic needs rank >= 2)")
    for i, sds in enumerate(_as_list(case.specs.get("out_shape", ()))):
        if len(sds.shape) < 2:
            errs.append(f"out_shape[{i}]: rank-{len(sds.shape)} "
                        f"{tuple(sds.shape)} (Mosaic needs rank >= 2)")
    return errs


def _as_jaxpr(item):
    """Duck-typed Jaxpr/ClosedJaxpr detection — the classes moved between
    ``jax.core`` and ``jax.extend.core`` across the supported versions."""
    if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
        return item.jaxpr                    # ClosedJaxpr
    if hasattr(item, "eqns") and hasattr(item, "invars"):
        return item                          # Jaxpr
    return None


def _sub_jaxprs(jaxpr) -> Sequence:
    """All jaxprs reachable from ``jaxpr``'s equation params (scan/cond/
    closed_call bodies ...), one level; callers recurse."""
    found = []
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            for item in (val if isinstance(val, (list, tuple)) else [val]):
                sub = _as_jaxpr(item)
                if sub is not None:
                    found.append(sub)
    return found


def _iota_errors_in(jaxpr, where: str) -> List[str]:
    """Rank-1 iota anywhere under ``jaxpr`` (incl. scan/loop bodies)."""
    errs = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "iota":
            shape = tuple(eqn.params.get("shape", ()))
            if len(shape) < 2:
                errs.append(f"{where}: 1-D iota {shape} "
                            f"(use jax.lax.broadcasted_iota, rank >= 2)")
    for sub in _sub_jaxprs(jaxpr):
        errs.extend(_iota_errors_in(sub, where))
    return errs


def _trace_errors(case: KernelCase) -> List[str]:
    """Trace the public entry and lint the kernel jaxpr inside every
    pallas_call equation (the surrounding XLA-land padding shims may use
    1-D iota freely — only the Mosaic-bound body is constrained)."""
    try:
        traced = jax.make_jaxpr(case.fn)(*case.args)
    except Exception as e:                   # pragma: no cover - trace bug
        return [f"trace failed: {type(e).__name__}: {e}"]
    errs = []
    n_calls = 0

    def walk(jaxpr):
        nonlocal n_calls
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n_calls += 1
                inner = _as_jaxpr(eqn.params.get("jaxpr"))
                if inner is not None:
                    errs.extend(_iota_errors_in(
                        inner, f"{case.name} kernel body"))
        for sub in _sub_jaxprs(jaxpr):
            walk(sub)

    walk(traced.jaxpr)
    if n_calls == 0:
        errs.append("no pallas_call found in trace (lint case is broken)")
    return errs


def lint_case(case: KernelCase) -> LintReport:
    """The structural Mosaic lint: spec ranks + kernel-body iota ranks."""
    return LintReport(case.name, _spec_errors(case) + _trace_errors(case))


def lint_registered() -> Dict[str, LintReport]:
    """Lint every kernel registered via ``dispatch.register_lint``."""
    from repro.kernels import dispatch

    reports = {}
    for name, case_fn in sorted(dispatch.lint_cases().items()):
        reports[name] = lint_case(case_fn())
    return reports


def tpu_present() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:                        # pragma: no cover
        return False


def smoke_requested() -> bool:
    """The real-hardware gate: CI sets REPRO_TPU=1 on the TPU runner."""
    return os.environ.get("REPRO_TPU") == "1"


def lowering_smoke(case: KernelCase) -> Optional[str]:
    """Best-effort AOT ``jit(...).lower()`` through the full Mosaic
    pipeline.  Returns ``None`` on success, a skip reason when no TPU
    backend is attached, and raises on a genuine lowering failure."""
    if not tpu_present():
        return "no TPU backend attached (structural lint still ran)"
    jax.jit(case.fn).lower(*case.args)       # raises on Mosaic rejection
    return None
