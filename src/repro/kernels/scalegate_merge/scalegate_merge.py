"""Pallas TPU kernel: ScaleGate k-way sorted merge + readiness (paper §2.4).

The synchronization-free TPU rendering of ScaleGate: given the tick's tuples
(already tagged with source ids, each source's lanes timestamp-sorted), the
kernel produces the *total order* every reader observes — a bitonic sort
network over (tau, lane) in VMEM — plus the Definition-3 watermark
``W = min_i max_m tau_i^m`` and per-lane readiness ``tau <= W``.

The network compares (tau, arrival-lane) lexicographically — the lane
tie-break rides along as the carried index — so the sort is
stable-deterministic over the full int32 tau range (no packed-key
composite, no overflow restriction).

Single-program kernel (ticks are small: <= 4K lanes), entire tick resident
in VMEM; the bitonic network is log^2(n) masked min/max passes — pure VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.watermark import INF_TIME


def _bitonic_sort(keys, idx):
    """In-register bitonic sort of (keys, idx); n = power of two.

    Each compare-exchange pass is expressed as a reshape to
    ``[n/(2*stride), 2, stride]``: the two partner lanes (``lane ^ stride``)
    land in the middle axis, so the exchange is a vectorized select
    instead of an n-way per-lane gather (``keys[partner]``) — the gather form
    lowers to n scalar loads per pass under the Pallas interpreter and is
    what made interpret-mode runs minutes-long.  Equal keys tie-break on the
    carried original lane (``idx``), making the order total and stable over
    the whole int32 key range.
    """
    n = keys.shape[0]
    stages = n.bit_length() - 1
    for stage in range(stages):
        for sub in range(stage, -1, -1):
            stride = 1 << sub
            groups = n // (2 * stride)
            ks = keys.reshape(groups, 2, stride)
            ix = idx.reshape(groups, 2, stride)
            lo_k, hi_k = ks[:, 0], ks[:, 1]
            lo_i, hi_i = ix[:, 0], ix[:, 1]
            # block direction: ascending iff bit (stage+1) of the lane is 0;
            # constant within a group (2*stride <= 2^(stage+1), aligned).
            first_lane = (jax.lax.broadcasted_iota(jnp.int32, (groups, 1), 0)
                          * (2 * stride))
            dir_up = (first_lane & (1 << (stage + 1))) == 0
            lex_gt = (lo_k > hi_k) | ((lo_k == hi_k) & (lo_i > hi_i))
            lex_lt = (lo_k < hi_k) | ((lo_k == hi_k) & (lo_i < hi_i))
            swap = jnp.where(dir_up, lex_gt, lex_lt)
            new_lo_k = jnp.where(swap, hi_k, lo_k)
            new_hi_k = jnp.where(swap, lo_k, hi_k)
            new_lo_i = jnp.where(swap, hi_i, lo_i)
            new_hi_i = jnp.where(swap, lo_i, hi_i)
            keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(n)
            idx = jnp.stack([new_lo_i, new_hi_i], axis=1).reshape(n)
    return keys, idx


def _kernel(n_sources, tau_ref, src_ref, valid_ref,
            order_ref, ready_ref, wmark_ref):
    tau = tau_ref[...]
    src = src_ref[...]
    valid = valid_ref[...] != 0
    n = tau.shape[0]
    lane = jnp.arange(n)

    # Definition 3 watermark: min over sources of (max tau per source).
    per_src_max = jnp.full((n_sources,), -1, jnp.int32)
    src_onehot = (src[None, :] == jnp.arange(n_sources)[:, None]) & valid[None]
    per_src_max = jnp.max(jnp.where(src_onehot, tau[None, :], -1), axis=1)
    w = jnp.min(per_src_max)
    wmark_ref[0] = w

    key = jnp.where(valid, tau, INF_TIME)
    skey, order = _bitonic_sort(key, lane)
    order_ref[...] = order
    ready_ref[...] = jnp.where(valid[order] & (tau[order] <= w), 1, 0
                               ).astype(jnp.int32)


def scalegate_merge(tau, src, valid, *, n_sources: int,
                    interpret: bool = False):
    n = tau.shape[0]
    assert n & (n - 1) == 0, "tick size must be a power of two"

    kern = functools.partial(_kernel, n_sources)
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(tau, src, valid.astype(jnp.int32))
