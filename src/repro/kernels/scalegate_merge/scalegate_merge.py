"""Pallas TPU kernel: ScaleGate k-way sorted merge + readiness (paper §2.4).

The synchronization-free TPU rendering of ScaleGate: given the tick's tuples
(already tagged with source ids, each source's lanes timestamp-sorted), the
kernel produces the *total order* every reader observes — a bitonic sort
network over (tau, lane) in VMEM — plus the Definition-3 watermark
``W = min_i max_m tau_i^m`` and per-lane readiness ``tau <= W``.

The network compares (tau, arrival-lane) lexicographically — the lane
tie-break rides along as the carried index — so the sort is
stable-deterministic over the full int32 tau range (no packed-key
composite, no overflow restriction).

Mosaic-ready layout (ISSUE 5): the tick lives in VMEM as a rank-2
``(rows, 128)`` tile — the lane dim is the TPU vector lane dim — and every
compare-exchange pass is a *roll*: the bitonic partner of flat lane ``p``
at stride ``s`` is ``p ^ s``, which for the lanes with bit ``s`` clear is
``p + s`` (one roll left) and for the rest ``p - s`` (one roll right).
Strides below 128 roll the lane axis, strides at/above 128 roll the
sublane axis — no rank-1 iota, no gathers, no lane-dim reshapes, which is
exactly what the Mosaic lowering path needs (``pltpu.roll`` is the native
lane rotation).  The carried triple is ``(key, lane, valid)`` so readiness
never gathers back through the permutation.

Single-program kernel (ticks are small: <= 4K lanes), entire tick resident
in VMEM.  ``scalegate_merge`` pads any batch to the next power of two of
at least 128 lanes; padding lanes carry ``(INF_TIME, lane >= n)`` keys, so
they sort strictly after every real lane and the first ``n`` sorted
positions are exactly the unpadded order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.watermark import INF_TIME

LANES = 128                     # TPU vector lane width (last-dim tile)


def _roll(x, shift, axis):
    """Circular shift; ``pltpu.roll`` is the Mosaic-native lane rotation
    (its shift must be non-negative, so normalize mod the axis size)."""
    return pltpu.roll(x, shift % x.shape[axis], axis)


def _cmp_exchange(key, idx, val, stride, asc):
    """One bitonic compare-exchange pass over the row-major (R, 128) tile.

    ``stride`` pairs flat lane ``p`` with ``p ^ stride``; ``asc`` is the
    per-lane ascending-block mask of the enclosing stage.  The pass is two
    rolls + selects per carried array: lanes with the stride bit clear
    read their partner ``stride`` ahead, the others ``stride`` behind.
    """
    r, c = key.shape
    if stride >= c:
        axis, sh = 0, stride // c
        coord = jax.lax.broadcasted_iota(jnp.int32, (r, c), 0)
    else:
        axis, sh = 1, stride
        coord = jax.lax.broadcasted_iota(jnp.int32, (r, c), 1)
    is_lo = (coord & sh) == 0

    def partner(x):
        return jnp.where(is_lo, _roll(x, -sh, axis), _roll(x, sh, axis))

    pk, pi, pv = partner(key), partner(idx), partner(val)
    # (key, idx) pairs are unique, so strict lexicographic > is total.
    lex_gt = (key > pk) | ((key == pk) & (idx > pi))
    # In an ascending block the lo lane keeps the smaller element (and the
    # hi lane the larger); descending blocks mirror.  ``take`` selects the
    # partner's element exactly when ours is on the wrong side.
    take = jnp.where(asc == is_lo, lex_gt, ~lex_gt)
    return (jnp.where(take, pk, key), jnp.where(take, pi, idx),
            jnp.where(take, pv, val))


def _sort_ready(tau, valid, w):
    """The shared bitonic body: sort (tau, arrival) over the [R, 128] tile
    and gate readiness against the scalar watermark ``w`` without a gather
    (the carried key equals tau on valid lanes by construction).  Returns
    ``(order, ready)`` tiles; used by both the flat and the stacked-leaf
    kernels so their traced networks can never drift apart."""
    r, c = tau.shape
    vb = valid != 0
    lane = (jax.lax.broadcasted_iota(jnp.int32, (r, c), 0) * c
            + jax.lax.broadcasted_iota(jnp.int32, (r, c), 1))
    key = jnp.where(vb, tau, INF_TIME)
    idx = lane
    val = valid
    n = r * c
    stages = n.bit_length() - 1
    for stage in range(stages):
        # block direction: ascending iff bit (stage+1) of the flat lane is
        # 0 — constant within each 2^(stage+1)-aligned bitonic block.
        asc = (lane & (1 << (stage + 1))) == 0
        for sub in range(stage, -1, -1):
            key, idx, val = _cmp_exchange(key, idx, val, 1 << sub, asc)
    ready = jnp.where((val != 0) & (key <= w), 1, 0).astype(jnp.int32)
    return idx, ready


def _kernel(n_sources, tau_ref, src_ref, valid_ref,
            order_ref, ready_ref, wmark_ref):
    tau = tau_ref[...]                    # [R, 128] i32
    src = src_ref[...]                    # [R, 128] i32
    valid = valid_ref[...]                # [R, 128] i32 (0/1)
    vb = valid != 0

    # Definition 3 watermark: min over sources of (max tau per source).
    # n_sources is static and small — an unrolled scalar min-of-max chain
    # instead of a rank-1 per-source vector.
    w = None
    for s_id in range(n_sources):
        s_max = jnp.max(jnp.where((src == s_id) & vb, tau, -1))
        w = s_max if w is None else jnp.minimum(w, s_max)
    wmark_ref[0, 0] = w

    order_ref[...], ready_ref[...] = _sort_ready(tau, valid, w)


def _stacked_kernel(tau_ref, valid_ref, rep_ref,
                    order_ref, ready_ref, wmark_ref):
    """Stacked-leaf fused root merge: the watermark is not derived from the
    tuples but from the leaves' *reported* frontiers (explicit-watermark
    mode, paper §6) — ``rep_ref`` is a (1, 128) tile of per-leaf effective
    frontiers, INF on inactive/absent lanes, so ``W = min(rep)`` is the
    Definition-3 composition ``W_root = min_leaf W_leaf``."""
    w = jnp.min(rep_ref[...])
    wmark_ref[0, 0] = w
    order_ref[...], ready_ref[...] = _sort_ready(tau_ref[...],
                                                 valid_ref[...], w)


def pallas_specs(n_rows: int):
    """The call's grid/Block/out structure — shared with the lowering lint
    (kernels/lowering.py) so the linted shape can never drift from the
    executed one.  Everything is rank >= 2 with a 128 lane dim."""
    tile = (n_rows, LANES)
    return dict(
        grid=(1,),
        in_specs=[pl.BlockSpec(tile, lambda i: (0, 0)),
                  pl.BlockSpec(tile, lambda i: (0, 0)),
                  pl.BlockSpec(tile, lambda i: (0, 0))],
        out_specs=[pl.BlockSpec(tile, lambda i: (0, 0)),
                   pl.BlockSpec(tile, lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(tile, jnp.int32),
                   jax.ShapeDtypeStruct(tile, jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
    )


def scalegate_merge(tau, src, valid, *, n_sources: int,
                    interpret: bool = False):
    """-> (order i32[N], ready i32[N], watermark i32[1]); any N >= 1.

    N is padded internally to the next power of two of at least 128 lanes
    and laid out as (N/128, 128); padding lanes are invalid with the
    largest arrival indices, so they sort after every real lane and
    ``order[:N]`` is exactly the unpadded (tau, arrival) total order.
    """
    n = tau.shape[0]
    n_pad = max(LANES, 1 << (n - 1).bit_length()) if n > 1 else LANES
    valid = valid.astype(jnp.int32)
    if n_pad != n:
        tau = jnp.pad(tau, (0, n_pad - n))
        src = jnp.pad(src, (0, n_pad - n))
        valid = jnp.pad(valid, (0, n_pad - n))
    rows = n_pad // LANES

    kern = functools.partial(_kernel, n_sources)
    order2, ready2, w2 = pl.pallas_call(
        kern,
        **pallas_specs(rows),
        interpret=interpret,
    )(tau.reshape(rows, LANES), src.reshape(rows, LANES),
      valid.reshape(rows, LANES))
    return (order2.reshape(n_pad)[:n], ready2.reshape(n_pad)[:n],
            w2.reshape(1))


def pallas_specs_stacked(n_rows: int):
    """Grid/Block/out structure of the stacked-leaf entry — shared with its
    lowering-lint case.  Three rank-2 inputs: the (rows, 128) tau and valid
    tiles plus the (1, 128) reported-frontier tile."""
    tile = (n_rows, LANES)
    return dict(
        grid=(1,),
        in_specs=[pl.BlockSpec(tile, lambda i: (0, 0)),
                  pl.BlockSpec(tile, lambda i: (0, 0)),
                  pl.BlockSpec((1, LANES), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec(tile, lambda i: (0, 0)),
                   pl.BlockSpec(tile, lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(tile, jnp.int32),
                   jax.ShapeDtypeStruct(tile, jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
    )


def scalegate_merge_stacked(tau2, src2, valid2, reports, *,
                            interpret: bool = False):
    """Fused root merge over stacked per-leaf chunk rows.

    -> (order i32[R, C] flat row-major indices, ready i32[R, C],
        watermark i32[1]); any rank-2 input, ``reports`` i32[L <= 128]
    pre-masked per-leaf effective frontiers (INF for inactive leaves).

    The [R, C] buffer is flattened row-major (arrival = flat index), padded
    to a power-of-two (rows, 128) tile like the flat kernel, and sorted by
    the same (tau, arrival) bitonic network; the watermark gate is the min
    over the reported frontiers instead of the per-source fold, so a single
    kernel call replaces the root's whole per-round merge.  ``src2`` rides
    along for signature parity with the xla oracle; the (tau, arrival)
    contract does not consult it (see core.scalegate.TIE_BREAK).
    """
    del src2
    r_in, c_in = tau2.shape
    n = r_in * c_in
    tau = tau2.reshape(n)
    valid = valid2.astype(jnp.int32).reshape(n)
    n_pad = max(LANES, 1 << (n - 1).bit_length()) if n > 1 else LANES
    if n_pad != n:
        tau = jnp.pad(tau, (0, n_pad - n))
        valid = jnp.pad(valid, (0, n_pad - n))
    n_leaves = reports.shape[0]
    assert n_leaves <= LANES, f"{n_leaves} leaves exceed one report tile"
    rep = jnp.pad(reports.astype(jnp.int32), (0, LANES - n_leaves),
                  constant_values=INF_TIME).reshape(1, LANES)
    rows = n_pad // LANES

    order2, ready2, w2 = pl.pallas_call(
        _stacked_kernel,
        **pallas_specs_stacked(rows),
        interpret=interpret,
    )(tau.reshape(rows, LANES), valid.reshape(rows, LANES), rep)
    return (order2.reshape(n_pad)[:n].reshape(r_in, c_in),
            ready2.reshape(n_pad)[:n].reshape(r_in, c_in),
            w2.reshape(1))
