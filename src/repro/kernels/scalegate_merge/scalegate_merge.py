"""Pallas TPU kernel: ScaleGate k-way sorted merge + readiness (paper §2.4).

The synchronization-free TPU rendering of ScaleGate: given the tick's tuples
(already tagged with source ids, each source's lanes timestamp-sorted), the
kernel produces the *total order* every reader observes — a bitonic sort
network over (tau, lane) in VMEM — plus the Definition-3 watermark
``W = min_i max_m tau_i^m`` and per-lane readiness ``tau <= W``.

The sort key packs (tau, arrival-lane) into one i64-free composite so the
network is stable-deterministic: key = tau * LANE_PAD + lane with
LANE_PAD = next_pow2(n), using f32-safe int32 range (tau < 2^31 / LANE_PAD
— enforced by the wrapper; benchmark streams use relative ticks).

Single-program kernel (ticks are small: <= 4K lanes), entire tick resident
in VMEM; the bitonic network is log^2(n) masked min/max passes — pure VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.watermark import INF_TIME


def _bitonic_sort(keys, idx):
    """In-register bitonic sort of (keys, idx); n = power of two."""
    n = keys.shape[0]
    stages = n.bit_length() - 1
    lane = jnp.arange(n)
    for stage in range(stages):
        for sub in range(stage, -1, -1):
            partner = lane ^ (1 << sub)
            dir_up = (lane & (1 << (stage + 1))) == 0
            pk = keys[partner]
            pi = idx[partner]
            first = lane < partner
            # ascending blocks keep min in the lower lane
            keep_self = jnp.where(first == dir_up, keys <= pk, keys >= pk)
            keys = jnp.where(keep_self, keys, pk)
            idx = jnp.where(keep_self, idx, pi)
    return keys, idx


def _kernel(n_sources, lane_pad, tau_ref, src_ref, valid_ref,
            order_ref, ready_ref, wmark_ref):
    tau = tau_ref[...]
    src = src_ref[...]
    valid = valid_ref[...] != 0
    n = tau.shape[0]
    lane = jnp.arange(n)

    # Definition 3 watermark: min over sources of (max tau per source).
    per_src_max = jnp.full((n_sources,), -1, jnp.int32)
    src_onehot = (src[None, :] == jnp.arange(n_sources)[:, None]) & valid[None]
    per_src_max = jnp.max(jnp.where(src_onehot, tau[None, :], -1), axis=1)
    w = jnp.min(per_src_max)
    wmark_ref[0] = w

    key = jnp.where(valid, tau, INF_TIME // lane_pad) * lane_pad + lane
    skey, order = _bitonic_sort(key, lane)
    order_ref[...] = order
    ready_ref[...] = jnp.where(valid[order] & (tau[order] <= w), 1, 0
                               ).astype(jnp.int32)


def scalegate_merge(tau, src, valid, *, n_sources: int,
                    interpret: bool = False):
    n = tau.shape[0]
    assert n & (n - 1) == 0, "tick size must be a power of two"
    lane_pad = 1 << (n - 1).bit_length() if n > 1 else 1

    kern = functools.partial(_kernel, n_sources, max(lane_pad, 2))
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((n,), lambda i: (0,)),
                   pl.BlockSpec((1,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(tau, src, valid.astype(jnp.int32))
