"""Pure-jnp oracle for the scalegate_merge kernel."""

import jax.numpy as jnp

from repro.core.watermark import INF_TIME


def scalegate_merge_ref(tau, src, valid, *, n_sources: int):
    n = tau.shape[0]
    lane = jnp.arange(n)
    src_onehot = (src[None, :] == jnp.arange(n_sources)[:, None]) & valid[None]
    per_src_max = jnp.max(jnp.where(src_onehot, tau[None, :], -1), axis=1)
    w = jnp.min(per_src_max)
    sort_tau = jnp.where(valid, tau, INF_TIME)
    order = jnp.argsort(sort_tau, stable=True).astype(jnp.int32)
    ready = (valid[order] & (tau[order] <= w)).astype(jnp.int32)
    return order, ready, w[None]


def scalegate_merge_stacked_ref(tau2, src2, valid2, reports):
    """Oracle for the stacked-leaf fused root merge: same (tau, arrival)
    contract as the flat kernel (arrival = row-major flat index), with the
    watermark taken from the pre-masked per-leaf reported frontiers instead
    of the per-source fold."""
    del src2
    r, c = tau2.shape
    tau = tau2.reshape(-1)
    valid = (valid2 != 0).reshape(-1)
    w = jnp.min(reports.astype(jnp.int32))
    sort_tau = jnp.where(valid, tau, INF_TIME)
    order = jnp.argsort(sort_tau, stable=True).astype(jnp.int32)
    ready = (valid[order] & (tau[order] <= w)).astype(jnp.int32)
    return order.reshape(r, c), ready.reshape(r, c), w[None]
