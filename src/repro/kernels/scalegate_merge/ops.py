"""Backend-dispatched public entry points for the scalegate_merge kernel."""

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.scalegate_merge.ref import scalegate_merge_ref
from repro.kernels.scalegate_merge.scalegate_merge import scalegate_merge

dispatch.register_kernel("scalegate_merge",
                         pallas=scalegate_merge, xla=scalegate_merge_ref)


@functools.partial(jax.jit, static_argnames=("n_sources", "backend"))
def _impl(tau, src, valid, *, n_sources, backend):
    fn = dispatch.lookup("scalegate_merge", backend)
    return fn(tau, src, valid, n_sources=n_sources)


def scalegate_merge_op(tau, src, valid, *, n_sources, backend=None):
    """-> (order i32[N], ready i32[N], watermark i32[1])."""
    return _impl(tau, src, valid, n_sources=n_sources,
                 backend=dispatch.resolve(backend))


scalegate_merge_ref_op = jax.jit(
    scalegate_merge_ref, static_argnames=("n_sources",))
