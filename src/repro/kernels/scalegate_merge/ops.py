"""Backend-dispatched public entry points for the scalegate_merge kernel."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.scalegate_merge.ref import scalegate_merge_ref
from repro.kernels.scalegate_merge.scalegate_merge import (LANES,
                                                           pallas_specs,
                                                           scalegate_merge)

dispatch.register_kernel("scalegate_merge",
                         pallas=scalegate_merge, xla=scalegate_merge_ref)


def _lowering_case():
    from repro.kernels import lowering
    n = 2 * LANES                       # representative padded tick
    return lowering.KernelCase(
        "scalegate_merge",
        fn=functools.partial(scalegate_merge, n_sources=4),
        args=(jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
              jnp.ones((n,), jnp.int32)),
        specs=pallas_specs(n // LANES))


dispatch.register_lint("scalegate_merge", _lowering_case)


@functools.partial(jax.jit, static_argnames=("n_sources", "backend"))
def _impl(tau, src, valid, *, n_sources, backend):
    fn = dispatch.lookup("scalegate_merge", backend)
    return fn(tau, src, valid, n_sources=n_sources)


def scalegate_merge_op(tau, src, valid, *, n_sources, backend=None):
    """-> (order i32[N], ready i32[N], watermark i32[1])."""
    return _impl(tau, src, valid, n_sources=n_sources,
                 backend=dispatch.resolve(backend))


scalegate_merge_ref_op = jax.jit(
    scalegate_merge_ref, static_argnames=("n_sources",))
