"""Jitted public entry points for the scalegate_merge kernel."""

import functools

import jax

from repro.kernels.scalegate_merge.ref import scalegate_merge_ref
from repro.kernels.scalegate_merge.scalegate_merge import scalegate_merge


@functools.partial(jax.jit, static_argnames=("n_sources", "interpret"))
def scalegate_merge_op(tau, src, valid, *, n_sources, interpret=True):
    return scalegate_merge(tau, src, valid, n_sources=n_sources,
                           interpret=interpret)


scalegate_merge_ref_op = jax.jit(
    scalegate_merge_ref, static_argnames=("n_sources",))
