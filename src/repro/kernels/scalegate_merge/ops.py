"""Backend-dispatched public entry points for the scalegate_merge kernel."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.scalegate_merge.ref import (scalegate_merge_ref,
                                               scalegate_merge_stacked_ref)
from repro.kernels.scalegate_merge.scalegate_merge import (
    LANES, pallas_specs, pallas_specs_stacked, scalegate_merge,
    scalegate_merge_stacked)

dispatch.register_kernel("scalegate_merge",
                         pallas=scalegate_merge, xla=scalegate_merge_ref)

dispatch.register_kernel("scalegate_merge_stacked",
                         pallas=scalegate_merge_stacked,
                         xla=scalegate_merge_stacked_ref)


def _lowering_case():
    from repro.kernels import lowering
    n = 2 * LANES                       # representative padded tick
    return lowering.KernelCase(
        "scalegate_merge",
        fn=functools.partial(scalegate_merge, n_sources=4),
        args=(jnp.zeros((n,), jnp.int32), jnp.zeros((n,), jnp.int32),
              jnp.ones((n,), jnp.int32)),
        specs=pallas_specs(n // LANES))


dispatch.register_lint("scalegate_merge", _lowering_case)


def _stacked_lowering_case():
    from repro.kernels import lowering
    r, c = 4, 64                        # representative stacked leaf rows
    return lowering.KernelCase(
        "scalegate_merge_stacked",
        fn=scalegate_merge_stacked,
        args=(jnp.zeros((r, c), jnp.int32), jnp.zeros((r, c), jnp.int32),
              jnp.ones((r, c), jnp.int32),
              jnp.zeros((8,), jnp.int32)),
        specs=pallas_specs_stacked((r * c) // LANES))


dispatch.register_lint("scalegate_merge_stacked", _stacked_lowering_case)


@functools.partial(jax.jit, static_argnames=("n_sources", "backend"))
def _impl(tau, src, valid, *, n_sources, backend):
    fn = dispatch.lookup("scalegate_merge", backend)
    return fn(tau, src, valid, n_sources=n_sources)


def scalegate_merge_op(tau, src, valid, *, n_sources, backend=None):
    """-> (order i32[N], ready i32[N], watermark i32[1])."""
    return _impl(tau, src, valid, n_sources=n_sources,
                 backend=dispatch.resolve(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _stacked_impl(tau2, src2, valid2, reports, *, backend):
    fn = dispatch.lookup("scalegate_merge_stacked", backend)
    return fn(tau2, src2, valid2, reports)


def scalegate_merge_stacked_op(tau2, src2, valid2, reports, *, backend=None):
    """-> (order i32[R, C] flat indices, ready i32[R, C], watermark i32[1]);
    ``reports`` are the pre-masked per-leaf effective frontiers."""
    return _stacked_impl(tau2, src2, valid2, reports,
                         backend=dispatch.resolve(backend))


scalegate_merge_ref_op = jax.jit(
    scalegate_merge_ref, static_argnames=("n_sources",))
