"""Kernel backend dispatch: one registry, three execution backends.

Every kernel in ``repro/kernels`` ships two realizations — the Pallas TPU
kernel and the pure-jnp ``ref.py`` oracle — and tier-1 must be correct and
*fast* on whatever backend the host actually has.  This registry picks the
realization at call time:

* ``"pallas"``            — the compiled Pallas kernel (TPU).
* ``"pallas-interpret"``  — the same kernel under the Pallas interpreter
                            (CPU-debuggable, slow; used for parity tests).
* ``"xla"``               — the jitted ``ref.py`` oracle, which XLA compiles
                            natively on any host.  This is the CPU fast path.

Resolution order for ``backend=None``:
  1. an explicit ``set_default_backend(...)`` (e.g. ``benchmarks/run.py
     --backend``),
  2. the ``REPRO_KERNEL_BACKEND`` environment variable,
  3. hardware: ``"pallas"`` iff a TPU is visible, else ``"xla"``.

Each ``kernels/*/ops.py`` registers its implementations at import time and
exposes a single ``<name>_op(..., backend=None)`` entry point; the core
callers (``core/scalegate.py``, ``core/aggregate.py``, ``core/join.py``)
and the benchmark harness all go through those entry points, so a backend
switch is one knob for the whole system.
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Dict, Optional

import jax

BACKENDS = ("pallas", "pallas-interpret", "xla")
_ENV_VAR = "REPRO_KERNEL_BACKEND"

_REGISTRY: Dict[str, Dict[str, Callable]] = {}
_LINT_CASES: Dict[str, Callable] = {}
_DEFAULT_BACKEND: Optional[str] = None


class UnknownBackendError(ValueError):
    pass


def _check_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise UnknownBackendError(
            f"backend {backend!r} not in {BACKENDS}")
    return backend


@functools.lru_cache(maxsize=1)
def _has_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def default_backend() -> str:
    """The backend used when callers pass ``backend=None``."""
    if _DEFAULT_BACKEND is not None:
        return _DEFAULT_BACKEND
    env = os.environ.get(_ENV_VAR)
    if env:
        return _check_backend(env)
    return "pallas" if _has_tpu() else "xla"


def set_default_backend(backend: Optional[str]) -> None:
    """Process-wide override (``None`` restores env/hardware resolution)."""
    global _DEFAULT_BACKEND
    _DEFAULT_BACKEND = None if backend is None else _check_backend(backend)


def register(name: str, backend: str, fn: Callable) -> None:
    _REGISTRY.setdefault(name, {})[_check_backend(backend)] = fn


def register_kernel(name: str, *, pallas: Callable, xla: Callable) -> None:
    """Register the standard triple for one kernel.

    ``pallas`` must accept ``interpret=`` (the Pallas-call escape hatch);
    ``xla`` is the jitted ref oracle.
    """
    register(name, "pallas", functools.partial(pallas, interpret=False))
    register(name, "pallas-interpret", functools.partial(pallas,
                                                         interpret=True))
    register(name, "xla", xla)


def resolve(backend: Optional[str] = None) -> str:
    """Resolve ``backend`` (or the default) to a concrete backend name.

    Entry points call this *outside* jit so the resolved name — not
    ``None`` — is the static argument; a later ``set_default_backend``
    therefore can never hit a stale jit cache.
    """
    return _check_backend(backend or default_backend())


def lookup(name: str, backend: Optional[str] = None) -> Callable:
    backend = resolve(backend)
    impls = _REGISTRY.get(name)
    if impls is None:
        raise KeyError(f"no kernel registered under {name!r}; "
                       f"known: {sorted(_REGISTRY)}")
    fn = impls.get(backend)
    if fn is None:
        raise KeyError(f"kernel {name!r} has no {backend!r} implementation; "
                       f"has: {sorted(impls)}")
    return fn


def registered() -> Dict[str, tuple]:
    """name -> tuple of available backends (introspection/tests)."""
    return {k: tuple(sorted(v)) for k, v in _REGISTRY.items()}


def register_lint(name: str, case_fn: Callable) -> None:
    """Register a kernel's Mosaic-lowering lint hook: a zero-arg factory
    returning a ``repro.kernels.lowering.KernelCase`` (factory, so the
    example arrays are only materialized when the lint actually runs).
    Every ``register_kernel`` caller must also register a lint case —
    ``tests/test_lowering_lint.py`` enforces the pairing."""
    _LINT_CASES[name] = case_fn


def lint_cases() -> Dict[str, Callable]:
    """name -> KernelCase factory for every lint-registered kernel."""
    return dict(_LINT_CASES)
