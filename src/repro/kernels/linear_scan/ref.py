"""Pure-jnp oracle for the linear_scan kernel (lax.scan over time)."""

import jax
import jax.numpy as jnp


def linear_scan_ref(r, k, v, w, u=None):
    """r/k/w: [BH, T, Dk]; v: [BH, T, Dv]; u: [BH, Dk] or None."""
    bh, t, dk = r.shape
    dv = v.shape[-1]
    use_bonus = u is not None
    if u is None:
        u = jnp.zeros((bh, dk), r.dtype)

    def one(r, k, v, w, u):
        def step(s, xs):
            rt, kt, vt, wt = xs
            kv = jnp.outer(kt, vt)
            att = s + u[:, None] * kv if use_bonus else s
            ot = rt @ att
            s = wt[:, None] * s + kv
            return s, ot
        s0 = jnp.zeros((dk, dv), jnp.float32)
        _, out = jax.lax.scan(step, s0, (r, k, v, w))
        return out

    return jax.vmap(one)(r, k, v, w, u).astype(r.dtype)
