"""Backend-dispatched public entry points for the linear_scan kernel."""

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.linear_scan.linear_scan import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref


def _xla(r, k, v, w, u=None, *, chunk=None):
    del chunk                       # a Pallas tiling knob; lax.scan instead
    return linear_scan_ref(r, k, v, w, u)


dispatch.register_kernel("linear_scan", pallas=linear_scan, xla=_xla)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _impl(r, k, v, w, u, *, chunk, backend):
    fn = dispatch.lookup("linear_scan", backend)
    return fn(r, k, v, w, u, chunk=chunk)


def linear_scan_op(r, k, v, w, u=None, *, chunk=64, backend=None):
    return _impl(r, k, v, w, u, chunk=chunk,
                 backend=dispatch.resolve(backend))


linear_scan_ref_op = jax.jit(linear_scan_ref)
