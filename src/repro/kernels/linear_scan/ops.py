"""Backend-dispatched public entry points for the linear_scan kernel."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.linear_scan.linear_scan import linear_scan, pallas_specs
from repro.kernels.linear_scan.ref import linear_scan_ref


def _xla(r, k, v, w, u=None, *, chunk=None):
    del chunk                       # a Pallas tiling knob; lax.scan instead
    return linear_scan_ref(r, k, v, w, u)


dispatch.register_kernel("linear_scan", pallas=linear_scan, xla=_xla)


def _lowering_case():
    from repro.kernels import lowering
    bh, t, dk, dv, chunk = 2, 128, 128, 128, 64
    return lowering.KernelCase(
        "linear_scan",
        fn=functools.partial(linear_scan, chunk=chunk),
        args=(jnp.zeros((bh, t, dk), jnp.float32),
              jnp.zeros((bh, t, dk), jnp.float32),
              jnp.zeros((bh, t, dv), jnp.float32),
              jnp.full((bh, t, dk), 0.9, jnp.float32),
              jnp.zeros((bh, dk), jnp.float32)),    # bonus path (rwkv6)
        specs=pallas_specs(bh, t, dk, dv, chunk))


dispatch.register_lint("linear_scan", _lowering_case)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def _impl(r, k, v, w, u, *, chunk, backend):
    fn = dispatch.lookup("linear_scan", backend)
    return fn(r, k, v, w, u, chunk=chunk)


def linear_scan_op(r, k, v, w, u=None, *, chunk=64, backend=None):
    return _impl(r, k, v, w, u, chunk=chunk,
                 backend=dispatch.resolve(backend))


linear_scan_ref_op = jax.jit(linear_scan_ref)
