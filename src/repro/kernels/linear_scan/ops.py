"""Jitted public entry points for the linear_scan kernel."""

import functools

import jax

from repro.kernels.linear_scan.linear_scan import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan_op(r, k, v, w, u=None, *, chunk=64, interpret=True):
    return linear_scan(r, k, v, w, u, chunk=chunk, interpret=interpret)


linear_scan_ref_op = jax.jit(linear_scan_ref)
