"""Pallas TPU kernel: chunked linear-recurrence scan (rwkv6 / SSM decode).

The matrix-state recurrence shared by RWKV6 ("Finch", data-dependent decay)
and Mamba-style SSD heads:

    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t          S: [Dk, Dv]
    o_t = r_t @ (S_{t-1} + diag(u) @ (k_t^T v_t))  (u = bonus; None for SSM)

Grid (BH, T/C): the time axis is innermost and *sequential*; the state S
persists in VMEM scratch across chunk steps (the same cross-grid-step
scratch discipline as flash attention's running softmax).  Within a chunk
the recurrence is an unrolled fori over C steps of rank-1 updates — the
chunk lives entirely in VMEM (C=128, D=64 f32: 32 KB per tensor).

This is the TPU adaptation of the GPU "chunked parallel scan": the
inter-chunk dependency is irreducibly sequential; the intra-chunk work is
what the VPU parallelizes (vectorized over Dk x Dv).  A matmul
(intra-chunk-attention) formulation is a further MXU optimization recorded
in EXPERIMENTS.md §Perf.

Mosaic-ready by construction (ISSUE 5): rank-3 BlockSpecs/out_shape, no
iota at all (time stepping is ``dynamic_slice``), rank-1-free dot_generals
with explicit ``preferred_element_type``, and grid dimension semantics
(BH parallel, the chunk axis ``arbitrary`` — the carried state scratch
makes it sequential).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowering import tpu_compiler_params


def _kernel(chunk, use_bonus, r_ref, k_ref, v_ref, w_ref, u_ref, o_ref,
            state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0]        # [C, Dk]
    k = k_ref[0]        # [C, Dk]
    v = v_ref[0]        # [C, Dv]
    w = w_ref[0]        # [C, Dk] decay in (0, 1)
    u = u_ref[0]        # [1, Dk] bonus (rwkv6) — zeros for plain SSM

    def step(t, carry):
        s, out = carry
        kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)       # [1, Dk]
        vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)       # [1, Dv]
        rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)       # [1, Dk]
        wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)       # [1, Dk]
        # outer product k_t^T v_t on the MXU: contract the length-1 time dim
        kv = jax.lax.dot_general(kt, vt, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Dk, Dv]
        if use_bonus:
            att = s + u.T * kv
        else:
            att = s
        ot = jax.lax.dot_general(rt, att, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [1, Dv]
        s = wt.T * s + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, ot.astype(out.dtype),
                                                  t, 0)
        return s, out

    s0 = state_ref[...]
    out0 = jnp.zeros_like(o_ref[0])
    s, out = jax.lax.fori_loop(0, chunk, step, (s0, out0))
    state_ref[...] = s
    o_ref[0] = out


def pallas_specs(bh: int, t: int, dk: int, dv: int, chunk: int,
                 dtype=jnp.float32):
    """Grid/Block/out structure, shared with the lowering lint."""
    specs = dict(
        grid=(bh, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dk), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dv), dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
    )
    params = tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    if params is not None:
        specs["compiler_params"] = params
    return specs


def linear_scan(r, k, v, w, u=None, *, chunk: int = 64,
                interpret: bool = False):
    """r/k/w: [BH, T, Dk]; v: [BH, T, Dv]; u: [BH, Dk] or None."""
    bh, t, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    use_bonus = u is not None
    if u is None:
        u = jnp.zeros((bh, dk), r.dtype)
    u = u[:, None, :]  # [BH, 1, Dk]

    kern = functools.partial(_kernel, chunk, use_bonus)
    return pl.pallas_call(
        kern,
        **pallas_specs(bh, t, dk, dv, chunk, r.dtype),
        interpret=interpret,
    )(r, k, v, w, u)
