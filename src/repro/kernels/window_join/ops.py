"""Backend-dispatched public entry points for the window_join kernel."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.window_join.ref import window_join_ref
from repro.kernels.window_join.window_join import pallas_specs, window_join


def _pallas(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
            ws, band, n_attrs, tile_k, interpret):
    counts, comps = window_join(
        new_tau, new_src, new_pay, st_tau, st_src, st_pay,
        ws=ws, band=band, n_attrs=n_attrs, tile_k=tile_k, interpret=interpret)
    return counts, comps.sum()


def _xla(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
         ws, band, n_attrs, tile_k=None):
    del tile_k
    return window_join_ref(new_tau, new_src, new_pay, st_tau, st_src, st_pay,
                           ws=ws, band=band, n_attrs=n_attrs)


dispatch.register_kernel("window_join", pallas=_pallas, xla=_xla)


def _lowering_case():
    from repro.kernels import lowering
    b, p, k, r, tile_k = 128, 2, 256, 16, 128
    return lowering.KernelCase(
        "window_join",
        fn=functools.partial(window_join, ws=500, band=10.0, n_attrs=2,
                             tile_k=tile_k),
        args=(jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32),
              jnp.zeros((b, p), jnp.float32),
              jnp.full((k, r), -1, jnp.int32), jnp.zeros((k, r), jnp.int32),
              jnp.zeros((k, r, p), jnp.float32)),
        specs=pallas_specs(b, p, k, r, tile_k))


dispatch.register_lint("window_join", _lowering_case)


@functools.partial(jax.jit, static_argnames=("ws", "band", "n_attrs",
                                             "tile_k", "backend"))
def _impl(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
          ws, band, n_attrs, tile_k, backend):
    fn = dispatch.lookup("window_join", backend)
    return fn(new_tau, new_src, new_pay, st_tau, st_src, st_pay,
              ws=ws, band=band, n_attrs=n_attrs, tile_k=tile_k)


def window_join_op(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                   ws, band=10.0, n_attrs=2, tile_k=128, backend=None):
    """-> (counts i32[B, K], comparisons i32[])."""
    return _impl(new_tau, new_src, new_pay, st_tau, st_src, st_pay,
                 ws=ws, band=band, n_attrs=n_attrs, tile_k=tile_k,
                 backend=dispatch.resolve(backend))


@functools.partial(jax.jit, static_argnames=("ws", "band", "n_attrs"))
def window_join_ref_op(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                       ws, band=10.0, n_attrs=2):
    return window_join_ref(new_tau, new_src, new_pay, st_tau, st_src, st_pay,
                           ws=ws, band=band, n_attrs=n_attrs)
