"""Jitted public entry points for the window_join kernel."""

import functools

import jax

from repro.kernels.window_join.ref import window_join_ref
from repro.kernels.window_join.window_join import window_join


@functools.partial(jax.jit, static_argnames=("ws", "band", "n_attrs",
                                             "tile_k", "interpret"))
def window_join_op(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                   ws, band=10.0, n_attrs=2, tile_k=128, interpret=True):
    counts, comps = window_join(
        new_tau, new_src, new_pay, st_tau, st_src, st_pay,
        ws=ws, band=band, n_attrs=n_attrs, tile_k=tile_k,
        interpret=interpret)
    return counts, comps.sum()


@functools.partial(jax.jit, static_argnames=("ws", "band", "n_attrs"))
def window_join_ref_op(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                       ws, band=10.0, n_attrs=2):
    return window_join_ref(new_tau, new_src, new_pay, st_tau, st_src, st_pay,
                           ws=ws, band=band, n_attrs=n_attrs)
