"""Pure-jnp oracle for the window_join kernel."""

import jax.numpy as jnp


def window_join_ref(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                    ws: int, band: float = 10.0, n_attrs: int = 2):
    fresh = st_tau[None] + ws >= new_tau[:, None, None]
    live = (st_tau[None] >= 0) & fresh
    opp = live & (st_src[None] != new_src[:, None, None])
    d = jnp.abs(new_pay[:, None, None, :n_attrs] - st_pay[None, :, :, :n_attrs])
    hit = opp & jnp.all(d <= band, axis=-1)
    counts = jnp.sum(hit.astype(jnp.int32), axis=-1)
    comps = jnp.sum(opp.astype(jnp.int32))
    return counts, comps
