"""Pallas TPU kernel: ScaleJoin blocked window band-join (paper Q3-Q6 hot loop).

Intra-chip VSN, literally (DESIGN.md §2): the incoming tuple block lives
once in HBM and is read by *every* grid program — the shared Tuple Buffer.
Each program owns a tile of virtual-key rows of the stored-tuple ring (its
``f_mu`` share, via the BlockSpec index map) and compares the whole incoming
block against its tile: no tuple duplication, disjoint state, deterministic.

Mosaic-ready layout (ISSUE 5): the per-tuple metadata enters as rank-2
``(B, 1)`` columns (no rank-1 BlockSpecs), B is padded to the f32 sublane
quantum with tau = INF_TIME lanes (past every freshness horizon: they match
nothing and count no comparisons, the ``band_join_counts`` neutral
element), and the kernel body is pure rank->=3 broadcasting — no iota at
all.

Shapes
  new_tau  i32[B]            incoming event times (timestamp-sorted tick)
  new_src  i32[B]            stream ids (0 = L, 1 = R)
  new_pay  f32[B, P]         payloads
  st_tau   i32[K, R]         stored ring event times (-1 = empty)
  st_src   i32[K, R]
  st_pay   f32[K, R, P]
outputs
  counts   i32[B, K]         matches of incoming b against key row k
  comps    i32[K_tiles, 1]   live comparisons per tile (roofline accounting)

Band predicate (the [13]/[21] benchmark): matches iff
``|newL.phi[a] - newR.phi[a]| <= band`` for a < n_attrs, with stream and
``tau_new - tau_stored <= WS`` freshness (purge-on-read).

Tiling: grid over K tiles; per step the program holds (B,P) + (TK,R,P) in
VMEM.  With B=256, TK=128, R=64, P=2 (f32): 2 KB + 64 KB blocks — far under
the ~16 MB VMEM budget, MXU-aligned lane dims via padding to 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.watermark import INF_TIME

SUBLANES = 8                    # f32 sublane quantum for the incoming block


def _kernel(ws, band, n_attrs,
            new_tau_ref, new_src_ref, new_pay_ref,
            st_tau_ref, st_src_ref, st_pay_ref,
            counts_ref, comps_ref):
    new_tau = new_tau_ref[...]            # [B, 1]
    new_src = new_src_ref[...]            # [B, 1]
    new_pay = new_pay_ref[...]            # [B, P]
    st_tau = st_tau_ref[...]              # [TK, R]
    st_src = st_src_ref[...]              # [TK, R]
    st_pay = st_pay_ref[...]              # [TK, R, P]

    # freshness + stream predicates: [B, TK, R]
    fresh = st_tau[None] + ws >= new_tau[:, :, None]
    live = (st_tau[None] >= 0) & fresh
    opp = live & (st_src[None] != new_src[:, :, None])

    # band predicate on the first n_attrs payload attributes
    ok = jnp.ones_like(opp)
    for a in range(n_attrs):
        d = new_pay[:, None, None, a] - st_pay[None, :, :, a]
        ok = ok & (jnp.abs(d) <= band)

    hit = opp & ok
    counts_ref[...] = jnp.sum(hit.astype(jnp.int32), axis=-1)
    comps_ref[0, 0] = jnp.sum(opp.astype(jnp.int32))


def pallas_specs(b: int, p: int, k: int, r: int, tile_k: int):
    """Grid/Block/out structure, shared with the lowering lint.  The
    incoming block is broadcast to every program; the stored-ring tiles
    walk the key axis.  All specs rank >= 2."""
    grid = (k // tile_k,)
    return dict(
        grid=grid,
        in_specs=[
            # the shared tuple block: every program maps the same HBM block
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((b, 1), lambda i: (0, 0)),
            pl.BlockSpec((b, p), lambda i: (0, 0)),
            # the program's key-row tile (its f_mu share)
            pl.BlockSpec((tile_k, r), lambda i: (i, 0)),
            pl.BlockSpec((tile_k, r), lambda i: (i, 0)),
            pl.BlockSpec((tile_k, r, p), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, tile_k), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        ],
    )


def window_join(new_tau, new_src, new_pay, st_tau, st_src, st_pay, *,
                ws: int, band: float = 10.0, n_attrs: int = 2,
                tile_k: int = 128, interpret: bool = False):
    b, p = new_pay.shape
    k, r = st_tau.shape
    tile_k = min(tile_k, k)
    assert k % tile_k == 0

    # sublane-align the incoming block: tau = INF_TIME padding lanes fail
    # every freshness test, so counts rows past b are sliced off and comps
    # is untouched.
    b_pad = -(-b // SUBLANES) * SUBLANES
    if b_pad != b:
        new_tau = jnp.pad(new_tau, (0, b_pad - b), constant_values=INF_TIME)
        new_src = jnp.pad(new_src, (0, b_pad - b))
        new_pay = jnp.pad(new_pay, ((0, b_pad - b), (0, 0)))

    kern = functools.partial(_kernel, ws, band, n_attrs)
    counts, comps = pl.pallas_call(
        kern,
        **pallas_specs(b_pad, p, k, r, tile_k),
        interpret=interpret,
    )(new_tau.reshape(b_pad, 1), new_src.reshape(b_pad, 1), new_pay,
      st_tau, st_src, st_pay)
    return counts[:b], comps
