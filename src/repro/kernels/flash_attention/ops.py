"""Jitted public entry points for the flash_attention kernel (incl. GQA)."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _gqa_expand(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=0)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "blk_q", "blk_k", "interpret", "n_rep"))
def flash_attention_op(q, k, v, *, causal=True, window=None, n_rep=1,
                       blk_q=128, blk_k=128, interpret=True):
    """q: [BH_q, Sq, D]; k, v: [BH_kv, Skv, D] with BH_q = BH_kv * n_rep."""
    k = _gqa_expand(k, n_rep)
    v = _gqa_expand(v, n_rep)
    return flash_attention(q, k, v, causal=causal, window=window,
                           blk_q=blk_q, blk_k=blk_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "n_rep"))
def attention_ref_op(q, k, v, *, causal=True, window=None, n_rep=1):
    k = _gqa_expand(k, n_rep)
    v = _gqa_expand(v, n_rep)
    return attention_ref(q, k, v, causal=causal, window=window)
