"""Backend-dispatched public entry points for flash_attention (incl. GQA)."""

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention.flash_attention import (flash_attention,
                                                           pallas_specs)
from repro.kernels.flash_attention.ref import attention_ref


def _gqa_expand(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=0)


def _xla(q, k, v, *, causal, window, blk_q=None, blk_k=None):
    del blk_q, blk_k                # Pallas tiling knobs
    return attention_ref(q, k, v, causal=causal, window=window)


dispatch.register_kernel("flash_attention", pallas=flash_attention, xla=_xla)


def _lowering_case():
    from repro.kernels import lowering
    bh, sq, skv, d, blk = 2, 128, 128, 128, 128
    return lowering.KernelCase(
        "flash_attention",
        fn=functools.partial(flash_attention, causal=True, window=32,
                             blk_q=blk, blk_k=blk),
        args=(jnp.zeros((bh, sq, d), jnp.float32),
              jnp.zeros((bh, skv, d), jnp.float32),
              jnp.zeros((bh, skv, d), jnp.float32)),
        specs=pallas_specs(bh, sq, skv, d, blk, blk))


dispatch.register_lint("flash_attention", _lowering_case)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "blk_q", "blk_k", "n_rep", "backend"))
def _impl(q, k, v, *, causal, window, n_rep, blk_q, blk_k, backend):
    k = _gqa_expand(k, n_rep)
    v = _gqa_expand(v, n_rep)
    fn = dispatch.lookup("flash_attention", backend)
    return fn(q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k)


def flash_attention_op(q, k, v, *, causal=True, window=None, n_rep=1,
                       blk_q=128, blk_k=128, backend=None):
    """q: [BH_q, Sq, D]; k, v: [BH_kv, Skv, D] with BH_q = BH_kv * n_rep."""
    return _impl(q, k, v, causal=causal, window=window, n_rep=n_rep,
                 blk_q=blk_q, blk_k=blk_k, backend=dispatch.resolve(backend))


@functools.partial(jax.jit, static_argnames=("causal", "window", "n_rep"))
def attention_ref_op(q, k, v, *, causal=True, window=None, n_rep=1):
    k = _gqa_expand(k, n_rep)
    v = _gqa_expand(v, n_rep)
    return attention_ref(q, k, v, causal=causal, window=window)
