"""Pallas TPU kernel: blocked online-softmax attention (LM hot loop).

Flash-style forward: grid (batch*q_heads, Sq/BQ, Skv/BK) with the KV axis
innermost; running (max, denom, acc) live in VMEM scratch across KV steps.
Supports causal masking, sliding-window (gemma3 local layers: the window is
a WA=1/WS=window STRETCH sliding window over sequence "time"), and decode
(Sq=1 against a long KV cache).  GQA is handled by the ops wrapper (KV head
indexed q_head // group).

Tiling: per step VMEM holds (BQ,D) q + (BK,D) k,v + (BQ,BK) logits +
(BQ,D) acc — e.g. BQ=BK=512, D=128 f32: ~1.8 MB, well under VMEM; matmul
dims are 128-aligned for the MXU.

Mosaic-ready by construction (ISSUE 5): every BlockSpec/out_shape is
rank-3, the position masks use 2-D ``broadcasted_iota`` only, and the grid
carries explicit dimension semantics — (batch*head, q) parallel, kv
``arbitrary`` (the running-softmax scratch makes it sequential).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lowering import tpu_compiler_params

NEG_INF = -1e30


def _kernel(scale, causal, window, blk_q, blk_k, seq_kv,
            q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # [BQ, D]
    k = k_ref[0]                      # [BK, D]
    v = v_ref[0]                      # [BK, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    # decode offsets: q positions sit at the end of the KV timeline
    q_pos = q_pos + (seq_kv - pl.num_programs(1) * blk_q)
    mask = jnp.ones((blk_q, blk_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def pallas_specs(bh: int, sq: int, skv: int, d: int, blk_q: int, blk_k: int,
                 dtype=jnp.float32):
    """Grid/Block/out structure, shared with the lowering lint."""
    specs = dict(
        grid=(bh, sq // blk_q, skv // blk_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # running max
            pltpu.VMEM((blk_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((blk_q, d), jnp.float32),   # running accumulator
        ],
    )
    params = tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    if params is not None:
        specs["compiler_params"] = params
    return specs


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False):
    """q: [BH, Sq, D]; k, v: [BH, Skv, D] (KV already GQA-expanded)."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    assert sq % blk_q == 0 and skv % blk_k == 0
    scale = d ** -0.5

    kern = functools.partial(_kernel, scale, causal, window, blk_q, blk_k, skv)
    return pl.pallas_call(
        kern,
        **pallas_specs(bh, sq, skv, d, blk_q, blk_k, q.dtype),
        interpret=interpret,
    )(q, k, v)
