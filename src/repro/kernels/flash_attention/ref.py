"""Pure-jnp oracle for the flash_attention kernel."""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: [BH, Sq, D]; k, v: [BH, Skv, D].  Decode convention: the Sq query
    positions sit at the *end* of the KV timeline."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
