"""Source partitioner: the sources→leaves map of the hierarchical ScaleGate.

Each ingest leaf owns a *disjoint* subset of the physical sources (the
shared-nothing property of the tier: no source is merged by two leaves, so
no coordination below the root).  The partitioner is pure host-side
bookkeeping:

* ``assignment[src] -> leaf_id`` — the current map;
* ``rebalance(add=…, remove=…)`` — recompute membership with **minimal
  movement**: only as many sources move as the balance targets require, and
  a removed leaf's sources are spread over the survivors.  Every move is
  returned as ``src -> (old_leaf, new_leaf)`` so the tier can drive the ESG
  ``removeSources``/``addSources`` handshake (old leaf flushes, new leaf
  starts the source at its Lemma-3 safe bound) — membership changes move
  *metadata only*, never stashed tuples.

Determinism: iteration over sources and leaves is by ascending id, so the
same command sequence always yields the same assignment (tests and the
single-gate parity oracle rely on this).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


class SourcePartitioner:
    def __init__(self, n_sources: int, leaf_ids: Iterable[int]):
        leaf_ids = sorted(leaf_ids)
        assert leaf_ids, "at least one leaf"
        self.n_sources = n_sources
        self._leaves: List[int] = list(leaf_ids)
        # initial contiguous balanced split over the leaves, ascending
        self.assignment = np.empty((n_sources,), np.int64)
        for i, src_ids in enumerate(np.array_split(np.arange(n_sources),
                                                   len(leaf_ids))):
            self.assignment[src_ids] = leaf_ids[i]

    # -- views ---------------------------------------------------------------
    @property
    def leaves(self) -> Tuple[int, ...]:
        return tuple(self._leaves)

    def leaf_of(self, src: int) -> int:
        return int(self.assignment[src])

    def sources_of(self, leaf: int) -> np.ndarray:
        return np.nonzero(self.assignment == leaf)[0]

    def owned_mask(self, leaf: int) -> np.ndarray:
        return self.assignment == leaf

    def counts(self) -> Dict[int, int]:
        return {l: int((self.assignment == l).sum()) for l in self._leaves}

    # -- rebalance -----------------------------------------------------------
    def rebalance(self, add: Optional[Iterable[int]] = None,
                  remove: Optional[Iterable[int]] = None
                  ) -> Dict[int, Tuple[int, int]]:
        """Apply a membership change; returns ``{src: (old, new)}`` moves.

        Balance target: every surviving leaf ends within one source of
        ``n_sources / n_leaves``.  Moves are chosen deterministically
        (largest donors first, sources by ascending id) and minimally (a
        source moves only if its leaf is above target and another is
        below).
        """
        add = sorted(set(add or ()))
        remove = sorted(set(remove or ()))
        for a in add:
            assert a not in self._leaves, f"leaf {a} already active"
        for r in remove:
            assert r in self._leaves, f"leaf {r} not active"
        new_leaves = sorted((set(self._leaves) | set(add)) - set(remove))
        assert new_leaves, "cannot remove the last leaf"

        moves: Dict[int, Tuple[int, int]] = {}
        counts = {l: 0 for l in new_leaves}
        for src in range(self.n_sources):
            l = int(self.assignment[src])
            if l in counts:
                counts[l] += 1

        # 1. orphaned sources (their leaf is leaving) must move;
        # 2. then shave overfull leaves down to the ceil target.
        base, extra = divmod(self.n_sources, len(new_leaves))
        target = {l: base + (1 if i < extra else 0)
                  for i, l in enumerate(new_leaves)}

        def receiver() -> int:
            # most-underfull surviving leaf; ties to the smallest id
            return min(new_leaves, key=lambda l: (counts[l] - target[l], l))

        for src in range(self.n_sources):
            old = int(self.assignment[src])
            if old not in counts:                      # orphaned
                new = receiver()
                moves[src] = (old, new)
                self.assignment[src] = new
                counts[new] += 1
        donors = sorted(new_leaves, key=lambda l: -(counts[l] - target[l]))
        for d in donors:
            while counts[d] > target[d]:
                new = receiver()
                if counts[new] - target[new] >= 0:
                    break                              # already balanced
                src = int(self.sources_of(d)[0])       # smallest id moves
                moves[src] = (d, new)
                self.assignment[src] = new
                counts[d] -= 1
                counts[new] += 1
        self._leaves = new_leaves
        return moves
