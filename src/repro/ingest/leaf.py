"""Leaf ScaleGate: one ingest worker's merge over its owned sources.

A leaf is the paper's per-host ScaleGate (§6 hierarchical TB): it merges
the timestamp-sorted streams of its *disjoint* source subset into a ready
stream that is itself timestamp-sorted — so the leaf outputs compose as
sources of the root merge one level up.  The leaf is a thin, host-driven
wrapper around the same ``scalegate.push`` the pipelines use:

* per round it pushes its routed slice (chunked to a fixed lane width so
  jit shapes stay static) and emits a ``LeafOut`` — the *compacted* ready
  tuples plus the leaf's reported watermark ``W_leaf`` and its cumulative
  stash-overflow count (surfaced every round, never silent);
* ESG membership ops ride the same round stream: ``add_source`` starts a
  gained source at its Lemma-3 safe bound gamma, ``remove_source`` flushes
  (the frontier stops gating; stashed tuples drain as W rises), ``flush``
  removes every owned source so the final push empties the stash.

``LeafOut`` payloads are plain numpy (the tier's channels may cross process
boundaries); the worker loops for thread and process mode live here too so
a spawn-context child can import them top-level.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import scalegate
from repro.core import tuples as T

FIELDS = ("tau", "keys", "payload", "source", "valid", "is_control",
          "ctrl_epoch")


def batch_to_np(b: T.TupleBatch) -> Dict[str, np.ndarray]:
    return {f: np.asarray(getattr(b, f)) for f in FIELDS}


def np_to_batch(d: Dict[str, np.ndarray]) -> T.TupleBatch:
    import jax.numpy as jnp
    return T.TupleBatch(**{f: jnp.asarray(d[f]) for f in FIELDS})


def compact_np(d: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Keep only the valid lanes (host-side; output of a gate push)."""
    keep = d["valid"]
    return {f: d[f][keep] for f in FIELDS}


def empty_np(kmax: int, payload_width: int) -> Dict[str, np.ndarray]:
    return {
        "tau": np.zeros((0,), np.int32),
        "keys": np.zeros((0, kmax), np.int32),
        "payload": np.zeros((0, payload_width), np.float32),
        "source": np.zeros((0,), np.int32),
        "valid": np.zeros((0,), bool),
        "is_control": np.zeros((0,), bool),
        "ctrl_epoch": np.zeros((0,), np.int32),
    }


def concat_np(parts: Sequence[Dict[str, np.ndarray]],
              kmax: int, payload_width: int) -> Dict[str, np.ndarray]:
    parts = [p for p in parts if p["tau"].shape[0]]
    if not parts:
        return empty_np(kmax, payload_width)
    return {f: np.concatenate([p[f] for p in parts]) for f in FIELDS}


def pad_np(d: Dict[str, np.ndarray], n: int) -> Dict[str, np.ndarray]:
    """Pad to exactly ``n`` lanes with invalid filler (static jit shapes)."""
    have = d["tau"].shape[0]
    assert have <= n, (have, n)
    if have == n:
        return d
    pad = n - have
    out = {}
    for f in FIELDS:
        a = d[f]
        shape = (pad,) + a.shape[1:]
        out[f] = np.concatenate([a, np.zeros(shape, a.dtype)])
    return out


@functools.lru_cache(maxsize=None)
def _jit_push(backend: Optional[str]):
    """One jitted ``scalegate.push`` per backend, shared by every gate (the
    jit cache then dedups compilations across leaves by shape)."""
    import jax
    return jax.jit(functools.partial(scalegate.push, backend=backend))


@dataclasses.dataclass
class LeafOut:
    """One leaf's contribution to one root round (picklable: numpy only)."""
    leaf_id: int
    round_id: int
    ready: Dict[str, np.ndarray]   # compacted ready tuples, tau-sorted
    wmark: int                     # reported leaf watermark W_leaf
    overflow: int                  # cumulative leaf stash-overflow count
    final: bool = False            # last message (leaf flushed and left)
    # cross-process observability payload (drained child spans/counters/
    # events piggybacking on the round stream); None in thread mode, where
    # the leaf shares the parent's registry directly
    obs: Optional[Dict] = None

    @property
    def n_ready(self) -> int:
        return int(self.ready["tau"].shape[0])


@dataclasses.dataclass
class LeafSnap:
    """One leaf's answer to a snapshot round: its full exported gate state
    (picklable numpy only — crosses process channels like any LeafOut).
    Riding the same round stream as tick messages is what pins the snapshot
    to an exact tick boundary: the state is captured after the leaf pushed
    round ``round_id - 1`` and before it sees the next tick."""
    leaf_id: int
    round_id: int
    state: Dict


class LeafGate:
    """The pure leaf state machine; drivable inline, from a thread, or from
    a child process (see the worker loops below)."""

    def __init__(self, leaf_id: int, n_sources: int, owned: np.ndarray,
                 cap: int, kmax: int, payload_width: int,
                 backend: Optional[str] = None, chunk: Optional[int] = None,
                 state: Optional[Dict] = None):
        import jax.numpy as jnp
        self.leaf_id = leaf_id
        self.n_sources = n_sources
        self.kmax = kmax
        self.payload_width = payload_width
        self.backend = backend
        # chunk width: combined merge size is cap + chunk; keeping it a
        # power of two lets merge_order take the bitonic-kernel path
        self.chunk = chunk or cap
        if state is not None:
            # restore: stash / frontier / active mask all come from the
            # snapshot (the owned mask is part of the exported state)
            self.state = scalegate.import_np(state)
        else:
            self.state = scalegate.init_scalegate(
                n_sources, cap, kmax, payload_width,
                active=jnp.asarray(owned, bool))
        self._push = _jit_push(backend)

    def export_state(self) -> Dict:
        """Picklable numpy snapshot of the gate (stash + frontier +
        overflow); ``LeafGate(..., state=...)`` restores it exactly."""
        return scalegate.export_np(self.state)

    # -- per-round work ------------------------------------------------------
    def push_round(self, round_id: int, slice_np: Optional[Dict] = None,
                   final: bool = False) -> LeafOut:
        """Push this round's routed tuples (possibly none) and report."""
        parts: List[Dict[str, np.ndarray]] = []
        lanes = 0 if slice_np is None else slice_np["tau"].shape[0]
        off = 0
        while True:
            n = min(self.chunk, lanes - off)
            if slice_np is None or n <= 0:
                chunk = pad_np(empty_np(self.kmax, self.payload_width),
                               self.chunk)
            else:
                chunk = pad_np({f: slice_np[f][off:off + n] for f in FIELDS},
                               self.chunk)
            self.state, out = self._push(self.state, np_to_batch(chunk))
            parts.append(compact_np(batch_to_np(out)))
            off += self.chunk
            if off >= lanes:
                break
        ready = concat_np(parts, self.kmax, self.payload_width)
        return LeafOut(self.leaf_id, round_id, ready,
                       wmark=int(self.state.wmark.value()),
                       overflow=int(self.state.overflow), final=final)

    # -- ESG membership ------------------------------------------------------
    def _mask(self, src: int):
        import jax.numpy as jnp
        m = np.zeros((self.n_sources,), bool)
        m[src] = True
        return jnp.asarray(m)

    def add_source(self, src: int, gamma: int) -> None:
        self.state = scalegate.add_sources(self.state, self._mask(src), gamma)

    def remove_source(self, src: int) -> None:
        self.state = scalegate.remove_sources(self.state, self._mask(src))

    def flush_all(self) -> None:
        import jax.numpy as jnp
        self.state = scalegate.remove_sources(
            self.state, jnp.ones((self.n_sources,), bool))

    def apply(self, ops: Sequence[Tuple]) -> bool:
        """Apply a reconfiguration op list; returns True when this leaf is
        leaving (its subsequent push is its flush + final message)."""
        leaving = False
        for op in ops:
            if op[0] == "add_source":
                self.add_source(op[1], op[2])
            elif op[0] == "remove_source":
                self.remove_source(op[1])
            elif op[0] == "flush":
                self.flush_all()
                leaving = True
            else:                                     # pragma: no cover
                raise ValueError(f"unknown leaf op {op!r}")
        return leaving


def run_gate_loop(gate: LeafGate, recv, send, ship_obs: bool = False) -> None:
    """The worker protocol: drive ``gate`` from ``recv()`` messages until a
    stop/flush; shared verbatim by thread and process workers.

    Messages: ``("tick", round, slice_np)`` | ``("cmd", round, ops)`` |
    ``("snap", round)`` | ``("stop",)``.  Every tick/cmd/snap message
    produces exactly one answer (``LeafOut`` / ``LeafSnap``) via ``send`` —
    the root's round barrier counts on it.

    ``ship_obs=True`` (process workers only) attaches the child's drained
    observability payload to each outgoing ``LeafOut``; thread workers
    share the parent's registry and must NOT ship (double-counting).
    """
    from repro import obs as _obs
    from repro.io.queues import QueueClosed

    def answer(out: LeafOut) -> None:
        _obs.counter_inc("leaf.rounds")
        _obs.counter_inc("leaf.tuples_ready", out.n_ready)
        _obs.event("leaf_push", leaf_id=out.leaf_id, round_id=out.round_id,
                   n_ready=out.n_ready, wmark=out.wmark,
                   overflow=out.overflow, final=out.final)
        if ship_obs:
            out.obs = _obs.drain_payload()
        send(out)

    while True:
        try:
            msg = recv()
        except QueueClosed:
            break
        kind = msg[0]
        if kind == "stop":
            break
        if kind == "tick":
            tl = _obs.exemplars()
            if tl is not None and msg[2] is not None:
                s = msg[2]
                tl.scan(s["source"], s["tau"],
                        s["valid"] & ~s["is_control"], "leaf_push")
            with _obs.span("leaf.push"):
                out = gate.push_round(msg[1], msg[2])
            answer(out)
        elif kind == "cmd":
            leaving = gate.apply(msg[2])
            with _obs.span("leaf.push"):
                out = gate.push_round(msg[1], None, final=leaving)
            answer(out)
            if leaving:
                break
        elif kind == "snap":
            send(LeafSnap(gate.leaf_id, msg[1], gate.export_state()))
        else:                                         # pragma: no cover
            raise ValueError(f"unknown message {msg!r}")


def process_worker_main(cfg: Dict, in_q, out_q) -> None:
    """Child-process entry point (spawn context: top-level importable).

    ``cfg`` carries the LeafGate constructor args as picklable values; jax
    initializes fresh in the child (CPU), and all channel payloads are
    numpy.  Mirrors ``run_gate_loop`` over the mp queues.
    """
    from repro import obs as _obs
    from repro.ingest.channels import MP_CLOSE
    from repro.io.queues import QueueClosed

    ship_obs = False
    if cfg.get("obs"):
        # the child gets its own Obs (same config as the parent's) and
        # ships drained payloads back on the round stream
        _obs.install(_obs.ObsConfig.from_dict(cfg["obs"]))
        ship_obs = True

    gate = LeafGate(cfg["leaf_id"], cfg["n_sources"],
                    np.asarray(cfg["owned"], bool), cfg["cap"], cfg["kmax"],
                    cfg["payload_width"], backend=cfg.get("backend"),
                    chunk=cfg.get("chunk"), state=cfg.get("state"))

    def recv():
        msg = in_q.get()
        if msg == MP_CLOSE:
            raise QueueClosed
        return msg

    run_gate_loop(gate, recv, out_q.put, ship_obs=ship_obs)
