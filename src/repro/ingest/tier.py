"""IngestTier: the hierarchical multi-host ScaleGate, end to end.

Topology (paper §6's elastic/hierarchical TB)::

    source stream ──router──> leaf 0 (ScaleGate over its sources) ─┐
                  ├─────────> leaf 1                              ─┤──> root
                  └─────────> leaf N-1                            ─┘   merge
                                                                        │
                                              totally-ordered ready ────┘
                                              stream (one tick/round)

* the **router** splits each source tick over the leaves by the
  ``SourcePartitioner`` assignment and folds the host-side per-source
  frontier (the Lemma-3 gamma oracle for rebalances);
* each **leaf worker** (``worker="thread" | "process" | "inline"``) owns
  one ``LeafGate`` and answers every round with a ``LeafOut`` — ready
  tuples + reported watermark + overflow count (the round barrier that
  makes the tier deterministic);
* the **root merge** runs in the consumer's thread (for
  ``AsyncStreamRuntime`` that is its ingest thread: the tier is a drop-in
  source upstream of ``pipeline.stage()``) and yields one totally-ordered
  ready batch per round.

Backpressure propagates root→leaf→source through the bounded channels
alone: a slow consumer stops collecting rounds, the leaf→root channel
fills, leaves block, the router's leaf channels fill, and the source
iterator stalls — memory never grows with the lag.

Elasticity: ``add_host``/``remove_host`` reuse the ESG semantics at both
levels with **zero state transfer** — moved sources restart at their
Lemma-3 safe bound gamma on the gaining leaf while their stashed tuples
drain from the losing leaf's flush; the root clamps the gaining leaf's
frontier to gamma (`wm.clamp_frontier`) so total order survives the move.
Attach/detach latency (command issued → membership round merged at the
root) is measured per command.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs as _obs
from repro.core import scalegate
from repro.core import tuples as T
from repro.ingest import leaf as L
from repro.ingest.channels import make_channel
from repro.ingest.partitioner import SourcePartitioner
from repro.ingest.root import MIN_PAD, RootMerge, bucket
from repro.io.queues import TIMEOUT, BoundedQueue, QueueClosed

ROUND_TIMEOUT_S = 120.0       # hang guard: a missing leaf answer is a bug


class LeafFailure(RuntimeError):
    """A leaf worker process died before answering its round (unplanned
    host loss — SIGKILL, OOM, crash).  Raised by the consumer promptly (the
    liveness check runs every collect poll, not after ``ROUND_TIMEOUT_S``);
    ``t_detected`` stamps the detection instant so recovery drills can
    report detection→recovered latency."""

    def __init__(self, msg: str):
        super().__init__(msg)
        self.t_detected = time.perf_counter()


@dataclasses.dataclass
class _Command:
    kind: str                 # "add" | "remove"
    leaf_id: int
    at_tick: Optional[int]
    t_issued: float           # re-stamped when the command is *released*
    #                           (an at_tick-deferred command must report
    #                           the membership handshake, not queue wait)


@dataclasses.dataclass
class _RoundRec:
    round_id: int
    kind: str                 # "tick" | "reconfig" | "final" | "snap"
    leaves: Tuple[int, ...]   # who must answer this round
    root_ops: Tuple = ()
    cmd: Optional[_Command] = None
    # snap rounds only: the router-side cut captured at build time (the
    # router runs ahead of the consumer, so consumer-side reads would race)
    snap_tick: Optional[int] = None       # source ticks routed before cut
    snap_frontier: Optional[np.ndarray] = None
    snap_tuples_in: int = 0
    snap_next_leaf_id: int = 0


@dataclasses.dataclass
class IngestStats:
    leaves: Tuple[int, ...]
    rounds: int
    ticks: int
    tuples_in: int
    tuples_out: int
    watermark: int
    root_overflow: int
    leaf_overflow: Dict[int, int]
    attach_ms: List[float]
    detach_ms: List[float]

    @property
    def total_overflow(self) -> int:
        return self.root_overflow + sum(self.leaf_overflow.values())

    def summary(self) -> str:
        att = (f"{np.mean(self.attach_ms):.1f}ms" if self.attach_ms
               else "n/a")
        det = (f"{np.mean(self.detach_ms):.1f}ms" if self.detach_ms
               else "n/a")
        return (f"{len(self.leaves)} leaves, {self.rounds} rounds "
                f"({self.ticks} ticks): {self.tuples_in} tuples in, "
                f"{self.tuples_out} out, W={self.watermark}, attach {att}, "
                f"detach {det}, overflow root={self.root_overflow} "
                f"leaves={sum(self.leaf_overflow.values())}")


class _Handle:
    """One leaf worker, any transport."""

    def __init__(self, leaf_id: int):
        self.leaf_id = leaf_id
        self.gate: Optional[L.LeafGate] = None    # inline only
        self.chan = None                          # thread/process only
        self.thread: Optional[threading.Thread] = None
        self.proc = None


class IngestTier:
    """Iterable of root-ready ``TupleBatch`` ticks over ``stream``.

    ``stream`` yields source ticks (``TupleBatch``; per-source
    timestamp-sorted, source ids in ``[0, n_sources)``).  One-shot: iterate
    it once.
    """

    def __init__(self, stream, n_sources: int, n_leaves: int, *,
                 worker: str = "thread", leaf_cap: int = 128,
                 root_cap: int = 256, chan_cap: int = 4,
                 max_leaves: Optional[int] = None,
                 backend: Optional[str] = None, record: bool = False,
                 schedule=None, out_pad: int = MIN_PAD,
                 root_device: bool = False, root_check_every: int = 8,
                 snapshot_every: int = 0, restore: Optional[Dict] = None):
        assert worker in ("thread", "process", "inline"), worker
        assert n_leaves >= 1
        self.stream = stream
        self.n_sources = n_sources
        self.worker = worker
        self.leaf_cap = leaf_cap
        self.root_cap = root_cap
        self.chan_cap = chan_cap
        self.backend = backend
        self.max_leaves = max_leaves or max(2 * n_leaves, n_leaves + 4)
        assert n_leaves <= self.max_leaves
        self.schedule = schedule
        self.out_pad = out_pad
        self.root_device = root_device
        self.root_check_every = root_check_every
        # snapshot_every=K inserts a barrier "snap" round after every K-th
        # routed source tick: every leaf answers with its exported state at
        # that exact boundary, so the assembled snapshot is consistent
        # across the whole tier by construction (no leaf has seen tick K
        # when it answers, every leaf has pushed tick K-1)
        self.snapshot_every = snapshot_every
        self._snapshots: Dict[int, Dict] = {}   # emitted_rounds -> payload
        self._restore = restore
        if restore is not None:
            self.part = SourcePartitioner(n_sources, restore["leaves"])
            self.part.assignment[:] = np.asarray(restore["assignment"],
                                                 np.int64)
            self.frontier = np.asarray(restore["frontier"],
                                       np.int64).copy()
            self._next_leaf_id = int(restore["next_leaf_id"])
            self._tick_index = int(restore["source_ticks"])
            self._rounds_emitted = int(restore["emitted_rounds"])
            self.tuples_in = int(restore.get("tuples_in", 0))
        else:
            self.part = SourcePartitioner(n_sources, range(n_leaves))
            self.frontier = np.zeros((n_sources,), np.int64)
            self._next_leaf_id = n_leaves
            self._tick_index = 0
            self._rounds_emitted = 0
            self.tuples_in = 0
        self._last_snap_tick = self._tick_index
        self.emitted: Optional[List[T.TupleBatch]] = [] if record else None

        self._handles: Dict[int, _Handle] = {}
        self._cmds: List[_Command] = []
        self._cmd_lock = threading.Lock()
        self._round = 0
        self._stream_done = False
        self._flushed = False
        self._started = False
        self._stop = False
        self._router_error: Optional[BaseException] = None
        self._kmax: Optional[int] = None
        self._pw: Optional[int] = None
        self._ctx = None
        self.root: Optional[RootMerge] = None
        self.attach_ms: List[float] = []
        self.detach_ms: List[float] = []
        # thread/process plumbing, created in _start()
        self._rounds: Optional[BoundedQueue] = None
        self._root_in = None
        self._outs_buf: Dict[int, Dict[int, L.LeafOut]] = defaultdict(dict)

    # -- public control -------------------------------------------------------
    def add_host(self, at_tick: Optional[int] = None) -> int:
        """Schedule an ingest host join (applied at the next tick boundary,
        or right before data tick ``at_tick``).  Returns the new leaf id."""
        with self._cmd_lock:
            leaf_id = self._next_leaf_id
            assert leaf_id < self.max_leaves, "max_leaves exhausted"
            self._next_leaf_id += 1
            self._cmds.append(_Command("add", leaf_id, at_tick,
                                       time.perf_counter()))
        return leaf_id

    def remove_host(self, leaf_id: int, at_tick: Optional[int] = None) -> None:
        """Schedule an ingest host leave (ESG flush semantics)."""
        with self._cmd_lock:
            self._cmds.append(_Command("remove", leaf_id, at_tick,
                                       time.perf_counter()))

    def rate_hint(self, tick: int) -> Optional[float]:
        return self.schedule.rate_at(tick) if self.schedule else None

    def stats(self) -> IngestStats:
        r = self.root
        if r is not None:
            r.sync_stats()
        return IngestStats(
            leaves=self.part.leaves,
            rounds=0 if r is None else r.rounds,
            ticks=self._tick_index,
            tuples_in=self.tuples_in,
            tuples_out=0 if r is None else r.tuples_out,
            watermark=-1 if r is None else r.wmark,
            root_overflow=0 if r is None else r.overflow,
            leaf_overflow=dict({} if r is None else r.leaf_overflow),
            attach_ms=list(self.attach_ms),
            detach_ms=list(self.detach_ms))

    # -- startup --------------------------------------------------------------
    def _start(self) -> None:
        assert not self._started, "IngestTier is one-shot"
        self._started = True
        self._it = iter(self.stream)
        first = next(self._it, None)
        if first is not None:
            self._it = itertools.chain([first], self._it)
            self._kmax, self._pw = first.kmax, first.payload_width
        elif self._restore is not None:
            # empty replay suffix (the snapshot covered the whole stream):
            # the gates still need their exact restored shapes to flush
            self._stream_done = True
            st = next(iter(self._restore["leaf_states"].values()))
            self._kmax = int(st["stash"]["keys"].shape[1])
            self._pw = int(st["stash"]["payload"].shape[1])
        else:
            self._stream_done = True
            self._kmax, self._pw = 1, 1
        if self.worker == "process":
            import multiprocessing as mp
            self._ctx = mp.get_context("spawn")
        if self._restore is not None and self._kmax is not None:
            # restore dimensions must match the snapshotted stream's (the
            # RuntimeConfig in the manifest rebuilds an identical stack)
            st = next(iter(self._restore["leaf_states"].values()))
            want_kmax = st["stash"]["keys"].shape[1]
            assert want_kmax == self._kmax, (want_kmax, self._kmax)
        self.root = RootMerge(self.max_leaves, self.root_cap, self._kmax,
                              self._pw, self.part.leaves,
                              backend=self.backend, out_pad=self.out_pad,
                              device=self.root_device,
                              check_every=self.root_check_every)
        if self._restore is not None:
            self.root.import_state(self._restore["root"])
        if self.worker != "inline":
            self._rounds = BoundedQueue(max(2 * self.chan_cap, 4))
            cap = max(4, (self.chan_cap + 2) * self.max_leaves)
            self._root_in = make_channel(self.worker, cap, self._ctx)
        restore_states = ({} if self._restore is None
                          else self._restore["leaf_states"])
        for leaf_id in self.part.leaves:
            self._spawn(leaf_id, self.part.owned_mask(leaf_id),
                        state=restore_states.get(leaf_id))
        if self.worker != "inline":
            self._router = threading.Thread(target=self._route_loop,
                                            daemon=True)
            self._router.start()

    def _spawn(self, leaf_id: int, owned: np.ndarray,
               state: Optional[Dict] = None) -> None:
        h = _Handle(leaf_id)
        if self.worker == "inline":
            h.gate = L.LeafGate(leaf_id, self.n_sources, owned,
                                self.leaf_cap, self._kmax, self._pw,
                                backend=self.backend, state=state)
        elif self.worker == "thread":
            gate = L.LeafGate(leaf_id, self.n_sources, owned, self.leaf_cap,
                              self._kmax, self._pw, backend=self.backend,
                              state=state)
            h.chan = make_channel("thread", self.chan_cap)
            h.thread = threading.Thread(
                target=L.run_gate_loop,
                args=(gate, h.chan.get, self._root_in.put), daemon=True)
            h.thread.start()
        else:                                     # process
            cfg = dict(leaf_id=leaf_id, n_sources=self.n_sources,
                       owned=np.asarray(owned, bool), cap=self.leaf_cap,
                       kmax=self._kmax, payload_width=self._pw,
                       backend=self.backend, state=state)
            o = _obs.get()
            if o is not None:
                # the child installs its own Obs with the parent's config
                # and ships drained payloads back on LeafOut.obs
                cfg["obs"] = o.cfg.to_dict()
            h.chan = make_channel("process", self.chan_cap, self._ctx)
            h.proc = self._ctx.Process(
                target=L.process_worker_main,
                args=(cfg, h.chan._q, self._root_in._q), daemon=True)
            h.proc.start()
        self._handles[leaf_id] = h

    # -- round construction (router role) ------------------------------------
    def _pop_due_cmd(self) -> Optional[_Command]:
        with self._cmd_lock:
            for i, c in enumerate(self._cmds):
                if c.at_tick is None or c.at_tick <= self._tick_index:
                    c = self._cmds.pop(i)
                    c.t_issued = time.perf_counter()
                    return c
        return None

    def _build_reconfig(self, cmd: _Command):
        ops_by_leaf: Dict[int, List[Tuple]] = {l: [] for l in
                                               self.part.leaves}
        if cmd.kind == "add":
            moves = self.part.rebalance(add=[cmd.leaf_id])
            ops_by_leaf[cmd.leaf_id] = []
            self._spawn(cmd.leaf_id,
                        np.zeros((self.n_sources,), bool))  # gains via ops
        else:
            moves = self.part.rebalance(remove=[cmd.leaf_id])
            ops_by_leaf[cmd.leaf_id] = [("flush",)]
        gains: Dict[int, int] = {}                # leaf -> min gamma gained
        for src, (old, new) in sorted(moves.items()):
            gamma = int(self.frontier[src])
            if cmd.kind != "remove" or old != cmd.leaf_id:
                # a flushing leaf removes everything wholesale
                ops_by_leaf.setdefault(old, []).append(
                    ("remove_source", src))
            ops_by_leaf.setdefault(new, []).append(
                ("add_source", src, gamma))
            gains[new] = min(gains.get(new, gamma), gamma)
        root_ops: List[Tuple] = []
        if cmd.kind == "add":
            from repro.core.watermark import INF_TIME
            root_ops.append(("add_leaf", cmd.leaf_id,
                             gains.pop(cmd.leaf_id, int(INF_TIME))))
        for leaf, gamma in sorted(gains.items()):
            root_ops.append(("clamp", leaf, gamma))
        if cmd.kind == "remove":
            root_ops.append(("remove_leaf", cmd.leaf_id))
        participants = tuple(sorted(set(self.part.leaves) |
                                    {cmd.leaf_id}))
        rec = _RoundRec(self._round, "reconfig", participants,
                        tuple(root_ops), cmd)
        msgs = {l: ("cmd", self._round, tuple(ops_by_leaf.get(l, ())))
                for l in participants}
        return rec, msgs

    def _fold_frontier(self, b_np: Dict[str, np.ndarray]) -> int:
        ok = b_np["valid"] & ~b_np["is_control"]
        src = b_np["source"][ok]
        tau = b_np["tau"][ok]
        if src.size:
            assert int(src.max()) < self.n_sources, \
                f"source id {int(src.max())} >= n_sources={self.n_sources}"
            np.maximum.at(self.frontier, src, tau.astype(np.int64))
        return int(ok.sum())

    def _build_next(self):
        """Next (rec, msgs_by_leaf), or None when the stream is fully
        routed and flushed."""
        if (self.snapshot_every and not self._flushed
                and self._tick_index > self._last_snap_tick
                and self._tick_index % self.snapshot_every == 0):
            # barrier snapshot round at the K-tick boundary, built BEFORE
            # any due membership command so the captured cut excludes it
            # (commands are controller intents, re-issued after a restore,
            # not snapshotted state)
            self._last_snap_tick = self._tick_index
            with self._cmd_lock:
                next_leaf_id = self._next_leaf_id
            rec = _RoundRec(self._round, "snap", self.part.leaves,
                            snap_tick=self._tick_index,
                            snap_frontier=self.frontier.copy(),
                            snap_tuples_in=self.tuples_in,
                            snap_next_leaf_id=next_leaf_id)
            msgs = {l: ("snap", self._round, None) for l in self.part.leaves}
            self._round += 1
            return rec, msgs
        cmd = self._pop_due_cmd()
        if cmd is not None:
            out = self._build_reconfig(cmd)
            self._round += 1
            return out
        if not self._stream_done:
            b = next(self._it, None)
            if b is None:
                self._stream_done = True
            else:
                b_np = L.batch_to_np(b)
                self.tuples_in += self._fold_frontier(b_np)
                tl = _obs.exemplars()
                if tl is not None:
                    # admission: the first stage of a sampled tuple's
                    # end-to-end timeline (same predicate at every stage)
                    tl.scan(b_np["source"], b_np["tau"],
                            b_np["valid"] & ~b_np["is_control"], "admit")
                keep = b_np["valid"]
                leaf_of_lane = self.part.assignment[
                    np.clip(b_np["source"], 0, self.n_sources - 1)]
                msgs = {}
                for l in self.part.leaves:
                    sel = keep & (leaf_of_lane == l)
                    msgs[l] = ("tick", self._round,
                               {f: b_np[f][sel] for f in L.FIELDS})
                rec = _RoundRec(self._round, "tick", self.part.leaves)
                self._round += 1
                self._tick_index += 1
                return rec, msgs
        if not self._flushed:
            self._flushed = True
            rec = _RoundRec(self._round, "final", self.part.leaves)
            msgs = {l: ("cmd", self._round, (("flush",),))
                    for l in self.part.leaves}
            self._round += 1
            return rec, msgs
        return None

    # -- threaded router ------------------------------------------------------
    def _route_loop(self) -> None:
        try:
            while not self._stop:
                item = self._build_next()
                if item is None:
                    break
                rec, msgs = item
                # record first: the consumer may only block on leaf outs
                # for rounds it knows about
                self._rounds.put(rec)
                for l, msg in msgs.items():
                    self._handles[l].chan.put(msg)
        except QueueClosed:
            pass                                   # shutdown while blocked
        except BaseException as e:                 # surfaced by consumer
            self._router_error = e
        finally:
            self._rounds.close()

    # -- consumer side --------------------------------------------------------
    def _collect(self, rec: _RoundRec) -> List[L.LeafOut]:
        if self.worker == "inline":
            raise AssertionError("inline mode collects synchronously")
        buf = self._outs_buf
        deadline = time.monotonic() + ROUND_TIMEOUT_S
        while set(buf[rec.round_id]) != set(rec.leaves):
            if self._router_error is not None:
                raise self._router_error
            out = self._root_in.get(timeout=1.0)
            if out is TIMEOUT:
                missing = sorted(set(rec.leaves) - set(buf[rec.round_id]))
                for l in missing:
                    h = self._handles.get(l)
                    if (h is not None and h.proc is not None
                            and not h.proc.is_alive()):
                        _obs.event("leaf_failure", leaf_id=l,
                                   round_id=rec.round_id,
                                   exitcode=h.proc.exitcode)
                        raise LeafFailure(
                            f"ingest leaf {l} died (exit code "
                            f"{h.proc.exitcode}) before answering round "
                            f"{rec.round_id}")
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"ingest round {rec.round_id} timed out waiting "
                        f"for leaves {missing}")
                continue
            buf[out.round_id][out.leaf_id] = out
        round_outs = buf.pop(rec.round_id)
        return [round_outs[l] for l in rec.leaves]

    def _dispatch_inline(self, rec: _RoundRec,
                         msgs: Dict[int, Tuple]) -> List[L.LeafOut]:
        outs = []
        for l in rec.leaves:
            h = self._handles[l]
            kind, r, payload = msgs[l]
            if kind == "tick":
                outs.append(h.gate.push_round(r, payload))
            elif kind == "snap":
                outs.append(L.LeafSnap(l, r, h.gate.export_state()))
            else:
                leaving = h.gate.apply(payload)
                outs.append(h.gate.push_round(r, None, final=leaving))
                if leaving:
                    del self._handles[l]
        return outs

    # -- snapshots ------------------------------------------------------------
    def _store_snapshot(self, rec: _RoundRec, snaps: List) -> None:
        """Assemble the tier-wide cut: every leaf's state at the barrier,
        the root gate (consumer-thread-owned, so between-rounds is safe),
        and the router-side routing state captured when the snap round was
        built.  Keyed by ``emitted_rounds`` — the number of merged rounds
        the consumer (pipeline) has seen before this cut — which is what
        aligns it with the runtime's tick ids."""
        self._snapshots[self._rounds_emitted] = {
            "leaves": [int(l) for l in rec.leaves],
            "assignment": self.part.assignment.tolist(),
            "next_leaf_id": int(rec.snap_next_leaf_id),
            "frontier": np.asarray(rec.snap_frontier, np.int64),
            "source_ticks": int(rec.snap_tick),
            "emitted_rounds": int(self._rounds_emitted),
            "tuples_in": int(rec.snap_tuples_in),
            "leaf_states": {int(s.leaf_id): s.state for s in snaps},
            "root": self.root.export_state(),
        }

    def pop_snapshot(self, emitted_rounds: int) -> Optional[Dict]:
        """The snapshot whose cut sits exactly before merged round
        ``emitted_rounds`` (and drop any older ones); None if not taken.
        The consumer thread stores, any thread may pop — guarded by the
        GIL-atomic dict ops plus the runtime's happens-before (the tier
        always collects the snap round before yielding the next tick)."""
        snap = self._snapshots.pop(emitted_rounds, None)
        for k in [k for k in self._snapshots if k < emitted_rounds]:
            self._snapshots.pop(k, None)
        return snap

    def latest_snapshot(self) -> Optional[Dict]:
        if not self._snapshots:
            return None
        return self._snapshots[max(self._snapshots)]

    def __iter__(self):
        self._start()
        try:
            while True:
                if self.worker == "inline":
                    item = self._build_next()
                    if item is None:
                        break
                    rec, msgs = item
                    outs = self._dispatch_inline(rec, msgs)
                else:
                    try:
                        rec = self._rounds.get()
                    except QueueClosed:
                        if self._router_error is not None:
                            raise self._router_error
                        break
                    outs = self._collect(rec)
                if rec.kind == "snap":
                    self._store_snapshot(rec, outs)
                    _obs.event("tier_snapshot", round_id=rec.round_id,
                               source_ticks=rec.snap_tick,
                               emitted_rounds=self._rounds_emitted)
                    continue               # snapshots merge nothing
                for lo in outs:            # cross-process obs piggybacks
                    if lo.obs is not None:
                        _obs.ingest_payload(lo.obs)
                tl = _obs.exemplars()
                if tl is not None:
                    for lo in outs:
                        r = lo.ready
                        if r["tau"].shape[0]:
                            tl.scan(r["source"], r["tau"],
                                    r["valid"] & ~r["is_control"],
                                    "root_merge")
                with _obs.span("root.merge"):
                    self.root.apply_pre(rec.root_ops)
                    out = self.root.push(outs)
                    self.root.apply_post(rec.root_ops)
                if rec.cmd is not None:
                    lat = (time.perf_counter() - rec.cmd.t_issued) * 1e3
                    (self.attach_ms if rec.cmd.kind == "add"
                     else self.detach_ms).append(lat)
                    _obs.event("tier_reconfig", cmd=rec.cmd.kind,
                               leaf_id=rec.cmd.leaf_id,
                               round_id=rec.round_id, latency_ms=lat,
                               leaves=[int(l) for l in self.part.leaves])
                if self.emitted is not None:
                    self.emitted.append(out)
                self._rounds_emitted += 1
                yield out
        finally:
            self._shutdown()

    def _shutdown(self) -> None:
        self._stop = True
        for h in list(self._handles.values()):
            if h.chan is not None:
                try:
                    h.chan.put(("stop",), timeout=0.1)
                except Exception:
                    pass
                h.chan.close()
        if self._rounds is not None:
            self._rounds.close()
        for h in list(self._handles.values()):
            if h.thread is not None:
                h.thread.join(timeout=10)
            if h.proc is not None:
                h.proc.join(timeout=20)
                if h.proc.is_alive():              # pragma: no cover
                    h.proc.terminate()
        if getattr(self, "_router", None) is not None \
                and self.worker != "inline":
            self._router.join(timeout=10)


# -- the flat oracle ---------------------------------------------------------

def single_gate_stream(stream, n_sources: int, cap: int, *,
                       backend: Optional[str] = None,
                       flush: bool = True) -> List[T.TupleBatch]:
    """The single-process oracle the tier must match: one flat ScaleGate
    over all sources, pushed tick by tick (plus a final ESG flush so the
    tail drains) — returns the list of ready batches."""
    import jax.numpy as jnp
    push = L._jit_push(backend)
    state = None
    outs: List[T.TupleBatch] = []
    for b in stream:
        if state is None:
            state = scalegate.init_scalegate(n_sources, cap, b.kmax,
                                             b.payload_width)
        state, out = push(state, b)
        outs.append(out)
    if state is not None and flush:
        state = scalegate.remove_sources(
            state, jnp.ones((n_sources,), bool))
        state, out = push(state, T.empty_batch(MIN_PAD, outs[0].kmax,
                                               outs[0].payload_width))
        outs.append(out)
    return outs


def collect_tuples(batches: Iterable[T.TupleBatch]) -> List[Tuple]:
    """Sorted multiset of (tau, source, keys, payload) over the valid lanes
    — the tier-level parity currency (payloads rounded as in io.sinks)."""
    res = []
    for b in batches:
        tau = np.asarray(b.tau)
        src = np.asarray(b.source)
        keys = np.asarray(b.keys)
        pay = np.asarray(b.payload)
        for i in np.nonzero(np.asarray(b.valid))[0]:
            res.append((int(tau[i]), int(src[i]), tuple(keys[i].tolist()),
                        tuple(np.round(pay[i], 4).tolist())))
    return sorted(res)


def emitted_taus(batches: Iterable[T.TupleBatch]) -> np.ndarray:
    """Concatenated valid-lane taus in emission order (the total-order
    witness: callers assert non-decreasing)."""
    taus = [np.asarray(b.tau)[np.asarray(b.valid)] for b in batches]
    return (np.concatenate(taus) if taus else np.zeros((0,), np.int64))
