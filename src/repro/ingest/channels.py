"""Channels: the bounded, closable edges between tier roles.

One protocol, two transports:

* ``thread`` / ``inline`` — ``repro.io.queues.BoundedQueue`` (its contract
  verbatim: blocking ``put`` backpressure, ``QueueClosed`` after drain,
  ``TIMEOUT`` sentinel on a timed-out ``get``);
* ``process``             — a ``multiprocessing.Queue`` wrapper that
  re-exposes the same contract (``maxsize`` gives the blocking-put
  backpressure; a ``MP_CLOSE`` marker item plays the close signal, since
  mp queues have no cross-process close).

Backpressure is the point: root→leaf→source stalls propagate purely by
these channels filling up — a slow consumer of the tier's merged stream
eventually blocks the source iterator itself.
"""

from __future__ import annotations

import queue as _stdlib_queue
import threading
import time
from typing import Any, Optional

from repro.io.queues import TIMEOUT, BoundedQueue, QueueClosed

MP_CLOSE = "__ingest_channel_close__"

# granularity of the blocked-put close poll: an mp.Queue has no condition
# variable we can hook close() into, so a blocked put re-checks the local
# closed flag this often (worst-case extra latency on close, not on data)
_PUT_POLL_S = 0.05


class MpChannel:
    """BoundedQueue-contract adapter over ``multiprocessing.Queue``."""

    def __init__(self, ctx, cap: int):
        self._q = ctx.Queue(maxsize=cap)
        self._recv_closed = False
        self._send_closed = False

    def put(self, item: Any, timeout: Optional[float] = None) -> None:
        """Blocking put with the BoundedQueue close contract: ``close()``
        during a blocked put raises ``QueueClosed`` within ``_PUT_POLL_S``
        instead of waiting out ``timeout`` — the router thread stuck
        feeding a SIGKILLed leaf's full queue must unblock as soon as the
        tier starts draining, or restore-after-kill hangs on it."""
        deadline = None if timeout is None else time.monotonic() + timeout
        stalled = False
        while True:
            if self._send_closed:
                raise QueueClosed
            slice_s = _PUT_POLL_S
            if deadline is not None:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError("MpChannel.put timed out")
                slice_s = min(slice_s, left)
            try:
                self._q.put(item, timeout=slice_s)
                return
            except _stdlib_queue.Full:
                if not stalled:
                    # one event per stall episode, not per poll slice
                    stalled = True
                    from repro import obs as _obs
                    _obs.event("backpressure_stall", transport="mp")
                    _obs.counter_inc("chan.mp_blocked_puts")
                continue

    def get(self, timeout: Optional[float] = None) -> Any:
        if self._recv_closed:
            raise QueueClosed
        try:
            item = self._q.get(timeout=timeout)
        except _stdlib_queue.Empty:
            return TIMEOUT
        if item == MP_CLOSE:
            self._recv_closed = True
            raise QueueClosed
        return item

    def close(self) -> None:
        # marker, not Queue.close(): the receiver must still drain what the
        # producer enqueued before the close (the BoundedQueue contract).
        # Delivery must not be droppable: on a full queue a background
        # retry keeps trying while the receiver drains (the tier's
        # process-join timeout + terminate() covers a receiver that never
        # will).
        if self._send_closed:
            return
        self._send_closed = True
        try:
            self._q.put_nowait(MP_CLOSE)
        except _stdlib_queue.Full:
            def _retry():
                try:
                    self._q.put(MP_CLOSE, timeout=60)
                except Exception:
                    pass
            threading.Thread(target=_retry, daemon=True).start()


def make_channel(worker: str, cap: int, ctx=None):
    if worker == "process":
        assert ctx is not None
        return MpChannel(ctx, cap)
    return BoundedQueue(cap)
