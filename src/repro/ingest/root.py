"""Root merge: the upper level of the hierarchical ScaleGate (§6).

The root is *literally* ``scalegate.push`` one level up: its "sources" are
the leaf gates, whose ready batches are themselves timestamp-sorted
streams.  Two deltas from a flat gate, both threaded through the core
primitives rather than re-implemented:

* **explicit watermarks** — the root's frontier axis is the leaf set while
  its tuples keep their original source ids for the downstream pipeline,
  so the per-tuple fold is replaced by ``wm.observe_explicit`` over the
  leaves' *reported* watermarks (``scalegate.push(wstate=…)``).  Since a
  leaf only forwards ``tau <= W_leaf``, the report dominates any forwarded
  tau, and Definition 3 composes:
  ``W_root = min_leaf W_leaf = min_leaf min_{i in leaf} tau-frontier_i =
  min_i frontier_i`` — exactly the flat gate's watermark.
* **rebalance clamps** — when a leaf *gains* a migrated source, the root's
  frontier for that leaf drops to the source's Lemma-3 bound gamma
  (``wm.clamp_frontier``); gamma is an active source's frontier, hence
  ``>= W_root``, so the root watermark never regresses.

The root also *checks* its two end-to-end invariants every round — the
emitted stream's tau is non-decreasing across rounds and the watermark is
monotone — and surfaces stash overflow (its own and each leaf's reported
count) through ``warnings`` + stats, never silently.

Tie-break tolerance: the root re-sorts whatever arrives, so leaves may run
either ``merge_order`` backend contract (``(tau, source, arrival)`` on xla,
``(tau, arrival)`` on the Pallas bitonic path) — the root's ready *set*
and tau grouping are identical regardless (see
``repro.core.scalegate.TIE_BREAK``).
"""

from __future__ import annotations

import functools
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import scalegate
from repro.core import tuples as T
from repro.core import watermark as wm
from repro.ingest.leaf import LeafOut, concat_np, np_to_batch, pad_np

MIN_PAD = 32


def bucket(n: int, lo: int = MIN_PAD) -> int:
    """Power-of-two lane bucket >= n (bounds the set of jit shapes)."""
    p = lo
    while p < n:
        p <<= 1
    return p


@functools.lru_cache(maxsize=None)
def _jit_push_wstate(backend: Optional[str]):
    import jax

    def push(state, incoming, wstate):
        return scalegate.push(state, incoming, backend=backend,
                              wstate=wstate)
    return jax.jit(push)


class RootMerge:
    def __init__(self, max_leaves: int, cap: int, kmax: int,
                 payload_width: int, active_leaves: Sequence[int],
                 backend: Optional[str] = None, out_pad: int = MIN_PAD):
        import jax.numpy as jnp
        self.max_leaves = max_leaves
        self.kmax = kmax
        self.payload_width = payload_width
        self.backend = backend
        # lane floor for the incoming pad: a floor near the steady-state
        # round volume keeps the emitted batch shape constant, so the
        # downstream pipeline compiles one step instead of one per bucket
        self.out_pad = out_pad
        active = np.zeros((max_leaves,), bool)
        active[list(active_leaves)] = True
        self.state = scalegate.init_scalegate(
            max_leaves, cap, kmax, payload_width, active=jnp.asarray(active))
        self._push = _jit_push_wstate(backend)
        # -- invariants + accounting -------------------------------------
        self.last_emitted_tau = -1       # total-order witness across rounds
        self.wmark = -1                  # monotone watermark witness
        self.leaf_overflow: Dict[int, int] = {l: 0 for l in active_leaves}
        self.tuples_out = 0
        self.rounds = 0

    @property
    def overflow(self) -> int:
        return int(self.state.overflow)

    # -- membership ----------------------------------------------------------
    def _mask(self, leaf: int):
        import jax.numpy as jnp
        m = np.zeros((self.max_leaves,), bool)
        m[leaf] = True
        return jnp.asarray(m)

    def add_leaf(self, leaf: int, gamma: int) -> None:
        self.state = scalegate.add_sources(self.state, self._mask(leaf),
                                           gamma)
        self.leaf_overflow.setdefault(leaf, 0)

    def remove_leaf(self, leaf: int) -> None:
        self.state = scalegate.remove_sources(self.state, self._mask(leaf))

    def clamp_leaf(self, leaf: int, gamma: int) -> None:
        """The leaf gained a migrated source with safe bound gamma."""
        self.state = scalegate.ScaleGateState(
            stash=self.state.stash,
            wmark=wm.clamp_frontier(self.state.wmark, self._mask(leaf),
                                    gamma),
            overflow=self.state.overflow)

    def apply_pre(self, root_ops: Sequence) -> None:
        for op in root_ops:
            if op[0] == "add_leaf":
                self.add_leaf(op[1], op[2])
            elif op[0] == "clamp":
                self.clamp_leaf(op[1], op[2])

    def apply_post(self, root_ops: Sequence) -> None:
        for op in root_ops:
            if op[0] == "remove_leaf":
                self.remove_leaf(op[1])

    # -- the merge -----------------------------------------------------------
    def push(self, outs: Sequence[LeafOut]) -> T.TupleBatch:
        """Merge one round of leaf outputs; returns the root-ready batch
        (static ``cap + bucket`` lanes, validity-masked, totally ordered).
        """
        import jax.numpy as jnp

        reports = np.full((self.max_leaves,), -1, np.int64)
        rmask = np.zeros((self.max_leaves,), bool)
        for o in outs:
            reports[o.leaf_id] = max(reports[o.leaf_id], o.wmark)
            rmask[o.leaf_id] = True
            prev = self.leaf_overflow.get(o.leaf_id, 0)
            if o.overflow > prev:
                warnings.warn(
                    f"ingest leaf {o.leaf_id} stash overflow: "
                    f"{o.overflow} tuples dropped (was {prev})",
                    RuntimeWarning, stacklevel=2)
            self.leaf_overflow[o.leaf_id] = max(prev, o.overflow)

        incoming_np = concat_np([o.ready for o in outs],
                                self.kmax, self.payload_width)
        n = incoming_np["tau"].shape[0]
        incoming = np_to_batch(pad_np(incoming_np, bucket(n, self.out_pad)))

        wstate = wm.observe_explicit(self.state.wmark,
                                     jnp.asarray(reports, jnp.int32),
                                     jnp.asarray(rmask))
        prev_overflow = self.overflow
        self.state, out = self._push(self.state, incoming, wstate)

        # -- invariants (cheap host checks on every round) ----------------
        w = int(self.state.wmark.value())
        if w < self.wmark:
            raise AssertionError(
                f"root watermark regressed: {self.wmark} -> {w}")
        self.wmark = w
        tau = np.asarray(out.tau)
        valid = np.asarray(out.valid)
        if valid.any():
            emitted = tau[valid]
            if int(emitted[0]) < self.last_emitted_tau:
                raise AssertionError(
                    "root ready stream not totally ordered: emitted "
                    f"tau {int(emitted[0])} after {self.last_emitted_tau}")
            if (np.diff(emitted) < 0).any():
                raise AssertionError("root ready batch not tau-sorted")
            self.last_emitted_tau = int(emitted[-1])
            self.tuples_out += int(valid.sum())
        if self.overflow > prev_overflow:
            warnings.warn(
                f"ingest root stash overflow: {self.overflow} tuples "
                f"dropped (was {prev_overflow})", RuntimeWarning,
                stacklevel=2)
        self.rounds += 1
        return out
