"""Root merge: the upper level of the hierarchical ScaleGate (§6).

The root is *literally* ``scalegate.push`` one level up: its "sources" are
the leaf gates, whose ready batches are themselves timestamp-sorted
streams.  Two deltas from a flat gate, both threaded through the core
primitives rather than re-implemented:

* **explicit watermarks** — the root's frontier axis is the leaf set while
  its tuples keep their original source ids for the downstream pipeline,
  so the per-tuple fold is replaced by ``wm.observe_explicit`` over the
  leaves' *reported* watermarks (``scalegate.push(wstate=…)``).  Since a
  leaf only forwards ``tau <= W_leaf``, the report dominates any forwarded
  tau, and Definition 3 composes:
  ``W_root = min_leaf W_leaf = min_leaf min_{i in leaf} tau-frontier_i =
  min_i frontier_i`` — exactly the flat gate's watermark.
* **rebalance clamps** — when a leaf *gains* a migrated source, the root's
  frontier for that leaf drops to the source's Lemma-3 bound gamma
  (``wm.clamp_frontier``); gamma is an active source's frontier, hence
  ``>= W_root``, so the root watermark never regresses.

The root also *checks* its two end-to-end invariants every round — the
emitted stream's tau is non-decreasing across rounds and the watermark is
monotone — and surfaces stash overflow (its own and each leaf's reported
count) through ``warnings`` + stats, never silently.

Tie-break tolerance: the root re-sorts whatever arrives, so leaves may run
either ``merge_order`` backend contract (``(tau, source, arrival)`` on xla,
``(tau, arrival)`` on the Pallas bitonic path) — the root's ready *set*
and tau grouping are identical regardless (see
``repro.core.scalegate.TIE_BREAK``).
"""

from __future__ import annotations

import functools
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import obs as _obs
from repro.core import scalegate
from repro.core import tuples as T
from repro.core import watermark as wm
from repro.ingest.leaf import (FIELDS, LeafOut, concat_np, empty_np,
                               np_to_batch, pad_np)

MIN_PAD = 32


def bucket(n: int, lo: int = MIN_PAD) -> int:
    """Power-of-two lane bucket >= n (bounds the set of jit shapes)."""
    p = lo
    while p < n:
        p <<= 1
    return p


@functools.lru_cache(maxsize=None)
def _jit_push_wstate(backend: Optional[str]):
    import jax

    def push(state, incoming, wstate):
        return scalegate.push(state, incoming, backend=backend,
                              wstate=wstate)
    return jax.jit(push)


@functools.lru_cache(maxsize=None)
def _jit_push_stacked(backend: Optional[str]):
    import jax

    def push(state, stacked, reports, rmask):
        return scalegate.push_stacked(state, stacked, reports, rmask,
                                      backend=backend)
    return jax.jit(push)


class RootMerge:
    """``device=True`` selects the fused on-device round: per-leaf ready
    chunks are stacked into one rank-2 buffer and merged by a single
    ``scalegate_merge_stacked`` kernel call with the watermark gate
    evaluated on device (``wm.fold_reports``), so the steady-state round
    issues no blocking host readback.  The per-round invariant checks of
    the host path then run every ``check_every`` rounds instead (each
    check is a device sync); stats accrue lazily (``sync_stats``)."""

    def __init__(self, max_leaves: int, cap: int, kmax: int,
                 payload_width: int, active_leaves: Sequence[int],
                 backend: Optional[str] = None, out_pad: int = MIN_PAD,
                 device: bool = False, check_every: int = 8):
        import jax.numpy as jnp
        self.max_leaves = max_leaves
        self.kmax = kmax
        self.payload_width = payload_width
        self.backend = backend
        # lane floor for the incoming pad: a floor near the steady-state
        # round volume keeps the emitted batch shape constant, so the
        # downstream pipeline compiles one step instead of one per bucket
        self.out_pad = out_pad
        self.device = device
        self.check_every = check_every
        if device:
            # chunk rows of the stacked buffer; the stash prepends as whole
            # rows, so the capacity must be row-aligned
            self.chunk = bucket(out_pad)
            cap = ((cap + self.chunk - 1) // self.chunk) * self.chunk
        active = np.zeros((max_leaves,), bool)
        active[list(active_leaves)] = True
        self.state = scalegate.init_scalegate(
            max_leaves, cap, kmax, payload_width, active=jnp.asarray(active))
        self._push = _jit_push_wstate(backend)
        self._push_stacked = _jit_push_stacked(backend)
        # -- invariants + accounting -------------------------------------
        self.last_emitted_tau = -1       # total-order witness across rounds
        self.wmark = -1                  # monotone watermark witness
        self.leaf_overflow: Dict[int, int] = {l: 0 for l in active_leaves}
        self.tuples_out = 0
        self.rounds = 0
        self._out_valid: List = []       # device count handles, unsynced
        self._last_overflow_warned = 0

    @property
    def overflow(self) -> int:
        return int(self.state.overflow)

    # -- membership ----------------------------------------------------------
    def _mask(self, leaf: int):
        import jax.numpy as jnp
        m = np.zeros((self.max_leaves,), bool)
        m[leaf] = True
        return jnp.asarray(m)

    def add_leaf(self, leaf: int, gamma: int) -> None:
        self.state = scalegate.add_sources(self.state, self._mask(leaf),
                                           gamma)
        self.leaf_overflow.setdefault(leaf, 0)

    def remove_leaf(self, leaf: int) -> None:
        self.state = scalegate.remove_sources(self.state, self._mask(leaf))

    def clamp_leaf(self, leaf: int, gamma: int) -> None:
        """The leaf gained a migrated source with safe bound gamma."""
        self.state = scalegate.ScaleGateState(
            stash=self.state.stash,
            wmark=wm.clamp_frontier(self.state.wmark, self._mask(leaf),
                                    gamma),
            overflow=self.state.overflow)

    def apply_pre(self, root_ops: Sequence) -> None:
        for op in root_ops:
            if op[0] == "add_leaf":
                self.add_leaf(op[1], op[2])
            elif op[0] == "clamp":
                self.clamp_leaf(op[1], op[2])

    def apply_post(self, root_ops: Sequence) -> None:
        for op in root_ops:
            if op[0] == "remove_leaf":
                self.remove_leaf(op[1])

    # -- the merge -----------------------------------------------------------
    def _fold_leaf_reports(self, outs: Sequence[LeafOut]):
        """Per-leaf reported watermarks + report mask of this round, with
        the leaf-overflow surfacing shared by both merge paths."""
        reports = np.full((self.max_leaves,), -1, np.int64)
        rmask = np.zeros((self.max_leaves,), bool)
        for o in outs:
            reports[o.leaf_id] = max(reports[o.leaf_id], o.wmark)
            rmask[o.leaf_id] = True
            prev = self.leaf_overflow.get(o.leaf_id, 0)
            if o.overflow > prev:
                warnings.warn(
                    f"ingest leaf {o.leaf_id} stash overflow: "
                    f"{o.overflow} tuples dropped (was {prev})",
                    RuntimeWarning, stacklevel=2)
                _obs.event("leaf_overflow", leaf_id=o.leaf_id,
                           overflow=o.overflow, was=prev)
            self.leaf_overflow[o.leaf_id] = max(prev, o.overflow)
        return reports, rmask

    def push(self, outs: Sequence[LeafOut]) -> T.TupleBatch:
        """Merge one round of leaf outputs; returns the root-ready batch
        (static lane count, validity-masked, totally ordered).
        """
        if self.device:
            return self._push_device(outs)
        return self._push_host(outs)

    def _push_host(self, outs: Sequence[LeafOut]) -> T.TupleBatch:
        import jax.numpy as jnp

        reports, rmask = self._fold_leaf_reports(outs)
        incoming_np = concat_np([o.ready for o in outs],
                                self.kmax, self.payload_width)
        n = incoming_np["tau"].shape[0]
        incoming = np_to_batch(pad_np(incoming_np, bucket(n, self.out_pad)))

        wstate = wm.observe_explicit(self.state.wmark,
                                     jnp.asarray(reports, jnp.int32),
                                     jnp.asarray(rmask))
        prev_overflow = self.overflow
        self.state, out = self._push(self.state, incoming, wstate)

        # -- invariants (cheap host checks on every round) ----------------
        w = int(self.state.wmark.value())
        if w < self.wmark:
            raise AssertionError(
                f"root watermark regressed: {self.wmark} -> {w}")
        self.wmark = w
        tau = np.asarray(out.tau)
        valid = np.asarray(out.valid)
        if valid.any():
            emitted = tau[valid]
            if int(emitted[0]) < self.last_emitted_tau:
                raise AssertionError(
                    "root ready stream not totally ordered: emitted "
                    f"tau {int(emitted[0])} after {self.last_emitted_tau}")
            if (np.diff(emitted) < 0).any():
                raise AssertionError("root ready batch not tau-sorted")
            self.last_emitted_tau = int(emitted[-1])
            self.tuples_out += int(valid.sum())
        if self.overflow > prev_overflow:
            warnings.warn(
                f"ingest root stash overflow: {self.overflow} tuples "
                f"dropped (was {prev_overflow})", RuntimeWarning,
                stacklevel=2)
            _obs.event("root_overflow", overflow=self.overflow,
                       was=prev_overflow)
        self.rounds += 1
        o = _obs.get()
        if o is not None:
            reg = o.registry
            reg.inc("root.rounds")
            reg.set_gauge("root.wmark", self.wmark)
            reg.set_gauge("root.tuples_out", self.tuples_out)
        return out

    def _push_device(self, outs: Sequence[LeafOut]) -> T.TupleBatch:
        """The fused round: stack per-leaf ready chunks into rank-2 rows and
        issue ONE ``push_stacked`` (merge + device-side watermark gate) —
        no blocking host sync in the steady state.  Arrival order inside
        the stacked buffer preserves the leaves' relative lane order, so
        the emitted (tau, arrival) stream groups exactly like the host
        path's compacted concat."""
        import jax.numpy as jnp

        reports, rmask = self._fold_leaf_reports(outs)
        chunk = self.chunk
        rows = []
        for o in outs:
            n, off = o.n_ready, 0
            while off < n:
                part = {f: o.ready[f][off:off + chunk] for f in FIELDS}
                rows.append(pad_np(part, chunk))
                off += chunk
        # power-of-two row count bounds the set of compiled shapes; the
        # floor at the round's leaf count keeps the steady-state output
        # shape CONSTANT (a leaf with nothing ready contributes no data
        # rows, and a flip-flopping shape would force the downstream
        # super-batcher to flush partial, padded K-tick groups)
        n_rows = bucket(max(len(rows), len(outs), 1), lo=1)
        if len(rows) < n_rows:
            empty = pad_np(empty_np(self.kmax, self.payload_width), chunk)
            rows += [empty] * (n_rows - len(rows))
        stacked = T.TupleBatch(
            **{f: jnp.asarray(np.stack([r[f] for r in rows]))
               for f in FIELDS})
        self.state, out = self._push_stacked(
            self.state, stacked, jnp.asarray(reports, jnp.int32),
            jnp.asarray(rmask))
        self.rounds += 1
        _obs.counter_inc("root.rounds")
        self._out_valid.append(out.num_valid())
        if self.check_every and self.rounds % self.check_every == 0:
            self._verify_round(out)
        return out

    def _verify_round(self, out: T.TupleBatch) -> None:
        """The host-path invariant checks, run periodically on the device
        path (each is a device sync).  ``last_emitted_tau`` then witnesses
        order across *checked* rounds — still sound, since a correct
        emitted stream is non-decreasing across every round between them."""
        w = int(self.state.wmark.value())
        if w < self.wmark:
            raise AssertionError(
                f"root watermark regressed: {self.wmark} -> {w}")
        self.wmark = w
        tau = np.asarray(out.tau)
        valid = np.asarray(out.valid)
        if valid.any():
            emitted = tau[valid]
            if int(emitted[0]) < self.last_emitted_tau:
                raise AssertionError(
                    "root ready stream not totally ordered: emitted "
                    f"tau {int(emitted[0])} after {self.last_emitted_tau}")
            if (np.diff(emitted) < 0).any():
                raise AssertionError("root ready batch not tau-sorted")
            self.last_emitted_tau = int(emitted[-1])
        if self.overflow > self._last_overflow_warned:
            warnings.warn(
                f"ingest root stash overflow: {self.overflow} tuples "
                f"dropped (was {self._last_overflow_warned})",
                RuntimeWarning, stacklevel=2)
            _obs.event("root_overflow", overflow=self.overflow,
                       was=self._last_overflow_warned)
        self._last_overflow_warned = self.overflow
        _obs.gauge_set("root.wmark", self.wmark)

    def sync_stats(self) -> None:
        """Materialize the device path's lazily-tracked stats (blocks on the
        accumulated count handles; call outside the hot loop)."""
        if self._out_valid:
            self.tuples_out += int(np.sum([int(np.asarray(v))
                                           for v in self._out_valid]))
            self._out_valid.clear()
        if self.device:
            self.wmark = max(self.wmark, int(self.state.wmark.value()))

    # -- checkpoint/restore --------------------------------------------------
    @staticmethod
    def effective_cap(cap: int, out_pad: int, device: bool) -> int:
        """The stash capacity a ``RootMerge(cap=cap)`` actually allocates
        (the device path row-aligns it) — restore templates need the real
        array shapes."""
        if not device:
            return cap
        chunk = bucket(out_pad)
        return ((cap + chunk - 1) // chunk) * chunk

    def export_state(self) -> Dict:
        """Numpy snapshot of the root gate *and* its host-side invariant
        counters, taken at a round boundary (the tier's consumer thread is
        the only mutator, so calling between rounds is race-free)."""
        self.sync_stats()
        return {
            "sg": scalegate.export_np(self.state),
            "meta": {
                "last_emitted_tau": self.last_emitted_tau,
                "wmark": self.wmark,
                "leaf_overflow": dict(self.leaf_overflow),
                "tuples_out": self.tuples_out,
                "rounds": self.rounds,
                "last_overflow_warned": self._last_overflow_warned,
            },
        }

    def import_state(self, snap: Dict) -> None:
        got = np.asarray(snap["sg"]["stash"]["tau"]).shape[0]
        want = self.state.capacity
        assert got == want, f"root stash capacity changed: {got} != {want}"
        self.state = scalegate.import_np(snap["sg"])
        meta = snap["meta"]
        self.last_emitted_tau = int(meta["last_emitted_tau"])
        self.wmark = int(meta["wmark"])
        self.leaf_overflow = {int(k): int(v)
                              for k, v in meta["leaf_overflow"].items()}
        self.tuples_out = int(meta["tuples_out"])
        self.rounds = int(meta["rounds"])
        self._last_overflow_warned = int(meta["last_overflow_warned"])
        self._out_valid = []
