"""Hierarchical multi-host ScaleGate: the distributed ingest tier (§6).

Many ingest hosts (leaf ScaleGates, each merging a disjoint source subset)
feed one mesh through a root merge that is ``scalegate.push`` one level up
— Definition 3 composes (``W = min_leaf W_leaf = min_i frontier_i``) and
the ready stream stays totally ordered end to end.  ``IngestTier`` is the
runtime: elastic membership (``add_host``/``remove_host`` with the ESG
``addSources``/``removeSources`` semantics, zero state transfer),
bounded-channel backpressure root→leaf→source, and a drop-in iterable
source for ``AsyncStreamRuntime``.
"""

from repro.ingest.leaf import LeafGate, LeafOut, LeafSnap
from repro.ingest.partitioner import SourcePartitioner
from repro.ingest.root import RootMerge
from repro.ingest.tier import (IngestStats, IngestTier, LeafFailure,
                               collect_tuples, emitted_taus,
                               single_gate_stream)

__all__ = [
    "IngestStats", "IngestTier", "LeafFailure", "LeafGate", "LeafOut",
    "LeafSnap", "RootMerge", "SourcePartitioner", "collect_tuples",
    "emitted_taus", "single_gate_stream",
]
