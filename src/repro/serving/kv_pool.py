"""VSN serving slot pool: state-transfer-free elastic inference (DESIGN.md §3).

The KV cache pool is STRETCH's shared sigma for the serving operator:
request slots are virtual keys with a *fixed* storage layout over the full
mesh; which *instance* (active replica group) serves a slot is the epoch's
``f_mu`` — scaling replicas up/down, or draining a straggler, rewrites the
tiny table and never moves a byte of KV (the SN baseline, implemented for
comparison, migrates the slot's KV to its new owner — GBs per reconfig).

The engine implements continuous batching as a stream operator: requests
are tuples (tau = arrival time), admission is the windowed batch assembly,
and per-tick the active slots advance one decode step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic
from repro.models import model as M, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # token ids
    max_new: int
    arrived: int                 # tau
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1


@dataclasses.dataclass
class SlotPool:
    """Fixed-capacity decode slots; free-list + f_mu ownership table."""
    cfg: ModelConfig
    n_slots: int
    max_seq: int
    n_instances: int

    def __post_init__(self):
        self.caches, self.states = transformer.init_caches(
            self.cfg, self.n_slots, self.max_seq)
        self.free = list(range(self.n_slots))
        self.pos = np.zeros((self.n_slots,), np.int32)
        self.fmu = np.arange(self.n_slots, dtype=np.int32) % self.n_instances
        self.active = np.ones((self.n_instances,), bool)
        self.kv_bytes_moved = 0   # SN baseline counter

    def alloc(self) -> Optional[int]:
        return self.free.pop() if self.free else None

    def release(self, slot: int):
        self.pos[slot] = 0
        self.free.append(slot)

    def slot_bytes(self) -> int:
        per_slot = 0
        for leaf in jax.tree.leaves((self.caches, self.states)):
            per_slot += leaf.dtype.itemsize * leaf.size // leaf.shape[1] \
                if leaf.ndim > 1 else 0
        return per_slot

    # ---- elasticity -------------------------------------------------------
    def reconfigure_vsn(self, n_active: int) -> int:
        """VSN: remap slot ownership; zero KV movement.  Returns bytes."""
        self.active[:] = False
        self.active[:n_active] = True
        self.fmu = np.arange(self.n_slots, dtype=np.int32) % max(n_active, 1)
        return self.fmu.nbytes + self.active.nbytes

    def reconfigure_sn(self, n_active: int) -> int:
        """SN baseline: slots whose owner changed ship their KV state."""
        old = self.fmu.copy()
        moved_bytes = 0
        self.reconfigure_vsn(n_active)
        moved = (old != self.fmu) & ~np.isin(np.arange(self.n_slots),
                                             self.free)
        moved_bytes = int(moved.sum()) * self.slot_bytes()
        self.kv_bytes_moved += moved_bytes
        return moved_bytes


class ServingEngine:
    """Continuous batching driver over a SlotPool."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int,
                 max_seq: int, n_instances: int = 1, greedy: bool = True):
        self.cfg, self.params = cfg, params
        self.pool = SlotPool(cfg, n_slots, max_seq, n_instances)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self.greedy = greedy
        self._decode = jax.jit(
            lambda p, c, s, t, pos: M.decode_step(p, c, s, t, pos, cfg=cfg))
        self.steps = 0

    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        while self.waiting:
            slot = self.pool.alloc()
            if slot is None:
                return
            req = self.waiting.pop(0)
            req.slot = slot
            # prefill token-by-token through the decode path (single code
            # path; a bulk prefill_with_cache fast path exists for batch=1)
            for i, t in enumerate(req.prompt):
                self._step_slot(req, int(t))
            self.running[req.uid] = req

    def _step_slot(self, req: Request, token: int):
        slot = req.slot
        caches, states = self.pool.caches, self.pool.states
        one = lambda a: a[:, slot:slot + 1] if a is not None else None
        c1 = jax.tree.map(lambda a: a[:, slot:slot + 1], caches) \
            if caches is not None else None
        s1 = jax.tree.map(lambda a: a[:, slot:slot + 1], states) \
            if states is not None else None
        tok = jnp.asarray([token], jnp.int32)
        logits, c1, s1 = self._decode(self.params, c1, s1, tok,
                                      jnp.int32(self.pool.pos[slot]))
        if caches is not None:
            self.pool.caches = jax.tree.map(
                lambda a, b: a.at[:, slot:slot + 1].set(b), caches, c1)
        if states is not None:
            self.pool.states = jax.tree.map(
                lambda a, b: a.at[:, slot:slot + 1].set(b), states, s1)
        self.pool.pos[slot] += 1
        return int(jnp.argmax(logits[0]))

    def tick(self) -> List[Request]:
        """One decode round over all running requests; returns finished."""
        self._admit()
        done = []
        for req in list(self.running.values()):
            last = req.out[-1] if req.out else int(req.prompt[-1])
            nxt = self._step_slot(req, last)
            req.out.append(nxt)
            if len(req.out) >= req.max_new:
                done.append(req)
                del self.running[req.uid]
                self.pool.release(req.slot)
        self.steps += 1
        return done
