"""VSN serving slot pool: state-transfer-free elastic inference (DESIGN.md §3).

The KV cache pool is STRETCH's shared sigma for the serving operator:
request slots are virtual keys with a *fixed* storage layout over the full
mesh; which *instance* (active replica group) serves a slot is the epoch's
``f_mu`` — scaling replicas up/down, or draining a straggler, rewrites the
tiny table and never moves a byte of KV (the SN baseline, implemented for
comparison, migrates the slot's KV to its new owner — GBs per reconfig).

The engine implements continuous batching as a stream operator: requests
are tuples (tau = arrival time), admission is the windowed batch assembly,
and per-tick the active slots advance one decode step.  Admission prefills
the whole prompt in one forward (the first output token is the argmax of
the prefill's final logits); the decode round gathers the active slot set
into one power-of-two-bucketed batch and advances every running request
with a single jitted call — per-slot positions via vmap, so slots at
different depths share the executable.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.models import model as M, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # token ids
    max_new: int
    arrived: int = 0             # tau
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    admitted_step: int = -1
    finished_step: int = -1


@dataclasses.dataclass
class SlotPool:
    """Fixed-capacity decode slots; free-list + f_mu ownership table."""
    cfg: ModelConfig
    n_slots: int
    max_seq: int
    n_instances: int

    def __post_init__(self):
        self.caches, self.states = transformer.init_caches(
            self.cfg, self.n_slots, self.max_seq)
        self.free = list(range(self.n_slots))
        self.pos = np.zeros((self.n_slots,), np.int32)
        self.n_active = self.n_instances
        self.fmu = np.arange(self.n_slots, dtype=np.int32) % self.n_instances
        self.active = np.ones((self.n_instances,), bool)
        self.kv_bytes_moved = 0   # SN baseline counter

    def alloc(self) -> Optional[int]:
        return self.free.pop() if self.free else None

    def release(self, slot: int):
        self.pos[slot] = 0
        # a recycled slot must not leak the previous occupant: recurrent
        # state (SSM/RWKV wkv, shift, ssm state) feeds straight into the
        # next request's first step, so it MUST be zeroed; the KV cache is
        # zeroed too — positions past ``pos`` are causally masked, so this
        # half is hygiene, but it keeps a freed slot bit-identical to a
        # fresh one (the engine-vs-reference parity contract).
        if self.states is not None:
            self.states = jax.tree.map(
                lambda a: a.at[:, slot:slot + 1].set(
                    jnp.zeros((), a.dtype)), self.states)
        if self.caches is not None:
            self.caches = jax.tree.map(
                lambda a: a.at[:, slot:slot + 1].set(
                    jnp.zeros((), a.dtype)), self.caches)
        self.free.append(slot)

    def slot_bytes(self) -> int:
        per_slot = 0
        for leaf in jax.tree.leaves((self.caches, self.states)):
            per_slot += leaf.dtype.itemsize * leaf.size // leaf.shape[1] \
                if leaf.ndim > 1 else 0
        return per_slot

    def occupied(self) -> List[int]:
        free = set(self.free)
        return [s for s in range(self.n_slots) if s not in free]

    # ---- elasticity -------------------------------------------------------
    def reconfigure_vsn(self, n_active: int) -> int:
        """VSN: remap slot ownership; zero KV movement.  Returns bytes."""
        self.active[:] = False
        self.active[:n_active] = True
        self.n_active = max(n_active, 1)
        self.fmu = np.arange(self.n_slots, dtype=np.int32) % self.n_active
        return self.fmu.nbytes + self.active.nbytes

    def reconfigure_sn(self, n_active: int) -> int:
        """SN baseline: slots whose owner changed ship their KV state.
        The shipped bytes are *materialized* (device -> host -> device round
        trip of the moved slots' caches), so the measured reconfiguration
        latency reflects a real migration, not just a counter."""
        old = self.fmu.copy()
        self.reconfigure_vsn(n_active)
        # free slots hold no live state and never move; membership via a
        # set keeps this O(slots), not O(slots * free)
        free = set(self.free)
        moved = [s for s in range((self.n_slots))
                 if old[s] != self.fmu[s] and s not in free]
        moved_bytes = len(moved) * self.slot_bytes()
        if moved:
            idx = np.asarray(moved, np.int32)
            for tree_name in ("caches", "states"):
                tree = getattr(self, tree_name)
                if tree is None:
                    continue
                hostcopy = jax.tree.map(
                    lambda a: np.asarray(a[:, idx]), tree)   # "send"
                setattr(self, tree_name, jax.tree.map(       # "receive"
                    lambda a, h: a.at[:, idx].set(jnp.asarray(h)),
                    tree, hostcopy))
        self.kv_bytes_moved += moved_bytes
        return moved_bytes


def _make_prefill(cfg: ModelConfig, chunk: int):
    """One compiled prefill-into-slot: run the whole prompt through the
    forward, write the slot's caches/state back in place, and return the
    argmax of the final-position logits — the request's FIRST output token
    (re-feeding the last prompt token would double-feed it; the old
    per-token admission loop had exactly that bug)."""

    def pre(params, caches, states, slot, toks):
        # toks: i32[1, S]; slot: traced scalar -> dynamic slice
        c1 = None if caches is None else jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            caches)
        s1 = None if states is None else jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            states)
        logits, c1, s1 = M.prefill_with_cache(params, toks, c1, s1,
                                              cfg=cfg, chunk=chunk)
        if caches is not None:
            caches = jax.tree.map(
                lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                    a, b.astype(a.dtype), slot, axis=1), caches, c1)
        if states is not None:
            states = jax.tree.map(
                lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                    a, b.astype(a.dtype), slot, axis=1), states, s1)
        return jnp.argmax(logits[0]).astype(jnp.int32), caches, states

    return jax.jit(pre, donate_argnums=(1, 2))


def _make_decode(cfg: ModelConfig, chunk: int):
    """One compiled decode round over a gathered slot batch.

    ``idx`` holds the active slots' ids (padded to the bucket size with
    ``n_slots`` — out of bounds, so gathers clamp harmlessly and the
    write-back scatter drops the pad lanes).  Per-lane positions via vmap:
    every lane attends/advances at its own depth, one executable per
    bucket size instead of one dispatch per request per tick."""

    def one(params, c, s, tok, pos):
        # one lane: c/s leaves have the slot axis stripped by vmap
        c1 = None if c is None else jax.tree.map(lambda a: a[:, None], c)
        s1 = None if s is None else jax.tree.map(lambda a: a[:, None], s)
        logits, c1, s1 = M.decode_step(params, c1, s1, tok[None], pos,
                                       cfg=cfg, chunk=chunk)
        c1 = None if c1 is None else jax.tree.map(lambda a: a[:, 0], c1)
        s1 = None if s1 is None else jax.tree.map(lambda a: a[:, 0], s1)
        return jnp.argmax(logits[0]).astype(jnp.int32), c1, s1

    vdec = jax.vmap(one, in_axes=(None, 1, 1, 0, 0), out_axes=(0, 1, 1))

    def step(params, caches, states, idx, tokens, pos):
        gc = None if caches is None else jax.tree.map(
            lambda a: a[:, idx], caches)
        gs = None if states is None else jax.tree.map(
            lambda a: a[:, idx], states)
        toks, nc, ns = vdec(params, gc, gs, tokens, pos)
        if caches is not None:
            caches = jax.tree.map(
                lambda a, b: a.at[:, idx].set(b.astype(a.dtype)), caches, nc)
        if states is not None:
            states = jax.tree.map(
                lambda a, b: a.at[:, idx].set(b.astype(a.dtype)), states, ns)
        return toks, caches, states

    return jax.jit(step, donate_argnums=(1, 2))


def _bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n (capped): bounds the compiled-shape count
    of the gathered decode to log2(n_slots) executables."""
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class ServingEngine:
    """Continuous batching driver over a SlotPool."""

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int,
                 max_seq: int, n_instances: int = 1, greedy: bool = True,
                 chunk: int = 1024):
        self.cfg, self.params = cfg, params
        self.pool = SlotPool(cfg, n_slots, max_seq, n_instances)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}
        self.greedy = greedy
        self._prefill = _make_prefill(cfg, chunk)
        self._decode = _make_decode(cfg, chunk)
        self.steps = 0
        self.tokens_out = 0
        self.requests_done = 0

    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self, done: List[Request]):
        pool = self.pool
        while self.waiting:
            slot = pool.alloc()
            if slot is None:
                return
            req = self.waiting.popleft()
            req.slot = slot
            req.admitted_step = self.steps
            assert len(req.prompt) + req.max_new <= pool.max_seq, (
                "request does not fit the slot sequence budget")
            with _obs.span("serve.prefill"):
                toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
                first, pool.caches, pool.states = self._prefill(
                    self.params, pool.caches, pool.states,
                    jnp.int32(slot), toks)
                first = int(first)
            pool.pos[slot] = len(req.prompt)
            req.out.append(first)
            self.tokens_out += 1
            if len(req.out) >= req.max_new:     # max_new == 1: done at admit
                self._finish(req, done)
            else:
                self.running[req.uid] = req

    def _finish(self, req: Request, done: List[Request]):
        req.finished_step = self.steps
        self.running.pop(req.uid, None)
        self.pool.release(req.slot)
        self.requests_done += 1
        done.append(req)

    def tick(self) -> List[Request]:
        """One decode round over all running requests; returns finished."""
        done: List[Request] = []
        self._admit(done)
        if self.running:
            pool = self.pool
            reqs = list(self.running.values())
            k = _bucket(len(reqs), pool.n_slots)
            idx = np.full((k,), pool.n_slots, np.int32)     # OOB pad lanes
            tokens = np.zeros((k,), np.int32)
            pos = np.zeros((k,), np.int32)
            for i, r in enumerate(reqs):
                idx[i] = r.slot
                tokens[i] = r.out[-1]
                pos[i] = pool.pos[r.slot]
            with _obs.span("serve.decode"):
                toks, pool.caches, pool.states = self._decode(
                    self.params, pool.caches, pool.states,
                    jnp.asarray(idx), jnp.asarray(tokens), jnp.asarray(pos))
                toks = np.asarray(toks)         # sync: latency is real
            for i, req in enumerate(reqs):
                req.out.append(int(toks[i]))
                pool.pos[req.slot] += 1
                self.tokens_out += 1
                if len(req.out) >= req.max_new:
                    self._finish(req, done)
        self.steps += 1
        return done

    # ---- elasticity -------------------------------------------------------
    def reconfigure(self, n_active: int, mode: str = "vsn"):
        """Apply a replica-count change as the paper's f_mu rewrite (VSN)
        or the SN migration baseline.  Returns (kv_bytes_moved, wall_ms)."""
        t0 = time.perf_counter()
        with _obs.span("serve.reconfig"):
            if mode == "vsn":
                self.pool.reconfigure_vsn(n_active)
                moved = 0
            elif mode == "sn":
                moved = self.pool.reconfigure_sn(n_active)
                jax.block_until_ready(
                    jax.tree.leaves((self.pool.caches, self.pool.states)))
            else:
                raise ValueError(f"unknown reconfig mode {mode!r}")
        ms = (time.perf_counter() - t0) * 1e3
        _obs.event("serve_reconfig", mode=mode, n_active=int(n_active),
                   kv_bytes_moved=int(moved), ms=ms)
        return moved, ms

    def inst_load(self) -> np.ndarray:
        """Active decode slots per instance under the current f_mu."""
        load = np.zeros((self.pool.n_instances,), np.int64)
        slots = [r.slot for r in self.running.values()]
        if slots:
            np.add.at(load, self.pool.fmu[np.asarray(slots)], 1)
        return load


def reference_decode(cfg: ModelConfig, params, prompt, max_new: int,
                     max_seq: int, chunk: int = 1024) -> List[int]:
    """Straight-line batch-1 greedy decode: fresh caches, one bulk prefill,
    then token-by-token.  The engine's per-request output must match this
    exactly — the contract the continuous-batching machinery is tested
    against."""
    caches, states = transformer.init_caches(cfg, 1, max_seq)
    toks_in = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, caches, states = M.prefill_with_cache(
        params, toks_in, caches, states, cfg=cfg, chunk=chunk)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_new:
        logits, caches, states = M.decode_step(
            params, caches, states, jnp.asarray([out[-1]], jnp.int32),
            jnp.int32(pos), cfg=cfg, chunk=chunk)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out
