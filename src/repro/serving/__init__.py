"""Elastic LLM serving tier on the VSN slot pool.

``kv_pool`` holds the continuous-batching engine + the slot pool whose
ownership table is the paper's ``f_mu``; ``stream`` promotes the engine
into the streaming stack (requests as tuples, ``AsyncStreamRuntime`` /
``IngestTier`` compatible pipeline, SLO-driven controller policy).
"""

from repro.serving.kv_pool import (Request, ServingEngine, SlotPool,
                                   reference_decode)
from repro.serving.stream import (RequestSource, ServingConfig,
                                  ServingPipeline, SloServingController,
                                  build_serving_pipeline)

__all__ = [
    "Request", "ServingEngine", "SlotPool", "reference_decode",
    "RequestSource", "ServingConfig", "ServingPipeline",
    "SloServingController", "build_serving_pipeline",
]
