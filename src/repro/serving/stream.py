"""The serving tier as a stream: requests are tuples, decode is the tick.

This is what promotes ``ServingEngine`` from a standalone sketch into the
stack the last five PRs built:

* ``RequestSource`` — a multi-tenant arrival process (Poisson draws against
  a ``RateSchedule``, so diurnal spikes are one schedule away) that encodes
  each request as a stream tuple: ``tau`` = arrival time (ms), payload =
  ``[uid, max_new, prompt_len, prompt...]``.  Every tick also carries one
  heartbeat lane per source (``uid = -1``) so the per-source watermark
  frontier keeps advancing through the hierarchical ScaleGate ingest tier
  even when a tenant is idle — requests can arrive through
  ``src/repro/ingest/`` unchanged.
* ``ServingPipeline`` — the ``AsyncStreamRuntime`` pipeline contract
  (``stage`` / ``step_staged`` / ``epoch``) over a ``ServingEngine``: a
  staged tick's valid lanes are admitted, one continuous-batching decode
  round runs, and an injected ``Reconfiguration`` is applied as the
  paper's ``f_mu`` rewrite (VSN: zero KV moved; ``mode="sn"`` materializes
  the migration baseline).  The epoch switch commits in the same tick —
  zero state transfer is exactly why.
* ``SloServingController`` — the SLO-aware policy: it reads the windowed
  p99 of the ``span.serve.decode`` registry histogram (the PR-8/9
  instruments) plus the runtime's queue depth from ``LiveMetrics``, and
  provisions the smallest replica count predicted to clear the target;
  SLO-engine breaches (``LiveMetrics.slo_breaches``) force a scale-up
  even when the raw signals look calm.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.core import tuples as T
from repro.core.controller import (Reconfiguration, active_mask,
                                   balanced_fmu)
from repro.io.sources import RateSchedule
from repro.obs.slo import _windowed_quantile
from repro.serving.kv_pool import Request, ServingEngine

META_COLS = 3          # payload layout: [uid, max_new, prompt_len, prompt..]
HEARTBEAT_UID = -1.0   # watermark-advancing lane; never admitted


# ------------------------------------------------------------- requests --

def encode_requests(reqs: List[Request], *, lanes: int, prompt_cap: int,
                    n_inputs: int, k_virt: int, tau: int) -> T.TupleBatch:
    """One tick: ``n_inputs`` heartbeat lanes + up to ``lanes`` requests.
    Token ids ride in the float payload (exact below 2**24, asserted)."""
    assert len(reqs) <= lanes
    b = n_inputs + lanes
    pay = np.zeros((b, META_COLS + prompt_cap), np.float32)
    pay[:, 0] = HEARTBEAT_UID
    keys = np.zeros((b, 1), np.int32)
    source = np.zeros((b,), np.int32)
    valid = np.zeros((b,), bool)
    source[:n_inputs] = np.arange(n_inputs)
    valid[:n_inputs] = True
    for i, r in enumerate(reqs):
        lane = n_inputs + i
        assert r.uid < (1 << 24) and len(r.prompt) <= prompt_cap
        assert int(np.max(r.prompt, initial=0)) < (1 << 24)
        pay[lane, 0] = r.uid
        pay[lane, 1] = r.max_new
        pay[lane, 2] = len(r.prompt)
        pay[lane, META_COLS:META_COLS + len(r.prompt)] = r.prompt
        keys[lane, 0] = r.uid % k_virt
        source[lane] = r.uid % n_inputs
        valid[lane] = True
    return T.make_batch(np.full((b,), tau, np.int32), pay, keys=keys,
                        source=source, valid=valid)


def decode_request_lanes(b: T.TupleBatch) -> List[Request]:
    """Valid non-heartbeat lanes of a (possibly tier-merged) tick back into
    ``Request``s."""
    ok = np.asarray(b.valid) & ~np.asarray(b.is_control)
    pay = np.asarray(b.payload)
    tau = np.asarray(b.tau)
    out: List[Request] = []
    for lane in np.nonzero(ok)[0]:
        uid = int(round(float(pay[lane, 0])))
        if uid < 0:
            continue                              # heartbeat
        p_len = int(round(float(pay[lane, 2])))
        prompt = np.rint(pay[lane, META_COLS:META_COLS + p_len]).astype(
            np.int32)
        out.append(Request(uid=uid, prompt=prompt,
                           max_new=int(round(float(pay[lane, 1]))),
                           arrived=int(tau[lane])))
    return out


class RequestSource:
    """Deterministic multi-tenant arrival process as a tick stream.

    Per tick, a Poisson draw against ``schedule.rate_at(tick)`` (requests/s
    over a ``tick_ms`` window) decides how many requests arrive; spill past
    the per-tick lane budget carries to the next tick (a spike backs up,
    exactly like a real front door).  After ``ticks`` arrival ticks,
    ``drain_ticks`` heartbeat-only ticks keep the watermark moving while
    in-flight requests finish.  Re-iterating restarts the same stream
    (seeded), which is what the async-vs-direct parity checks replay."""

    def __init__(self, *, schedule: RateSchedule, ticks: int,
                 lanes: int = 8, prompt_len: int = 4, max_new: int = 4,
                 vocab: int = 256, seed: int = 0, n_inputs: int = 1,
                 k_virt: int = 8, tick_ms: int = 50,
                 drain_ticks: int = 32, pace: bool = False):
        self.schedule = schedule
        self.ticks = ticks
        self.lanes = lanes
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.vocab = vocab
        self.seed = seed
        self.n_inputs = n_inputs
        self.k_virt = k_virt
        self.tick_ms = tick_ms
        self.drain_ticks = drain_ticks
        self.pace = pace
        self.total_requests = 0       # after one full iteration

    def rate_hint(self, tick: int) -> Optional[float]:
        return self.schedule.rate_at(tick)

    def __len__(self) -> int:
        return self.ticks + self.drain_ticks

    def __iter__(self) -> Iterator[T.TupleBatch]:
        rng = np.random.default_rng(self.seed)
        uid = 0
        backlog = 0
        next_emit = time.perf_counter()
        for i in range(self.ticks + self.drain_ticks):
            if self.pace:
                now = time.perf_counter()
                if now < next_emit:
                    time.sleep(next_emit - now)
                next_emit = max(now, next_emit) + self.tick_ms / 1e3
            reqs: List[Request] = []
            if i < self.ticks:
                lam = self.schedule.rate_at(i) * self.tick_ms / 1e3
                backlog += int(rng.poisson(lam))
                take = min(backlog, self.lanes)
                backlog -= take
                for _ in range(take):
                    reqs.append(Request(
                        uid=uid,
                        prompt=rng.integers(1, self.vocab, self.prompt_len),
                        max_new=self.max_new, arrived=i * self.tick_ms))
                    uid += 1
            yield encode_requests(reqs, lanes=self.lanes,
                                  prompt_cap=self.prompt_len,
                                  n_inputs=self.n_inputs,
                                  k_virt=self.k_virt, tau=i * self.tick_ms)
        self.total_requests = uid


# ------------------------------------------------------------- pipeline --

@dataclasses.dataclass(frozen=True)
class _ServingOp:
    """The slice of the operator contract the runtime reads."""
    n_inputs: int
    k_virt: int


class ServingPipeline:
    """``AsyncStreamRuntime``-compatible pipeline whose sigma is the KV
    slot pool.  ``epoch`` is the pool itself (``fmu`` + ``active`` are the
    live ownership tables); an injected ``Reconfiguration`` commits within
    the same tick — the zero-state-transfer switch is the whole point."""

    device_inst_load = True      # step returns inst_load; skip the host hist
    _sg_ready = False            # runtime seeds the frontier from zeros

    def __init__(self, engine: ServingEngine, *, n_inputs: int = 1,
                 mode: str = "vsn"):
        assert mode in ("vsn", "sn"), mode
        self.engine = engine
        self.mode = mode
        self.op = _ServingOp(n_inputs, engine.pool.n_slots)
        self.epoch = engine.pool
        self.finished: List[Request] = []
        self.reconfig_events: List[Dict[str, Any]] = []

    def stage(self, b: T.TupleBatch) -> T.TupleBatch:
        return jax.tree.map(jnp.asarray, b)

    def step_staged(self, staged: T.TupleBatch, reconfig=None,
                    frontier=None):
        eng = self.engine
        for r in decode_request_lanes(staged):
            eng.submit(r)
        switched = False
        if reconfig is not None:
            moved, ms = eng.reconfigure(int(reconfig.n_active),
                                        mode=self.mode)
            self.reconfig_events.append(dict(
                n_active=int(reconfig.n_active), kv_bytes_moved=int(moved),
                ms=ms, epoch=int(reconfig.epoch)))
            switched = True          # the f_mu rewrite commits immediately
        done = eng.tick()
        self.finished.extend(done)
        uids = np.asarray([r.uid for r in done], np.int32)
        toks = (np.full((len(done), max((len(r.out) for r in done),
                                        default=0)), -1, np.int32))
        for i, r in enumerate(done):
            toks[i, :len(r.out)] = r.out
        return uids, toks, np.bool_(switched), eng.inst_load()

    def import_state(self, tree):
        raise NotImplementedError(
            "serving tier has no checkpoint/restore support yet")


@dataclasses.dataclass
class ServingConfig:
    """JSON-serializable description of the serving pipeline (rides inside
    ``RuntimeConfig.serving``)."""
    arch: str = "qwen3-14b"
    reduced: bool = True
    n_slots: int = 8
    max_seq: int = 64
    n_instances: int = 4
    mode: str = "vsn"            # reconfiguration mode: vsn | sn baseline
    seed: int = 0
    chunk: int = 1024

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def build_serving_pipeline(scfg: ServingConfig, *, n_inputs: int = 1,
                           n_active: int = 1) -> ServingPipeline:
    from repro.configs import canon, get_config, reduced
    from repro.models import transformer
    mcfg = get_config(canon(scfg.arch))
    if scfg.reduced:
        mcfg = reduced(mcfg)
    params = transformer.init_params(jax.random.PRNGKey(scfg.seed), mcfg)
    eng = ServingEngine(mcfg, params, n_slots=scfg.n_slots,
                        max_seq=scfg.max_seq,
                        n_instances=scfg.n_instances, chunk=scfg.chunk)
    eng.pool.reconfigure_vsn(n_active)
    return ServingPipeline(eng, n_inputs=n_inputs, mode=scfg.mode)


# ----------------------------------------------------------- controller --

@dataclasses.dataclass
class SloServingController:
    """SLO-aware replica policy: windowed p99 decode latency (read straight
    from the ``span.serve.decode`` registry histogram) + in-flight queue
    depth -> replica count, emitted as the paper's f_mu rewrite.

    Scale-up: p99 over target, queue nearly full, or a fresh SLO-engine
    breach (direct evidence the objective is missed).  The provision sizes
    by the overshoot ratio — smallest count predicted to clear the target,
    §8.4 shape.  Scale-down: p99 well under target AND an empty queue.
    ``cooldown`` decisions must pass between changes so one spike doesn't
    ring."""
    n_max: int
    k_virt: int
    target_p99_ms: float = 50.0
    low_p99_ms: Optional[float] = None
    metric: str = "span.serve.decode"
    window_s: float = 10.0
    min_count: int = 8
    cooldown: int = 4
    n_active: int = 1
    epoch: int = 0
    slo_breaches_seen: int = 0

    def __post_init__(self):
        if self.low_p99_ms is None:
            self.low_p99_ms = self.target_p99_ms / 4.0
        self._win: deque = deque()      # (t, counts, count) sketch baseline
        self._since = self.cooldown     # decisions since the last change
        self._decisions = 0

    # -- signal -------------------------------------------------------------
    def _windowed_p99_s(self) -> Optional[float]:
        """Windowed p99 over the registry sketch's bucket-count deltas
        (the PR-9 SLO-engine evaluation shape), None while the metric is
        absent or under ``min_count`` observations."""
        o = _obs.get()
        h = None if o is None else o.registry.histograms.get(self.metric)
        if h is None or h.count == 0:
            return None
        t = time.time()
        self._win.append((t, list(h.counts), h.count))
        while len(self._win) > 2 and t - self._win[1][0] > self.window_s:
            self._win.popleft()
        base_t, base_counts, base_count = self._win[0]
        n = h.count - base_count
        if len(self._win) == 1 or t - base_t > 4 * self.window_s:
            base_counts = [0] * len(h.counts)
            n = h.count
        if n < self.min_count:
            return None
        deltas = [c - b for c, b in zip(h.counts, base_counts)]
        return _windowed_quantile(deltas, n, 0.99)

    # -- policy -------------------------------------------------------------
    def observe_live(self, m) -> Optional[Reconfiguration]:
        self._decisions += 1
        self._since += 1
        if m.slo_breaches:
            self.slo_breaches_seen += len(m.slo_breaches)
        p99_s = self._windowed_p99_s()
        if p99_s is None:
            # tracing off (no span histogram): the bus's tick latency is
            # the fallback signal, gated by the same warmup count
            if self._decisions < self.min_count:
                return None
            p99_s = m.tick_latency_s
        p99_ms = p99_s * 1e3
        qr = (m.queue_depth / m.queue_cap) if m.queue_cap else 0.0
        desired = self.n_active
        if p99_ms > self.target_p99_ms or qr >= 0.75 or m.slo_breaches:
            over = max(p99_ms / self.target_p99_ms, 1.0)
            desired = min(self.n_max,
                          max(self.n_active + 1,
                              int(np.ceil(self.n_active * (over + qr)))))
        elif p99_ms < self.low_p99_ms and m.queue_depth == 0:
            desired = max(1, self.n_active - 1)
        if desired == self.n_active or self._since < self.cooldown:
            return None
        self._since = 0
        self.n_active = desired
        self.epoch += 1
        _obs.event("controller_decide", policy="slo", p99_ms=p99_ms,
                   queue_depth=m.queue_depth, epoch=int(self.epoch),
                   n_active=int(desired),
                   breaches=len(m.slo_breaches))
        return Reconfiguration(
            epoch=self.epoch, n_active=desired,
            fmu=balanced_fmu(self.k_virt, desired, self.n_max),
            active=active_mask(desired, self.n_max))
