"""Ingest-tier driver: the hierarchical multi-host ScaleGate, standalone.

    PYTHONPATH=src python -m repro.launch.ingest_tier --leaves 3 \
        --sources 6 --ticks 24 --join-at 6 --leave-at 14

Streams a multi-source Q1-style workload through ``repro.ingest.IngestTier``
(N leaf ScaleGates, each an ingest worker merging a disjoint source subset,
feeding the root merge) and verifies, live:

* exact output-set parity with the single-ScaleGate oracle — including
  across a mid-stream ``add_host`` (``--join-at``) and ``remove_host``
  (``--leave-at``);
* the merged ready stream is totally ordered and the root watermark never
  regresses (checked every round inside ``RootMerge``);
* membership changes move zero tuple state — only Lemma-3 gammas — with
  measured attach/detach latency;
* stash overflow at either level is surfaced, never silent.

``--worker`` selects the leaf execution vehicle (thread | process |
inline); ``--pipeline`` additionally drives the merged stream through a
``VSNPipeline`` via ``AsyncStreamRuntime`` (the tier as a drop-in live
source upstream of ``stage()``).
"""

import argparse
import sys
import time

import numpy as np

from repro.data import datagen
from repro.ingest import (IngestTier, collect_tuples, emitted_taus,
                          single_gate_stream)

K_VIRT = 128


def make_stream(args):
    rng = np.random.default_rng(args.seed)
    return list(datagen.tweets(
        rng, n_ticks=args.ticks, tick=args.tick, words_per_tweet=3,
        vocab=2000, k_virt=K_VIRT, rate_per_tick=50,
        n_sources=args.sources))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--leaves", type=int, default=3)
    ap.add_argument("--sources", type=int, default=6)
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--tick", type=int, default=64, help="tuples per tick")
    ap.add_argument("--worker", default="thread",
                    choices=["thread", "process", "inline"])
    ap.add_argument("--leaf-cap", type=int, default=128)
    ap.add_argument("--root-cap", type=int, default=256)
    ap.add_argument("--join-at", type=int, default=None,
                    help="add an ingest host before this data tick")
    ap.add_argument("--leave-at", type=int, default=None,
                    help="remove leaf 0 before this data tick")
    ap.add_argument("--pipeline", action="store_true",
                    help="also drive the merged stream through a "
                         "VSNPipeline via AsyncStreamRuntime")
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args(argv)

    batches = make_stream(args)
    tier = IngestTier(batches, args.sources, args.leaves,
                      worker=args.worker, leaf_cap=args.leaf_cap,
                      root_cap=args.root_cap, record=args.pipeline)
    if args.join_at is not None:
        new_leaf = tier.add_host(at_tick=args.join_at)
        print(f"# scheduled add_host -> leaf {new_leaf} at tick "
              f"{args.join_at}")
    if args.leave_at is not None:
        tier.remove_host(0, at_tick=args.leave_at)
        print(f"# scheduled remove_host(0) at tick {args.leave_at}")

    t0 = time.perf_counter()
    outs = list(tier)
    dt = time.perf_counter() - t0
    st = tier.stats()
    print(f"[ingest] {st.summary()}")
    print(f"[ingest] root-merge throughput {st.tuples_out / max(dt, 1e-9):.0f} t/s "
          f"over {dt:.2f}s ({args.worker} workers)")

    taus = emitted_taus(outs)
    assert (np.diff(taus) >= 0).all(), "ready stream lost total order"
    oracle = single_gate_stream(batches, args.sources,
                                cap=args.root_cap + args.leaf_cap)
    same = collect_tuples(outs) == collect_tuples(oracle)
    print(f"[ingest] output set == single-ScaleGate oracle: {same} "
          f"({st.tuples_out} tuples, watermark monotone, "
          f"{len(st.attach_ms)} joins / {len(st.detach_ms)} leaves)")
    assert same, "hierarchical ingest diverged from the flat oracle"

    if args.pipeline:
        from repro.core.aggregate import count_aggregate
        from repro.core.async_runtime import AsyncStreamRuntime
        from repro.core.runtime import VSNPipeline
        from repro.core.windows import WindowSpec

        op = count_aggregate(WindowSpec(wa=500, ws=1000, wt="multi"),
                             k_virt=K_VIRT, out_cap=1024, extra_slots=2,
                             n_inputs=args.sources)
        pipe = VSNPipeline(op, n_max=8, n_active=4,
                           stash_cap=args.root_cap + args.leaf_cap)
        tier2 = IngestTier(batches, args.sources, args.leaves,
                           worker=args.worker, leaf_cap=args.leaf_cap,
                           root_cap=args.root_cap, out_pad=2 * args.tick)
        rt = AsyncStreamRuntime(pipe, tier2, queue_cap=4)
        rep = rt.run()
        print(f"[ingest->pipeline] {rep.summary()}")
    print("ingest tier OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
