"""Meshes + compiled-HLO collective accounting.

Production meshes are never built at import (jax device state stays cold).
``make_stream_mesh`` is the 1-D instance-axis mesh the VSN runtime shards
key blocks over (core.runtime.MeshPipeline); on a laptop/CI host emulate
devices with

    XLA_FLAGS=--xla_force_host_platform_device_count=8

set *before* the first jax import (see tests/test_mesh_runtime.py and the
``multi-device`` CI job).

``collective_bytes`` parses a compiled HLO text and sums the output bytes
of every cross-device collective — the zero-state-transfer witness for the
mesh VSN step (Theorem 3: an ``f_mu`` switch moves tables, never sigma).
"""

from __future__ import annotations

import re

import jax

STREAM_AXIS = "i"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices for tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_stream_mesh(n_shards: int = None, axis: str = STREAM_AXIS):
    """1-D mesh over ``n_shards`` local devices for the VSN instance axis
    (defaults to every visible device)."""
    n_shards = n_shards or len(jax.devices())
    avail = len(jax.devices())
    if n_shards > avail:
        raise ValueError(
            f"mesh wants {n_shards} devices but only {avail} are visible; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n_shards} "
            f"before the first jax import to emulate them on CPU")
    return jax.make_mesh((n_shards,), (axis,))


# ---------------------------------------------------------------------------
# Compiled-HLO collective accounting
# ---------------------------------------------------------------------------

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*([a-z0-9]+)\[([0-9,]*)\]", re.I)

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the compiled HLO."""
    per_kind = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        kind = m.group(1).lower().removesuffix("-start")
        dt = m.group(2)
        dims = [int(x) for x in m.group(3).split(",") if x]
        n = 1
        for d in dims:
            n *= d
        b = n * DTYPE_BYTES.get(dt, 4)
        per_kind[kind] = per_kind.get(kind, 0) + b
    return per_kind


# host-boundary crossings inside a compiled program: send/recv pairs marked
# as host transfers, infeed/outfeed queues, and host-callback custom-calls
# (io_callback / pure_callback / debug prints all lower to one of these).
HOST_TRANSFER_RE = re.compile(
    r"is_host_transfer=true"
    r"|\b(?:infeed|outfeed)(?:-done|-start)?\("
    r"|custom_call_target=\"[^\"]*callback[^\"]*\"", re.I)


def host_transfer_ops(hlo_text: str):
    """HLO lines that move data across the host boundary *inside* the
    compiled program — the device-residency witness for the persistent
    K-tick drivers (``runtime.*.persistent_hlo``): an empty list proves the
    scan's data lane never leaves the device between ticks (arguments and
    results don't count; they cross once per call by definition)."""
    return [ln.strip() for ln in hlo_text.splitlines()
            if HOST_TRANSFER_RE.search(ln)]
