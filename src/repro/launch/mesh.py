"""Production meshes (never built at import: jax device state stays cold)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices for tests."""
    return jax.make_mesh((data, model), ("data", "model"))
