"""Serving driver: continuous batching on the VSN slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
        --requests 6 --max-new 8

Loads (or random-inits) weights, streams synthetic requests through the
ServingEngine, and exercises one elastic scale-up mid-run (zero KV moved).
"""

import argparse
import sys
import time

import numpy as np
import jax

from repro.configs import canon, get_config, reduced
from repro.models import transformer
from repro.serving.kv_pool import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(canon(args.arch))
    if args.reduced:
        cfg = reduced(cfg)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=args.slots,
                        max_seq=args.max_seq, n_instances=4)
    eng.pool.reconfigure_vsn(2)

    rng = np.random.default_rng(0)
    # one monotonic clock for everything: arrival taus are milliseconds
    # since t0 (not request ids), and tok/s is measured over the decode
    # loop only — model/engine init and submission stay out of the window.
    t0 = time.perf_counter()
    for uid in range(args.requests):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, cfg.vocab, 4),
                           max_new=args.max_new,
                           arrived=int((time.perf_counter() - t0) * 1000)))
    done = []
    t_serve = time.perf_counter()
    while len(done) < args.requests and eng.steps < 200:
        done += eng.tick()
        if eng.steps == 2:
            moved = eng.pool.reconfigure_vsn(4)
            print(f"scaled 2->4 replicas mid-decode, {moved} B moved",
                  flush=True)
    dt = time.perf_counter() - t_serve
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, "
          f"{toks / max(dt, 1e-9):.1f} tok/s (decode loop, init excluded)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
