"""Serving driver: the elastic continuous-batching tier on the facade.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
        --ticks 40 --rate 40 --spike 160 --controller slo

One ``RuntimeConfig`` describes the whole stack — requests arrive as
stream tuples from a diurnal-spike ``RateSchedule`` arrival process
(optionally through the multi-host ingest tier with ``--ingest-hosts``),
decode runs as the tick of an ``AsyncStreamRuntime``, and the SLO-aware
controller provisions replicas from the observed p99 decode latency.
Scale-up under ``--mode vsn`` is the paper's f_mu rewrite (zero KV
moved); ``--mode sn`` materializes the shared-nothing migration baseline
for comparison.
"""

import argparse
import sys

from repro.api import RuntimeConfig, build_runtime
from repro.io.sources import RateSchedule
from repro.serving import RequestSource, ServingConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--n-active", type=int, default=1)
    ap.add_argument("--mode", choices=("vsn", "sn"), default="vsn")
    # traffic: piecewise-constant req/s with a diurnal spike in the middle
    ap.add_argument("--rate", type=float, default=40.0,
                    help="baseline arrival rate, requests/s")
    ap.add_argument("--spike", type=float, default=0.0,
                    help="mid-run spike rate (0 = flat traffic)")
    ap.add_argument("--ticks", type=int, default=40)
    ap.add_argument("--tick-ms", type=int, default=50)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--pace", action="store_true",
                    help="pace ticks in wall-clock time")
    # stack
    ap.add_argument("--sources", type=int, default=2)
    ap.add_argument("--ingest-hosts", type=int, default=0)
    ap.add_argument("--controller", default="slo",
                    choices=("none", "slo"))
    ap.add_argument("--slo-target-ms", type=float, default=50.0)
    ap.add_argument("--trace", action="store_true")
    ap.add_argument("--export-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    phases = [(0, args.rate)]
    if args.spike > 0:
        phases = [(0, args.rate), (args.ticks // 3, args.spike),
                  (2 * args.ticks // 3, args.rate)]
    schedule = RateSchedule(phases)

    scfg = ServingConfig(arch=args.arch, reduced=args.reduced,
                         n_slots=args.slots, max_seq=args.max_seq,
                         n_instances=args.instances, mode=args.mode,
                         seed=args.seed)
    cfg = RuntimeConfig(
        serving=scfg, n_sources=args.sources,
        ingest_hosts=args.ingest_hosts, n_active=args.n_active,
        controller=args.controller,
        slo_target_p99_ms=args.slo_target_ms,
        obs={"enabled": True, "trace": args.trace,
             "export_dir": args.export_dir,
             "slo_rules": [{"name": "decode_p99",
                            "metric": "span.serve.decode",
                            "threshold": args.slo_target_ms / 1e3,
                            "quantile": 0.99}]})

    source = RequestSource(
        schedule=schedule, ticks=args.ticks, lanes=args.lanes,
        prompt_len=args.prompt_len, max_new=args.max_new,
        seed=args.seed, n_inputs=args.sources, k_virt=args.slots,
        tick_ms=args.tick_ms, pace=args.pace,
        # worst-case drain: every lane full every tick, n_slots requests
        # retiring per (max_new-1) decode rounds
        drain_ticks=(args.ticks * args.lanes * args.max_new
                     // args.slots + 16))

    rt = build_runtime(cfg, source)
    report = rt.run()
    pipe = rt.pipeline
    eng = pipe.engine

    print(report.summary())
    toks = sum(len(r.out) for r in pipe.finished)
    print(f"served {len(pipe.finished)}/{source.total_requests} requests, "
          f"{toks} tokens over {eng.steps} decode rounds "
          f"({args.mode} mode, {eng.pool.n_active}/{args.instances} "
          f"replicas at end)")
    for ev in pipe.reconfig_events:
        print(f"  reconfig -> n_active={ev['n_active']} "
              f"kv_bytes_moved={ev['kv_bytes_moved']} "
              f"({ev['ms']:.2f} ms)")
    if not pipe.reconfig_events:
        print("  (no reconfigurations)")
    return 0 if len(pipe.finished) == source.total_requests else 1


if __name__ == "__main__":
    sys.exit(main())
