"""Elasticity / fault drill (§8.4-§8.5 at the runtime level + serving pool).

Demonstrates, end to end, on one host:
  1. a straggler instance is drained by an f_mu epoch switch (work remap,
     zero state transfer) and the stream's outputs stay exactly correct;
  2. the serving slot pool scales replicas with zero KV movement while the
     SN baseline ships GBs (scaled down here);
  3. a crash between checkpoints resumes from the last manifest
     (storage-substrate level);
  4. the full kill-and-restore loop: a checkpointing run dies mid-stream,
     is rebuilt from the manifest-carried ``RuntimeConfig``, restores the
     latest complete snapshot (a planted torn save is invisible), replays
     the recorded stream from the snapshot frontier, and the merged output
     multiset equals the uninterrupted oracle tuple for tuple —
     detection→recovered latency is measured (``repro.launch.recovery``).

    PYTHONPATH=src python -m repro.launch.elastic_drill

Pipelines, tiers, and runtimes are built through ``repro.api``
(``RuntimeConfig`` + ``build_runtime``) — the same path the checkpoint
manifests serialize.

``--mesh N`` additionally (or with ``--drills mesh``, exclusively) runs
drill 1 on an N-device mesh: the epoch switch happens mid-stream on real
devices, outputs stay identical to the single-device run, and the compiled
step's HLO contains zero cross-device collectives.  Emulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--live`` (or ``--drills live``) runs the closed loop end to end: the
async runtime streams a rate trace whose spike makes the
``ThresholdController`` provision mid-stream, the ``Reconfiguration`` is
injected live through the control-tuple path, detection→switch latency is
measured, and the output set must exactly match the static max-width
oracle.

``--drills ingest`` drills the hierarchical multi-host ScaleGate: an
ingest host joins mid-stream and another leaves, both with zero
tuple-state transfer, attach/detach latency is measured, and the tier's
merged output must exactly equal the single-ScaleGate oracle.

``--drills recovery-kill`` runs drill 4 with real process-worker ingest
leaves and a SIGKILL (unplanned host loss; slower — each leaf is a spawned
process that initializes its own jax).
"""

import argparse
import dataclasses
import sys
import tempfile

import numpy as np
import jax

from repro import api
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.elastic import vsn_switch_bytes


def collect(outs):
    res = []
    tau, pay, val = (np.asarray(outs.tau), np.asarray(outs.payload),
                     np.asarray(outs.valid))
    for j in range(tau.shape[0]):
        res += [(int(t), tuple(np.round(p, 3))) for t, p, ok in
                zip(tau[j], pay[j], val[j]) if ok]
    return sorted(res)


def base_cfg(k: int) -> api.RuntimeConfig:
    return api.RuntimeConfig(op="count", wa=50, ws=100, wt="multi",
                             k_virt=k, out_cap=512, n_max=8, n_active=4,
                             stash_cap=64)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, default=0,
                    help="also run the straggler drill on an N-device mesh")
    ap.add_argument("--live", action="store_true",
                    help="also run the closed-loop live-runtime drill")
    ap.add_argument("--drills", default="straggler,serving,crash,recovery",
                    help="comma list of straggler,mesh,live,ingest,"
                         "serving,crash,recovery,recovery-kill")
    ap.add_argument("--obs-dump", default=None, metavar="DIR",
                    help="install the observability layer and dump the "
                         "flight-recorder ring + metrics snapshot into DIR "
                         "— on drill failure AND at clean exit (the CI "
                         "chaos job uploads DIR as an artifact)")
    args = ap.parse_args(argv)
    drills = {d.strip() for d in args.drills.split(",")}
    if args.mesh:
        drills.add("mesh")
    if args.live:
        drills.add("live")

    if not args.obs_dump:
        return run_drills(args, drills)

    from repro import obs as _obs
    # fully-instrumented drills: tracing, sampled exemplar tuple timelines,
    # and a deliberately-unmeetable tick-latency SLO (threshold 1 us) so
    # the breach -> controller.observe_live -> flight-dump loop is
    # exercised (and asserted) on every CI run of the live drill
    o = _obs.install(_obs.ObsConfig(
        enabled=True, trace=True, dump_dir=args.obs_dump,
        exemplar_rate=1.0 / 8.0,
        slo_rules=[dict(name="tick_p99", metric="bus.tick_latency_s",
                        threshold=1e-6, quantile=0.99, window_s=30.0,
                        min_count=4, cooldown_s=0.5)]))
    try:
        rc = run_drills(args, drills)
    except BaseException as e:
        # the runtime layers may have dumped already (runtime_crash /
        # ingest_error paths); this catches failures outside them —
        # drill-level assertion failures included
        o.dump_flight(reason=f"drill_failure: {e!r}")
        o.export(args.obs_dump)
        raise
    o.export(args.obs_dump)
    path = o.dump_flight(reason="drill_complete")
    print(f"[obs] metrics + flight ring dumped to {args.obs_dump} "
          f"({path})")
    return rc


def run_drills(args, drills):
    k = 64
    from repro.data import datagen

    def drain_reconfig():
        # instance 2 is slow: remap its keys to the others.  No
        # sigma row moves; only the f_mu table changes.
        fmu = balanced_fmu(k, 3, 8)
        fmu = np.where(fmu >= 2, fmu + 1, fmu).astype(np.int32)
        active = active_mask(4, 8)
        active[2] = False
        return Reconfiguration(epoch=1, n_active=3, fmu=fmu, active=active)

    def stream():
        rng = np.random.default_rng(0)
        return datagen.tweets(rng, n_ticks=6, tick=32, words_per_tweet=3,
                              vocab=500, k_virt=k, rate_per_tick=30)

    def run(drain_straggler: bool):
        pipe = api.make_pipeline(base_cfg(k))
        outs = []
        for i, b in enumerate(stream()):
            rc = drain_reconfig() if drain_straggler and i == 2 else None
            o1, o2, sw = pipe.step(b, reconfig=rc)
            outs += collect(o1) + collect(o2)
        return outs, pipe

    base = None
    if "straggler" in drills or "mesh" in drills:
        base, _ = run(False)
    if "straggler" in drills:
        drained, pipe = run(True)
        same = base == drained
        print(f"[1] straggler drain: outputs identical={same}, "
              f"switch bytes={vsn_switch_bytes(pipe.epoch)} "
              f"(vs sigma = {sum(l.nbytes for l in jax.tree.leaves(pipe.sigma))}"
              f" bytes that SN would reshard)")
        assert same

    if "mesh" in drills:
        n = args.mesh or min(len(jax.devices()), 8)
        if len(jax.devices()) < n:
            print(f"[1m] mesh drill SKIP: needs {n} devices, have "
                  f"{len(jax.devices())} (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={n})")
        else:
            # same config, mesh execution — the api picks MeshPipeline
            pipe = api.make_pipeline(
                dataclasses.replace(base_cfg(k), mesh_devices=n))
            outs = []
            for i, b in enumerate(stream()):
                rc = drain_reconfig() if i == 2 else None
                o1, o2, sw = pipe.step(b, reconfig=rc)
                outs += collect(o1) + collect(o2)
            same = sorted(outs) == sorted(base)
            coll = pipe.collective_bytes()
            sigma_bytes = sum(l.nbytes for l in jax.tree.leaves(pipe.sigma))
            print(f"[1m] mesh straggler drain on {n} devices: outputs "
                  f"identical={same}, reconfigs={int(pipe.epoch.reconfigs)}, "
                  f"cross-device state transfer={sum(coll.values())} B "
                  f"(HLO collectives: {coll or 'none'}), switch "
                  f"bytes={pipe.switch_bytes()} (tables) vs {sigma_bytes} B "
                  f"of sigma that SN would reshard")
            assert same, "mesh run diverged from single-device oracle"
            assert int(pipe.epoch.reconfigs) == 1
            assert sum(coll.values()) == 0, "state moved between devices"

    # --- live closed loop --------------------------------------------------
    if "live" in drills:
        from repro.core.async_runtime import run_sync
        from repro.io import RateSchedule, ReplaySource

        live_batches = list(datagen.tweets(
            np.random.default_rng(1), n_ticks=8, tick=64,
            words_per_tweet=3, vocab=500, k_virt=k, rate_per_tick=30))
        # offered-rate spike at tick 3 pushes load past the §8.4 upper
        # threshold: 2 instances x 2000 t/s capacity, 9000 t/s offered.
        sched = RateSchedule(((3, 1500.0), (5, 9000.0)))
        live_cfg = dataclasses.replace(
            base_cfg(k), n_active=2, stash_cap=128, queue_cap=3,
            controller="threshold", capacity_per_instance=2000.0)
        rt = api.build_runtime(live_cfg,
                               ReplaySource(live_batches, schedule=sched))
        rep = rt.run()
        static = api.make_pipeline(
            dataclasses.replace(live_cfg, n_active=8))
        _, oracle_sink = run_sync(static, ReplaySource(live_batches))
        same = rt.sink.results() == oracle_sink.results()
        d2s = (f"{np.mean(rep.detect_to_switch_ms):.1f} ms / "
               f"{np.mean(rep.detect_to_switch_ticks):.1f} ticks"
               if rep.detect_to_switch_ms else "n/a")
        print(f"[4] live loop: {len(rep.reconfig_trace)} controller "
              f"reconfigs ({rep.switches} switched) injected mid-stream, "
              f"outputs match static oracle={same}, detection->switch "
              f"latency {d2s}, queue high-water {rep.queue_high_water}")
        assert rep.switches >= 1, "the rate spike never triggered a switch"
        assert same, "live elastic run diverged from the static oracle"
        from repro import obs as _obs
        o = _obs.get()
        if o is not None and o.slo is not None:
            # the SLO loop must demonstrably close: breach events reach
            # the controller, land in the report, and trigger a dump
            ctrl = rt.runtime.controller
            n_seen = getattr(ctrl, "slo_breaches_seen", 0)
            assert n_seen >= 1, "SLO breach never reached observe_live"
            assert rep.slo_breaches, "SLO breaches missing from RunReport"
            if o.cfg.dump_dir:
                import glob
                import os
                dumps = glob.glob(os.path.join(o.cfg.dump_dir,
                                               "flight-slo-*.json"))
                assert dumps, "SLO breach produced no flight dump"
            print(f"[4] SLO loop: {len(rep.slo_breaches)} breach(es) of "
                  f"{rep.slo_breaches[0]['rule']} fed observe_live "
                  f"(controller saw {n_seen}) and triggered a flight dump")
        if o is not None and o.timeline is not None:
            tls = rep.exemplar_timelines
            assert tls, "exemplar sampling produced no completed timelines"
            for tl in tls:
                walls = [w for _, w in tl["timeline"]]
                assert walls == sorted(walls), \
                    f"exemplar timeline not monotone: {tl}"
            print(f"[4] exemplars: {len(tls)} completed tuple timelines, "
                  f"all stage orders monotone")

    # --- hierarchical multi-host ingest ------------------------------------
    if "ingest" in drills:
        from repro.ingest import (collect_tuples, emitted_taus,
                                  single_gate_stream)

        n_src, n_leaves = 6, 2
        ingest_batches = list(datagen.tweets(
            np.random.default_rng(5), n_ticks=10, tick=64,
            words_per_tweet=3, vocab=500, k_virt=k, rate_per_tick=40,
            n_sources=n_src))
        tier_cfg = dataclasses.replace(
            base_cfg(k), n_sources=n_src, ingest_hosts=n_leaves,
            leaf_cap=64, root_cap=128)

        def ingest_run():
            tier = api.make_tier(tier_cfg, ingest_batches)
            new_leaf = tier.add_host(at_tick=3)  # host joins mid-stream
            tier.remove_host(0, at_tick=7)       # ...and one leaves
            return tier, new_leaf, list(tier)

        # two identical runs: the first compiles every jit shape, so the
        # second's attach/detach latency is the membership handshake
        # itself (gammas + table swaps), not XLA warmup
        ingest_run()
        tier, new_leaf, outs = ingest_run()
        st = tier.stats()
        taus = emitted_taus(outs)
        ordered = bool((np.diff(taus) >= 0).all())
        oracle = single_gate_stream(ingest_batches, n_src, cap=192)
        same = collect_tuples(outs) == collect_tuples(oracle)
        att = f"{st.attach_ms[0]:.1f}" if st.attach_ms else "n/a"
        det = f"{st.detach_ms[0]:.1f}" if st.detach_ms else "n/a"
        print(f"[5] ingest tier: leaf {new_leaf} joined @t3, leaf 0 left "
              f"@t7 (zero tuple-state transfer); outputs == single-gate "
              f"oracle: {same}, totally ordered: {ordered}, "
              f"W monotone (checked/round), attach {att} ms, detach "
              f"{det} ms (warm), overflow root={st.root_overflow} "
              f"leaves={sum(st.leaf_overflow.values())}")
        assert same, "ingest tier diverged from the single-gate oracle"
        assert ordered, "ingest tier lost total order"
        assert st.attach_ms and st.detach_ms, "membership latency missing"

    # --- serving pool ------------------------------------------------------
    if "serving" in drills:
        from repro.configs import get_config, reduced
        from repro.models import transformer
        from repro.serving.kv_pool import Request, ServingEngine
        cfg = reduced(get_config("qwen3_14b"))
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(cfg, params, n_slots=4, max_seq=64, n_instances=4)
        eng.submit(Request(uid=0, prompt=np.asarray([5, 6, 7]), max_new=4,
                           arrived=0))
        eng.tick()
        v = eng.pool.reconfigure_vsn(2)
        s = eng.pool.reconfigure_sn(4)
        print(f"[2] serving scale 4->2->4: VSN moved {v} B (tables), "
              f"SN baseline moved {s} B of KV")
        assert s > 10 * v

    # --- crash/resume (storage substrate) ----------------------------------
    if "crash" in drills:
        import os
        from repro.checkpoint import checkpoint as C
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 10, {"w": np.ones(4)}, async_=False)
            os.makedirs(os.path.join(d, "step_00000011"))   # crashed save
            step = C.latest_step(d)
            print(f"[3] crash drill: latest complete step = {step} (11 is "
                  f"invisible)")
            assert step == 10

    # --- kill-and-restore (full stack) --------------------------------------
    if "recovery" in drills or "recovery-kill" in drills:
        from repro.launch.recovery import kill_restore_drill

        n_src = 4
        rng = np.random.default_rng(7)
        rec_batches = []
        tau_base = 0
        for _ in range(12):
            (b,) = datagen.tweets(rng, n_ticks=1, tick=64,
                                  words_per_tweet=3, vocab=500, k_virt=k,
                                  rate_per_tick=30, n_sources=n_src)
            b = dataclasses.replace(b, tau=b.tau + tau_base)
            tau_base = int(np.asarray(b.tau).max()) + 1
            rec_batches.append(b)

        if "recovery" in drills:
            with tempfile.TemporaryDirectory() as d:
                cfg = dataclasses.replace(
                    base_cfg(k), n_active=2, stash_cap=256,
                    n_sources=n_src, ingest_hosts=2, leaf_cap=128,
                    root_cap=256, checkpoint_dir=d, checkpoint_every=4)
                rep = kill_restore_drill(cfg, rec_batches, mode="stop",
                                         crash_after=7,
                                         crash_mid_save=True)
                print(f"[6] kill-and-restore ({rep.summary()}); torn save "
                      f"was invisible, outputs exactly-once")
                assert rep.parity, "recovery drill lost exactly-once parity"
                assert rep.restored_step >= cfg.checkpoint_every

        if "recovery-kill" in drills:
            with tempfile.TemporaryDirectory() as d:
                cfg = dataclasses.replace(
                    base_cfg(k), n_active=2, stash_cap=256,
                    n_sources=n_src, ingest_hosts=2,
                    ingest_worker="process", chan_cap=2, leaf_cap=128,
                    root_cap=256, checkpoint_dir=d, checkpoint_every=4)
                rep = kill_restore_drill(cfg, rec_batches, mode="sigkill",
                                         crash_after=6)
                print(f"[6k] SIGKILL leaf restore ({rep.summary()})")
                assert rep.parity, "sigkill drill lost exactly-once parity"

    print("elastic drill OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
