import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices build the production meshes; every cell's step function
must lower and compile with the production shardings, and we extract
``memory_analysis`` / ``cost_analysis`` / the HLO collective schedule for
EXPERIMENTS.md §Dry-run and the §Roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both] [--json out.json]
"""

import argparse
import functools
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, canon, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as M, sharding as shd, transformer
from repro.models.config import ModelConfig
from repro.optim import adamw

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (DESIGN.md §Arch-applicability); full-attention archs record the skip.
LONG_OK_KINDS = ("rwkv", "hybrid")


def input_specs(cfg: ModelConfig, shape: dict):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape["batch"], shape["seq"]
    if shape["kind"] == "train":
        return M.make_train_batch_shapes(cfg, b, s)
    if shape["kind"] == "prefill":
        if cfg.frontend == "token":
            return {"inputs": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)}
    # decode: one new token against a seq_len KV cache
    if cfg.frontend == "token":
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
    caches, states = jax.eval_shape(
        functools.partial(transformer.init_caches, cfg, b, s))
    return {"token": tok, "caches": caches, "states": states}


# collective accounting shared with the streaming mesh runtime
from repro.launch.mesh import (COLLECTIVE_RE, DTYPE_BYTES,  # noqa: F401
                               collective_bytes)


def build_step(cfg: ModelConfig, shape: dict, mesh, opt_cfg=None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    rules = S.make_rules(cfg, tp=mesh.shape["model"])
    pspecs = S.param_specs(cfg)
    aparams = M.abstract_params(cfg)
    pspecs = S.fit_tree(pspecs, aparams, mesh)
    ns = lambda spec: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), spec,
        is_leaf=lambda x: isinstance(x, P))
    with shd.use_rules(mesh, rules):
        dp = shd.resolve("batch")
    dp_axes = dp[0] if len(dp) and dp[0] is not None else None
    batch_spec = P(dp_axes)

    if shape["kind"] == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        aopt = M.abstract_opt(aparams)
        dp_group = (("data",) if "pod" not in mesh.shape
                    else ("pod", "data"))
        dp_size = 1
        for a in dp_group:
            dp_size *= mesh.shape[a]
        zspec = S.opt_specs(aparams, pspecs, dp_size, dp_group)
        ospecs = adamw.OptState(mu=zspec, nu=zspec, step=P())
        batch = input_specs(cfg, shape)
        bspecs = S.fit_tree({k: P(dp_axes) for k in batch}, batch, mesh)

        def fn(params, opt_state, batch):
            with shd.use_rules(mesh, rules):
                return M.train_step(params, opt_state, batch, cfg=cfg,
                                    opt_cfg=opt_cfg)
        in_shard = (ns(pspecs), ns(ospecs), ns(bspecs))
        out_shard = (ns(pspecs), ns(ospecs), None)
        args = (aparams, aopt, batch)
    elif shape["kind"] == "prefill":
        batch = input_specs(cfg, shape)

        def fn(params, inputs):
            with shd.use_rules(mesh, rules):
                return M.prefill_step(params, inputs, cfg=cfg)
        ispec = S.fit_spec(batch_spec, batch["inputs"].shape, mesh)
        in_shard = (ns(pspecs), NamedSharding(mesh, ispec))
        out_shard = None
        args = (aparams, batch["inputs"])
    else:
        inp = input_specs(cfg, shape)
        cspec, sspec = S.cache_specs(cfg, rules)
        if inp["caches"] is not None:
            cspec = S.fit_tree(
                jax.tree.map(lambda _: cspec["k"], inp["caches"]) | {}
                if False else
                {"k": cspec["k"], "v": cspec["v"]}, inp["caches"], mesh)
        if inp["states"] is not None:
            if isinstance(sspec, P):
                sspec = S.fit_tree(
                    jax.tree.map(lambda _: sspec, inp["states"],
                                 is_leaf=lambda x: hasattr(x, "shape")),
                    inp["states"], mesh)
            else:
                sspec = S.fit_tree(sspec, inp["states"], mesh)

        def fn(params, caches, states, token):
            with shd.use_rules(mesh, rules):
                return M.decode_step(params, caches, states, token,
                                     jnp.int32(shape["seq"] - 1), cfg=cfg)
        tspec = S.fit_spec(batch_spec, inp["token"].shape, mesh)
        in_shard = (ns(pspecs),
                    ns(cspec) if inp["caches"] is not None else None,
                    ns(sspec) if inp["states"] is not None else None,
                    NamedSharding(mesh, tspec))
        out_shard = (None,
                     ns(cspec) if inp["caches"] is not None else None,
                     ns(sspec) if inp["states"] is not None else None)
        args = (aparams, inp["caches"], inp["states"], inp["token"])
    return fn, args, in_shard, out_shard


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False, cfg: ModelConfig = None) -> dict:
    import dataclasses
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    if shape["kind"] == "train":
        # each microbatch must still split evenly over the dp group
        # (multi-pod dp=32: chameleon's 16 microbatches would leave half-
        # token shards); clamp so batch/microbatches % dp == 0.
        dp_total = 32 if multi_pod else 16
        max_mb = max(shape["batch"] // dp_total, 1)
        if cfg.n_microbatches > max_mb:
            cfg = dataclasses.replace(cfg, n_microbatches=max_mb)
    if shape_name == "long_500k" and cfg.kind not in LONG_OK_KINDS:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped (full attention)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_shard, out_shard = build_step(cfg, shape, mesh)
    # donate the in-place state: params+opt (train), KV caches (decode) —
    # without donation every step holds two copies of the largest buffers.
    donate = {"train": (0, 1), "prefill": (), "decode": (1, 2)}[shape["kind"]]
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_shard, out_shardings=out_shard,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    res = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
        "peak_bytes_per_device": ((getattr(mem, "argument_size_in_bytes", 0) or 0)
                                  + (getattr(mem, "output_size_in_bytes", 0) or 0)
                                  + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                                  - (getattr(mem, "alias_size_in_bytes", 0) or 0)),
    }
    if keep_hlo:
        res["hlo"] = hlo
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    archs = ARCHS if (args.all or args.arch is None) else [canon(args.arch)]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    r = run_cell(arch, shape, mp)
                except Exception as e:  # a failing cell is a bug: surface it
                    r = {"arch": arch, "shape": shape, "multi_pod": mp,
                         "status": f"FAIL {type(e).__name__}: {e}"}
                results.append(r)
                tag = "2x16x16" if mp else "16x16"
                coll = r.get("collective_bytes", {})
                print(f"{arch:20s} {shape:12s} {tag:8s} {r['status']:28s} "
                      f"flops={r.get('flops', 0):.3e} "
                      f"peakGB={(r.get('peak_bytes_per_device') or 0)/2**30:.2f} "
                      f"coll={ {k: f'{v/2**20:.0f}MB' for k, v in coll.items()} }",
                      flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"].startswith("FAIL")]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells passed")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
