"""Live streaming driver: async double-buffered ingest + controller loop.

    PYTHONPATH=src python -m repro.launch.live --ticks 24 --tick 256 \
        --controller threshold --compare-sync --oracle

Streams a Q1-style wordcount workload through ``AsyncStreamRuntime`` under
an abruptly-changing offered-rate trace (the Q5 shape).  The whole stack —
operator, pipeline (single device or mesh), optional multi-host ingest
tier, controller, checkpointing — is assembled by ``repro.api``: the flags
below populate one ``RuntimeConfig`` and ``build_runtime`` does the rest.
Prints throughput, tick latency p50/p99, the reconfiguration trace, and
detection→switch latency.

* ``--compare-sync``  also runs the synchronous host-loop baseline on the
  same stream (replaying the async run's reconfiguration trace) and
  reports the overlap gain;
* ``--oracle``        checks the live run's output set exactly matches a
  static max-width run (the paper's correctness contract under
  elasticity);
* ``--pace``          paces the source to the schedule in wall-clock (a
  genuinely live workload; default is free-running, which is what the
  throughput comparison wants);
* ``--mesh N``        runs the pipeline on an N-device mesh
  (``MeshPipeline``; emulate devices with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
* ``--record F.npz`` / ``--replay F.npz`` save / replay the exact tick
  stream (event times intact) via ``io.sources``;
* ``--super-batch K``  stages K consecutive ticks as one device-resident
  stack and dispatches the persistent compiled K-tick scan;
* ``--fused-root``     (with ``--ingest-hosts``) runs the root merge on
  device (``RootMerge(device=True)``);
* ``--ingest-hosts N``  spreads the workload over N physical sources and
  merges them through the hierarchical multi-host ScaleGate upstream of
  the runtime; the tier's output set is asserted against the
  single-ScaleGate oracle after the run;
* ``--checkpoint-dir D --checkpoint-every K``  takes an epoch-consistent
  snapshot of the whole stack (pipeline sigma + ScaleGate + ingest tier)
  every K ticks, asynchronously, with an atomic-manifest commit;
* ``--resume``         (with ``--checkpoint-dir`` and ``--replay``)
  restores the stack from the latest complete checkpoint and replays the
  recorded stream from the snapshot's frontier — the kill-and-restore
  loop ``repro.launch.recovery`` drills and measures.
"""

import argparse
import dataclasses
import sys

import numpy as np
import jax

from repro import api
from repro import obs as _obs
from repro.core.async_runtime import run_sync
from repro.data import datagen
from repro.io import (CollectSink, NullSink, RateSchedule, ReplaySource,
                      SyntheticSource, load_stream, save_stream)
from repro.obs import ObsConfig

K_VIRT = 256
# Q5-style abrupt phases (tuples/s offered), cycled over the tick budget
PHASES = (2000.0, 16000.0, 4000.0, 24000.0, 2500.0)


def make_stream(args):
    phase_len = max(args.ticks // len(PHASES), 1)
    sched = RateSchedule(tuple((phase_len, r) for r in PHASES))
    if args.replay:
        src = load_stream(args.replay)
        src.schedule = sched
        return src
    rng = np.random.default_rng(args.seed)
    batches = []
    tau_base = 0
    for i in range(args.ticks):
        rate = sched.rate_at(i)
        (b,) = datagen.tweets(
            rng, n_ticks=1, tick=args.tick, words_per_tweet=3, vocab=2000,
            k_virt=K_VIRT, rate_per_tick=max(int(rate) // 10, 1),
            n_sources=max(args.ingest_hosts, 1))
        # each tweets() call restarts event time at 0; shift so the stream
        # stays timestamp-sorted end to end (the ScaleGate source contract)
        b = dataclasses.replace(b, tau=b.tau + tau_base)
        tau_base = int(np.asarray(b.tau).max()) + 1
        batches.append(b)
    if args.record:
        save_stream(args.record, batches)
        print(f"# recorded {len(batches)} ticks -> {args.record}")
    if args.pace:
        return SyntheticSource(batches, schedule=sched, pace=True,
                               tick_size=args.tick)
    return ReplaySource(batches, schedule=sched)


def make_obs_cfg(args) -> ObsConfig:
    on = bool(args.trace or args.obs_export or args.flight_dump
              or args.obs_port is not None)
    return ObsConfig(enabled=on, trace=bool(args.trace),
                     export_dir=args.obs_export,
                     serve_port=args.obs_port,
                     exemplar_rate=args.exemplar_rate,
                     event_sample=args.event_sample,
                     span_sample=args.span_sample,
                     event_budget_per_s=args.event_budget)


def finish_obs(args, report) -> None:
    """Post-run observability outputs: per-stage latency breakdown
    (--trace), metrics export (--obs-export handled by Runtime.run, also
    here for the resume path), flight-ring dump (--flight-dump)."""
    o = _obs.get()
    if o is None:
        return
    if args.trace and getattr(report, "stage_latency_ms", None):
        print("[live/trace] per-stage latency (ms):")
        for stage, q in sorted(report.stage_latency_ms.items()):
            print(f"    {stage:<20} p50={q['p50']:8.3f} "
                  f"p90={q['p90']:8.3f} p99={q['p99']:8.3f} "
                  f"n={int(q['count'])}")
    if getattr(report, "exemplar_timelines", None):
        print(f"[live/obs  ] {len(report.exemplar_timelines)} exemplar "
              f"tuple timelines completed")
    if args.obs_export:
        paths = o.export(args.obs_export)
        print(f"[live/obs  ] exported {sorted(paths.values())}")
    if args.flight_dump:
        p = o.dump_flight("on_demand", path=args.flight_dump)
        print(f"[live/obs  ] flight ring ({len(o.flight.events)} events) "
              f"-> {p}")


def make_cfg(args, n_sources: int) -> api.RuntimeConfig:
    """One declarative description of the run — every launcher knob lands
    in the same ``RuntimeConfig`` the checkpoint manifest carries."""
    return api.RuntimeConfig(
        obs=make_obs_cfg(args),
        op="count", wa=500, ws=1000, wt="multi", k_virt=K_VIRT,
        out_cap=1024, extra_slots=2,
        n_max=args.n_max, n_active=2,
        stash_cap=args.tick * 4 if args.ingest_hosts else args.tick,
        mesh_devices=args.mesh,
        n_sources=n_sources, ingest_hosts=args.ingest_hosts,
        leaf_cap=args.tick, root_cap=2 * args.tick, out_pad=2 * args.tick,
        root_device=args.fused_root,
        queue_cap=args.queue_cap, super_batch=args.super_batch,
        controller=args.controller,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)


class _Recording:
    """Lazily tee the source (a --pace source must pace the *router*, not
    a startup materialization) while keeping the raw ticks for the
    post-run single-gate-oracle check."""

    def __init__(self, src):
        self.src = src
        self.schedule = getattr(src, "schedule", None)
        self.raw = []

    def __iter__(self):
        for b in self.src:
            self.raw.append(b)
            yield b


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--tick", type=int, default=256, help="tuples per tick")
    ap.add_argument("--controller", default="threshold",
                    choices=["threshold", "predictive", "none"])
    ap.add_argument("--n-max", type=int, default=16)
    ap.add_argument("--queue-cap", type=int, default=4)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--pace", action="store_true")
    ap.add_argument("--compare-sync", action="store_true")
    ap.add_argument("--oracle", action="store_true")
    ap.add_argument("--mesh", type=int, default=0)
    ap.add_argument("--record", default=None)
    ap.add_argument("--replay", default=None)
    ap.add_argument("--ingest-hosts", type=int, default=0,
                    help="merge the stream through a hierarchical "
                         "multi-host ScaleGate with N leaf gates")
    ap.add_argument("--super-batch", type=int, default=1,
                    help="stage K consecutive ticks as one device stack "
                         "and run the persistent compiled K-tick scan")
    ap.add_argument("--fused-root", action="store_true",
                    help="with --ingest-hosts: run the root merge on "
                         "device (one fused stacked-leaf kernel per round)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="take epoch-consistent snapshots into this dir")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="pipeline ticks between snapshots (0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="restore from the latest complete checkpoint in "
                         "--checkpoint-dir and replay --replay from the "
                         "snapshot's frontier")
    ap.add_argument("--trace", action="store_true",
                    help="enable span tracing (per-stage latency "
                         "breakdown printed after the run)")
    ap.add_argument("--obs-export", default=None, metavar="DIR",
                    help="write metrics.json/metrics.prom (+ flight.json) "
                         "to DIR after the run; implies obs on")
    ap.add_argument("--flight-dump", default=None, metavar="FILE",
                    help="dump the flight-recorder ring to FILE after the "
                         "run (and on crash); implies obs on")
    ap.add_argument("--obs-port", type=int, default=None, metavar="PORT",
                    help="serve /metrics (Prometheus text) and /snapshot "
                         "(schema-v2 JSON) live during the run on this "
                         "port (0 = ephemeral); implies obs on")
    ap.add_argument("--exemplar-rate", type=float, default=0.0,
                    metavar="RATE",
                    help="sample ~RATE of tuples as end-to-end exemplar "
                         "timelines (admission -> ... -> emit)")
    ap.add_argument("--event-sample", type=float, default=1.0,
                    metavar="RATE",
                    help="keep ~RATE of flight-event detail records "
                         "(counters stay exact; 1.0 = keep all)")
    ap.add_argument("--span-sample", type=float, default=1.0,
                    metavar="RATE",
                    help="keep ~RATE of finished-span detail records "
                         "(span histograms stay exact; 1.0 = keep all)")
    ap.add_argument("--event-budget", type=float, default=0.0,
                    metavar="PER_S",
                    help="adaptive sampling: back detail rates off to stay "
                         "under PER_S kept records/s per kind (0 = off)")
    args = ap.parse_args(argv)

    if args.mesh and len(jax.devices()) < args.mesh:
        print(f"live SKIP: needs {args.mesh} devices, have "
              f"{len(jax.devices())} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.mesh})")
        return 0

    if args.resume:
        assert args.checkpoint_dir, "--resume needs --checkpoint-dir"
        assert args.replay, "--resume needs the --replay record to replay"
        ocfg = make_obs_cfg(args)
        if ocfg.enabled:
            # the manifest's config wins inside resume_runtime; the resume
            # flags install obs explicitly so a restored run can be traced
            _obs.install(ocfg)
        rt = api.resume_runtime(args.checkpoint_dir, args.replay)
        report = rt.run()
        print(f"[live/resume] restored step {rt.restored_step} from "
              f"{args.checkpoint_dir}; {report.summary()}")
        finish_obs(args, report)
        print("live resume OK")
        return 0

    src = make_stream(args)
    if args.ingest_hosts:
        if args.replay:
            # the recording fixes the source-id space; the tier must merge
            # whatever was recorded, not what --ingest-hosts assumes
            n_sources = 1 + max(
                (int(np.asarray(b.source).max()) for b in src.batches),
                default=0)
        else:
            n_sources = args.ingest_hosts
        src = _Recording(src)
    else:
        n_sources = 1
    cfg = make_cfg(args, n_sources)
    # CollectSink retains every tick's device outputs for the parity
    # checks; a pure throughput run must not grow memory with the stream
    need_outputs = args.compare_sync or args.oracle
    sink = CollectSink() if need_outputs else NullSink()
    rt = api.build_runtime(cfg, src, sink=sink,
                           record_tier=bool(args.ingest_hosts))
    o = _obs.get()
    if o is not None and o.server is not None:
        print(f"[live/obs  ] scrape endpoint live at {o.server.url}"
              f"/metrics (+ /snapshot)", flush=True)
    report = rt.run()
    print(f"[live/async] {report.summary()}")
    finish_obs(args, report)
    if rt.checkpointer is not None:
        print(f"[live/ckpt ] saved steps {rt.checkpointer.saved_steps} "
              f"-> {cfg.checkpoint_dir} (resume with --resume)")
    if rt.tier is not None:
        from repro.ingest import collect_tuples, single_gate_stream
        st = rt.tier.stats()
        print(f"[live/ingest] {st.summary()}")
        oracle = single_gate_stream(src.raw, cfg.n_sources,
                                    cap=3 * args.tick)
        assert (collect_tuples(rt.tier.emitted) == collect_tuples(oracle)), \
            "ingest tier diverged from the single-gate oracle"
        print(f"[live/ingest] tier output == single-ScaleGate oracle over "
              f"{st.tuples_out} tuples")
    if report.reconfig_trace:
        trace = ", ".join(f"t{t}->pi{rc.n_active}"
                          for t, rc in report.reconfig_trace)
        print(f"[live/async] reconfig trace: {trace}")
    if need_outputs:
        outs = rt.sink.results()
        if rt.tier is not None:
            batches = list(rt.tier.emitted)  # the merged stream the
            #                                  runtime saw
        elif isinstance(src, ReplaySource):
            batches = list(src.batches)
        else:
            batches = list(make_stream(argparse.Namespace(
                **{**vars(args), "pace": False, "record": None})))

    if args.compare_sync:
        sync_pipe = api.make_pipeline(cfg)
        sync_rep, sync_sink = run_sync(
            sync_pipe, ReplaySource(batches),
            reconfig_trace=report.reconfig_trace)
        gain = report.throughput_tps / max(sync_rep.throughput_tps, 1e-9)
        print(f"[live/sync ] {sync_rep.summary()}")
        print(f"[live] overlap gain async/sync = {gain:.2f}x; "
              f"outputs identical = {outs == sync_sink.results()}")
        assert outs == sync_sink.results(), "async diverged from sync replay"

    if args.oracle:
        static = api.make_pipeline(
            dataclasses.replace(cfg, n_active=args.n_max))
        _, oracle_sink = run_sync(static, ReplaySource(batches))
        ok = outs == oracle_sink.results()
        print(f"[live] outputs match static oracle = {ok} "
              f"({len(outs)} output tuples, "
              f"{len(report.reconfig_trace)} live reconfigs)")
        assert ok, "live elastic run diverged from the static oracle"
    print("live run OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
