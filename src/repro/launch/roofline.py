import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Roofline analysis (§Roofline) via two-point unrolled decomposition.

XLA's ``cost_analysis()`` counts while-loop (scan) bodies ONCE and reports
*per-device* numbers (calibrated in EXPERIMENTS.md §Dry-run), so the
production program's scans (layers, microbatches, KV chunks) hide work.
We therefore lower each cell twice with everything unrolled —
``n_layers = 2p`` and ``4p`` (p = the gemma3 local:global period, else 1),
``scan_layers=False``, ``n_microbatches=1``, ``analysis_unroll=True`` —
and solve the linear model

    C(L) = C_fixed + L * C_layer          (per metric, per collective kind)

Total per-device cost = C_fixed + n_layers * C_layer.  The irreducibly
sequential rwkv/ssm time recurrences stay scanned; their (<2%) FLOPs are
added in closed form.  Peak memory comes from the *production* compile
(dryrun JSON), since peaks don't decompose linearly.

Terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 6 links):
    T_comp = flops_dev / 197e12
    T_mem  = bytes_dev / 819e9
    T_coll = coll_bytes_dev / (6 * 50e9)
    roofline_fraction = (MODEL_FLOPS_dev / 197e12) / max(T_*)

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --all --json roofline.json
  PYTHONPATH=src python -m repro.launch.roofline --cell qwen3_moe_30b_a3b:train_4k
"""

import argparse
import dataclasses
import json
import sys

from repro.configs import ARCHS, canon, get_config
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
LINKS = 6
CHIPS = 256
DP = 16                          # single-pod data-parallel degree

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def model_flops_per_device(cfg: ModelConfig, shape: str) -> float:
    n = cfg.active_param_count()
    toks = SHAPE_TOKENS[shape]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n * toks / CHIPS


def recurrence_flops_per_device(cfg: ModelConfig, shape: str) -> float:
    """Closed-form FLOPs of the scanned time recurrences (kept scanned)."""
    toks = SHAPE_TOKENS[shape]
    toks_dev = toks / DP if shape in ("train_4k", "prefill_32k") else toks / DP
    mult = 3.0 if shape == "train_4k" else 1.0   # fwd+bwd+remat vs fwd
    if cfg.kind == "rwkv":
        h = cfg.d_model // cfg.rwkv_head
        per_tok = 6 * h * cfg.rwkv_head * cfg.rwkv_head
    elif cfg.kind == "hybrid":
        per_tok = 6 * cfg.ssm_heads * cfg.ssm_state * cfg.head_dim
    else:
        return 0.0
    return mult * cfg.n_layers * per_tok * toks_dev


def _shape_dims(cfg: ModelConfig, shape: str):
    if shape == "train_4k":
        return 256 // DP, 4096, 4096, 3.0      # B_loc, Sq, Skv, passes
    if shape == "prefill_32k":
        return 32 // DP, 32768, 32768, 1.0
    if shape == "decode_32k":
        return 128 // DP, 1, 32768, 1.0
    return 1, 1, 524288, 1.0                   # long_500k


def attention_interior_bytes(cfg: ModelConfig, shape: str) -> float:
    """HBM bytes the XLA path spends on attention logits/probs that the
    Pallas flash kernel keeps in VMEM (s f32 w+r, p exp w+r: ~12 B/pair).
    Window layers cap the KV span at the window."""
    if not cfg.n_heads:
        return 0.0
    b, sq, skv, passes = _shape_dims(cfg, shape)
    heads_sharded = cfg.n_kv_heads % 16 == 0 and cfg.n_heads % 16 == 0
    h_dev = cfg.n_heads // 16 if heads_sharded else cfg.n_heads
    if cfg.window_pattern is not None:
        local, every = cfg.window_pattern
        span_local = min(local + 1024, skv)    # chunk granularity
        frac_g = 1.0 / every
        span = frac_g * skv + (1 - frac_g) * span_local
    else:
        span = skv
    pairs = b * sq * span * h_dev * cfg.n_layers
    return pairs * 12.0 * passes


def recurrence_interior_bytes(cfg: ModelConfig, shape: str) -> float:
    """HBM bytes of the per-step recurrent state the linear_scan kernel
    keeps in VMEM (state read+write per token: ~12 B/element)."""
    b, sq, _, passes = _shape_dims(cfg, shape)
    toks = b * sq
    if cfg.kind == "rwkv":
        h = cfg.d_model // cfg.rwkv_head
        elems = h * cfg.rwkv_head * cfg.rwkv_head
    elif cfg.kind == "hybrid":
        elems = cfg.ssm_heads * cfg.ssm_state * cfg.head_dim
    else:
        return 0.0
    return toks * elems * 12.0 * cfg.n_layers * passes


def measure_cell(arch: str, shape: str) -> dict:
    from repro.launch.dryrun import LONG_OK_KINDS, run_cell

    cfg = get_config(arch)
    if shape == "long_500k" and cfg.kind not in LONG_OK_KINDS:
        return {"arch": arch, "shape": shape,
                "status": "skipped (full attention)"}
    period = cfg.window_pattern[1] if cfg.window_pattern else 1
    l1, l2 = 2 * period, 4 * period
    points = {}
    for l in (l1, l2):
        cfg_a = dataclasses.replace(
            cfg, n_layers=l, scan_layers=False, n_microbatches=1,
            analysis_unroll=True)
        r = run_cell(arch, shape, multi_pod=False, cfg=cfg_a)
        if r["status"] != "ok":
            return {"arch": arch, "shape": shape,
                    "status": f"analysis-lower failed: {r['status']}"}
        coll = sum(r["collective_bytes"].values())
        points[l] = (r["flops"], r["hlo_bytes"], coll)

    def solve(i):
        c_layer = (points[l2][i] - points[l1][i]) / (l2 - l1)
        c_fixed = points[l1][i] - l1 * c_layer
        return c_fixed + cfg.n_layers * c_layer

    flops = solve(0) + recurrence_flops_per_device(cfg, shape)
    bytes_raw = solve(1)
    # kernelized memory: the Pallas flash/linear_scan kernels keep the
    # attention logits and recurrent state in VMEM — subtract their
    # closed-form HBM traffic from the unfused-XLA estimate.
    interior = (attention_interior_bytes(cfg, shape) +
                recurrence_interior_bytes(cfg, shape))
    bytes_kern = max(bytes_raw - interior, bytes_raw * 0.05)
    coll = max(solve(2), 0.0)
    return {"arch": arch, "shape": shape, "status": "ok",
            "flops_dev": flops, "bytes_dev": bytes_kern,
            "bytes_dev_raw": bytes_raw, "coll_dev": coll}


def min_bytes_per_device(cfg: ModelConfig, shape: str) -> float:
    """The memory floor: every chip must read its param shard once per step
    (TP=16: params replicated across the data axis) plus its KV/state
    slice — the MBU-style bound that governs decode."""
    tp = 16
    w = 2.0 * cfg.active_param_count() / tp
    b, sq, skv, _ = _shape_dims(cfg, shape)
    kv = 0.0
    if cfg.n_heads:
        kv = 2.0 * b * skv * cfg.kv_dim * 2 / tp     # kv_seq/model sharded
    if cfg.kind == "rwkv":
        kv = b * (cfg.d_model // cfg.rwkv_head) * cfg.rwkv_head ** 2 * 4
    if shape == "train_4k":
        w = w * 3 + 12.0 * cfg.active_param_count() / (tp * DP)  # grads+opt
    return w + kv


def analyse(rec: dict, peak_mem=None) -> dict:
    cfg = get_config(rec["arch"])
    t_comp = rec["flops_dev"] / PEAK_FLOPS
    t_mem = rec["bytes_dev"] / HBM_BW
    t_coll = rec["coll_dev"] / (LINKS * ICI_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, rec["shape"])
    # the achievable floor is whichever physical resource binds first:
    # the MXU (compute) or the HBM read of weights+KV (decode regime).
    t_ideal = max(mf / PEAK_FLOPS,
                  min_bytes_per_device(cfg, rec["shape"]) / HBM_BW)
    return {
        **rec,
        "t_comp_s": t_comp, "t_mem_s": t_mem, "t_coll_s": t_coll,
        "t_mem_raw_s": rec.get("bytes_dev_raw", rec["bytes_dev"]) / HBM_BW,
        "dominant": dominant,
        "model_flops_dev": mf,
        "useful_ratio": mf / rec["flops_dev"] if rec["flops_dev"] else 0.0,
        "roofline_fraction": t_ideal / max(terms.values())
        if max(terms.values()) else 0.0,
        "peak_gb": peak_mem,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cell", default=None)
    ap.add_argument("--json", default=None)
    ap.add_argument("--peaks-from", default="dryrun_single.json")
    args = ap.parse_args(argv)

    peaks = {}
    if os.path.exists(args.peaks_from):
        with open(args.peaks_from) as f:
            for r in json.load(f):
                if r.get("status") == "ok" and not r.get("multi_pod"):
                    peaks[(r["arch"], r["shape"])] = \
                        (r.get("peak_bytes_per_device") or 0) / 2 ** 30

    from repro.launch.dryrun import SHAPES
    cells = ([tuple(args.cell.split(":"))] if args.cell else
             [(a, s) for a in ARCHS for s in SHAPES])

    rows = []
    hdr = (f"{'arch':20s} {'shape':12s} {'T_comp':>10s} {'T_mem':>10s} "
           f"{'T_coll':>10s} {'dom':>10s} {'useful':>7s} {'roofline':>9s} "
           f"{'peakGB':>7s}")
    print(hdr, flush=True)
    for arch, shape in cells:
        arch = canon(arch)
        rec = measure_cell(arch, shape)
        if rec["status"] != "ok":
            print(f"{arch:20s} {shape:12s} {rec['status']}", flush=True)
            rows.append(rec)
            continue
        w = analyse(rec, peaks.get((arch, shape)))
        rows.append(w)
        print(f"{arch:20s} {shape:12s} {w['t_comp_s']:10.3e} "
              f"{w['t_mem_s']:10.3e} {w['t_coll_s']:10.3e} "
              f"{w['dominant']:>10s} {w['useful_ratio']:7.1%} "
              f"{w['roofline_fraction']:9.1%} "
              f"{(w['peak_gb'] or 0):7.2f}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
