"""Production training driver: mesh + shardings + checkpoint/resume +
streaming data + compute/comm overlap flags.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --steps 100 --ckpt-dir /tmp/ckpt [--reduced] [--grad-compress]

On the real cluster this runs once per host under the same jit program
(jax.distributed.initialize); here it drives whatever devices exist.
``--reduced`` shrinks the config to the smoke footprint so the full driver
path (resume, checkpoint cadence, metrics) is exercisable anywhere.
"""

import argparse
import os
import sys
import time

# Compute/communication overlap: let XLA's latency-hiding scheduler overlap
# collectives with compute (the standard large-scale flags).
os.environ.setdefault("XLA_FLAGS", "")
os.environ["XLA_FLAGS"] += (
    " --xla_tpu_enable_async_collective_fusion=true"
    " --xla_tpu_overlap_compute_collective_tc=true") \
    if "tpu" in os.environ.get("JAX_PLATFORMS", "") else ""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as C
from repro.configs import canon, get_config, reduced
from repro.data import datagen
from repro.models import model as M, transformer
from repro.optim import adamw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_config(canon(args.arch))
    if args.reduced:
        cfg = reduced(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 1))

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    opt = adamw.init_opt(params)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}", flush=True)

    start_step, restored = 0, None
    latest = C.latest_step(args.ckpt_dir)
    if latest is not None:
        start_step, (params, opt) = latest, C.restore(
            args.ckpt_dir, latest, (params, opt))
        print(f"resumed from step {latest}", flush=True)

    step_fn = jax.jit(lambda p, o, b: M.train_step(
        p, o, b, cfg=cfg, opt_cfg=opt_cfg, chunk=min(1024, args.seq)))

    rng = np.random.default_rng(start_step)
    stream = datagen.token_batches(rng, vocab=cfg.vocab, batch=args.batch,
                                   seq=args.seq,
                                   n_batches=args.steps - start_step)
    t0 = time.time()
    for i, batch in enumerate(stream, start=start_step + 1):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend != "token":
            batch["inputs"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, args.seq, cfg.d_model),
                jnp.bfloat16)
        params, opt, m = step_fn(params, opt, batch)
        if i % 10 == 0 or i == start_step + 1:
            dt = (time.time() - t0)
            print(f"step {i} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"tok/s={args.batch*args.seq*10/max(dt,1e-9):.0f}",
                  flush=True)
            t0 = time.time()
        if i % args.ckpt_every == 0:
            C.save(args.ckpt_dir, i, (params, opt))   # async
    C.wait(args.ckpt_dir)
    C.save(args.ckpt_dir, args.steps, (params, opt), async_=False)
    print("done", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
