"""Sharding rules per architecture + parameter PartitionSpec trees.

The logical->mesh rules adapt to the arch (DESIGN.md §6): attention heads
shard over "model" only when the KV head count divides the TP degree
(musicgen, deepseek-moe); otherwise head axes stay unconstrained for
compute (XLA propagates) and the *KV cache timeline* carries the model
axis ("kv_seq") so decode state fits memory with only scalar-sized softmax
collectives (attention.decode_attention).
"""

from __future__ import annotations

from typing import Optional

from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.sharding import DEFAULT_RULES


def make_rules(cfg: ModelConfig, tp: int = 16) -> dict:
    rules = dict(DEFAULT_RULES)
    heads_ok = cfg.n_heads and cfg.n_kv_heads % tp == 0 \
        and cfg.n_heads % tp == 0
    if heads_ok:
        rules["heads"] = "model"
        rules["kv_heads"] = "model"
        rules["head_dim"] = None
        rules["kv_seq"] = None
    else:
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["head_dim"] = None
        rules["kv_seq"] = "model"       # decode cache: shard the timeline
    return rules


def _layer_specs(cfg: ModelConfig, prefix=()):
    """PartitionSpec tree matching init_layer's dict structure."""
    pre = prefix

    def p(*axes):
        return P(*(pre + axes))

    d: dict = {"norm1": p(None), "norm2": p(None)}
    if cfg.kind == "rwkv":
        d["tm"] = {
            "mu_r": p(None), "mu_k": p(None), "mu_v": p(None),
            "mu_w": p(None), "mu_g": p(None),
            "w_r": p(None, "model"), "w_k": p(None, "model"),
            "w_v": p(None, "model"), "w_g": p(None, "model"),
            "w_o": p("model", None),
            "w0": p(None), "w_lora_a": p(None, None),
            "w_lora_b": p(None, None), "u": p(None), "ln_scale": p(None),
        }
        d["cm"] = {
            "mu_k": p(None), "mu_r": p(None),
            "w_k": p(None, "model"), "w_v": p("model", None),
            "w_r": p(None, "model"),
        }
        return d
    d["attn"] = {
        "wq": p(None, "model"), "wk": p(None, "model"),
        "wv": p(None, "model"), "wo": p("model", None),
    }
    if cfg.qk_norm:
        d["attn"]["q_scale"] = p(None)
        d["attn"]["k_scale"] = p(None)
    if cfg.kind == "hybrid":
        d["norm1b"] = p(None)
        d["ssm"] = {
            "w_x": p(None, "model"), "w_z": p(None, "model"),
            "w_b": p(None, "model"), "w_c": p(None, "model"),
            "w_dt": p(None, None), "w_out": p("model", None),
            "a_log": p(None),
        }
    if cfg.kind == "moe":
        d["moe"] = {
            "router": p(None, None),
            "wg": p("model", None, None), "wu": p("model", None, None),
            "wd": p("model", None, None),
        }
        if cfg.moe.n_shared:
            d["moe"]["shared_wg"] = p(None, "model")
            d["moe"]["shared_wu"] = p(None, "model")
            d["moe"]["shared_wd"] = p("model", None)
    else:
        d["mlp"] = {"wg": p(None, "model"), "wu": p(None, "model"),
                    "wd": p("model", None)}
    return d


def param_specs(cfg: ModelConfig):
    specs = {
        "embedding": P("model", None),     # vocab-sharded
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P("model", None)
    prefix = (None,) if cfg.scan_layers else ()
    layer = _layer_specs(cfg, prefix)
    if cfg.scan_layers:
        specs["layers"] = layer
    else:
        specs["layers"] = [layer for _ in range(cfg.n_layers)]
    return specs


def opt_specs(abstract_params, pspecs, data_size: int = 16,
              dp_axes=("data",)):
    """ZeRO-1: each f32 moment additionally shards over the data axis on the
    first dim that is (a) unsharded in the param spec and (b) divisible by
    the DP degree.  GSPMD then emits the ZeRO-1 gather/scatter pair around
    the optimizer update (measured in the dry-run collectives)."""
    import jax

    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def zero1(shape_struct, spec: P):
        shape = shape_struct.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, (dim, ax) in enumerate(zip(shape, entries)):
            if ax is None and dim % data_size == 0 and dim >= data_size:
                entries[i] = dp
                break
        return P(*entries)

    return jax.tree.map(zero1, abstract_params, pspecs)


def fit_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that don't exist or don't divide the dim (batch=1
    decode, odd vocab, pod axis on a single-pod mesh, ...)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.shape)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and size > 1 and dim % size == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def fit_tree(specs, abstract, mesh):
    import jax
    return jax.tree.map(
        lambda sp, ab: fit_spec(sp, ab.shape, mesh), specs, abstract,
        is_leaf=lambda x: isinstance(x, P))


def cache_specs(cfg: ModelConfig, rules: dict):
    """KV caches [L, B, S, KV, Dh] / recurrent states."""
    dp = rules["batch"]
    if cfg.kind == "rwkv":
        state = rules["state"]
        return None, {
            "shift_tm": P(None, dp, None),
            "shift_cm": P(None, dp, None),
            "wkv": P(None, dp, state, None, None),
        }
    kv_seq = rules["kv_seq"]
    kv_heads = rules["kv_heads"]
    caches = {"k": P(None, dp, kv_seq, kv_heads, None),
              "v": P(None, dp, kv_seq, kv_heads, None)}
    states = None
    if cfg.kind == "hybrid":
        states = P(None, dp, None, None, None)
    return caches, states
