"""qwen3-moe-30b-a3b [moe]: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
48L d_model=2048 32H (GQA kv=4, d_head=128) vocab=151936,
d_ff_expert=768."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_head=128, d_ff=768, vocab=151936, qk_norm=True,
    rope_theta=1e6, kind="moe",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, n_shared=0,
                  dispatch="vsn", capacity_factor=1.0),
    tie_embeddings=False, n_microbatches=8,
)
