"""hymba-1.5b [hybrid]: parallel attention + mamba heads
[arXiv:2411.13676].  32L d_model=1600 25H (GQA kv=5, d_head=64)
d_ff=5504 vocab=32001, ssm_state=16."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", n_layers=32, d_model=1600, n_heads=25,
    n_kv_heads=5, d_head=64, d_ff=5504, vocab=32001, kind="hybrid",
    ssm_state=16, ssm_heads=25, tie_embeddings=True, n_microbatches=8,
)
