"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4, d_head=256)
d_ff=10240 vocab=262144; 5:1 local:global (window 1024)
[hf:google/gemma-3-4b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8,
    n_kv_heads=4, d_head=256, d_ff=10240, vocab=262144, qk_norm=True,
    window_pattern=(1024, 6), kind="dense", tie_embeddings=True,
    n_microbatches=4,
)
