"""musicgen-large [audio]: decoder-only over EnCodec tokens
[arXiv:2306.05284].  48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend (4 codebooks, delay pattern) is a stub per the
assignment: input_specs() provides precomputed frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab=2048, kind="dense",
    frontend="embedding_stub", tie_embeddings=True, n_microbatches=4,
)
