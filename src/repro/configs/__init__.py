"""Assigned-architecture registry: ``get_config(name)`` + reduced configs.

Each ``<arch>.py`` holds the exact published hyperparameters from the
assignment; ``reduced()`` shrinks any config to a CPU-smoke footprint while
preserving its family (kind, GQA ratio, window pattern, MoE top-k...).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, MoEConfig

ARCHS = [
    "chameleon_34b", "stablelm_12b", "gemma3_12b", "gemma3_4b", "qwen3_14b",
    "musicgen_large", "hymba_1_5b", "deepseek_moe_16b", "qwen3_moe_30b_a3b",
    "rwkv6_7b",
]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same family, smoke-test footprint (runs a train step on 1 CPU core)."""
    upd = dict(
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=(max(1, round(4 * cfg.n_kv_heads / cfg.n_heads))
                    if cfg.n_heads else 0),
        d_head=16 if cfg.n_heads else None,
        n_microbatches=1,
        scan_layers=cfg.scan_layers,
    )
    if cfg.window_pattern is not None:
        upd["window_pattern"] = (8, cfg.window_pattern[1])
    if cfg.moe is not None:
        upd["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 3),
            d_ff_expert=32, n_shared=min(cfg.moe.n_shared, 1))
    if cfg.kind == "hybrid":
        upd["ssm_heads"] = 4
        upd["ssm_state"] = 8
    if cfg.kind == "rwkv":
        upd["rwkv_head"] = 16
    return dataclasses.replace(cfg, **upd)
