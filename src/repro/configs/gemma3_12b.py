"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8, d_head=256)
d_ff=15360 vocab=262144; 5:1 local:global sliding attention (window 1024),
128k context [hf:google/gemma-3-12b-pt]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
    n_kv_heads=8, d_head=256, d_ff=15360, vocab=262144, qk_norm=True,
    window_pattern=(1024, 6), kind="dense", tie_embeddings=True,
    n_microbatches=8,
)
