"""deepseek-moe-16b [moe]: fine-grained experts [arXiv:2401.06066].
28L d_model=2048 16H (kv=16) vocab=102400; 64 routed experts top-6 +
2 shared, d_ff_expert=1408."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=102400, kind="moe",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  dispatch="vsn"),
    tie_embeddings=False, n_microbatches=4,
)
