"""chameleon-34b [vlm]: early-fusion VQ-token backbone [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  The modality
frontend (VQ image tokenizer) is a stub per the assignment: input_specs()
provides precomputed patch/token embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", n_layers=48, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
    kind="dense", frontend="embedding_stub", tie_embeddings=True,
    n_microbatches=16,
)
