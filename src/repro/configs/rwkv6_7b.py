"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay
[arXiv:2404.05892].  32L d_model=4096 d_ff=14336 vocab=65536,
head size 64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=14336, vocab=65536, kind="rwkv", rwkv_head=64,
    tie_embeddings=False, n_microbatches=8,
)
