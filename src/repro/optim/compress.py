"""int8 error-feedback gradient compression (distributed-optimization trick).

Before the cross-replica gradient reduction, gradients are quantized to int8
with a per-tensor scale; the quantization error is carried in a residual and
re-added next step (error feedback keeps SGD/Adam convergence).  At 1000+
node scale this cuts the gradient all-reduce bytes 4x (f32->i8) or 2x
(bf16->i8); selectable per run (``train.py --grad-compress``).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residual) -> Tuple[Any, Any, Any]:
    """Returns (int8 grads, scales, new residual)."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale, g - q.astype(jnp.float32) * scale

    out = jax.tree.map(one, grads, residual)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1), pick(2)


def decompress(q, scales) -> Any:
    return jax.tree.map(lambda qq, s: qq.astype(jnp.float32) * s, q, scales)


def compressed_psum(grads, residual, axis_name=None):
    """Quantize -> (all-reduce) -> dequantize with error feedback.

    Under pjit the reduction is implicit in sharding propagation; the
    quantized dtype is what crosses the wire, which the dry-run's collective
    scan observes as i8 operands.
    """
    q, s, residual = compress(grads, residual)
    if axis_name is not None:
        q = jax.tree.map(lambda x: jax.lax.psum(x.astype(jnp.int32),
                                                axis_name), q)
        s = jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), s)
    return decompress(q, s), residual
