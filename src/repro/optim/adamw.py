"""AdamW with ZeRO-1 sharded optimizer state + cosine schedule (pure JAX).

Optimizer moments are f32 and get their *own* sharding (the "opt" logical
axis folds the data-parallel axis in, ZeRO-1 style) via launch-time
shardings; the update math is plain and jit-inlines into train_step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def init_opt(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt: OptState, cfg: AdamWConfig):
    step = opt.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step)
        nu_hat = nu / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, opt.mu, opt.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(new_mu, new_nu, step), {"grad_norm": gn,
                                                        "lr": lr}
