#!/usr/bin/env bash
# Pinned-environment benchmark launcher: makes the BENCH_*.json artifacts
# reproducible across hosts by fixing the knobs that silently skew timings.
#
#   ./run.sh --only q1_wordcount,q3_scalejoin --async --ingest-hosts 2 \
#            --bench-dir bench-json
#
# Environment knobs (all optional):
#   DEVICES=N    emulate N XLA host devices (sets
#                --xla_force_host_platform_device_count; leave unset for
#                the single real CPU device — smoke benches depend on it)
#   PIN_CPUS=S   pin the run to a CPU set via taskset (e.g. "0" or "0-3");
#                isolates the timed loops from sibling load
#   LD_PRELOAD   honored if already set; otherwise tcmalloc is preloaded
#                when present (allocator jitter is visible at the
#                sub-millisecond tick times the hot-path rows measure)
set -euo pipefail
cd "$(dirname "$0")"

if [ -n "${DEVICES:-}" ]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=${DEVICES}"
fi

if [ -z "${LD_PRELOAD:-}" ]; then
  for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
             /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
             /usr/lib/libtcmalloc_minimal.so.4; do
    if [ -e "$lib" ]; then
      export LD_PRELOAD="$lib"
      break
    fi
  done
fi

export PYTHONPATH="src${PYTHONPATH:+:${PYTHONPATH}}"

CMD=(python -m benchmarks.run "$@")
if [ -n "${PIN_CPUS:-}" ] && command -v taskset >/dev/null 2>&1; then
  exec taskset -c "${PIN_CPUS}" "${CMD[@]}"
fi
exec "${CMD[@]}"
