"""Quickstart: the paper's running example as a STRETCH pipeline.

An A+ computes the longest tweet per hashtag over 1-hour sliding windows
(WA=30 min) with VSN parallelism, then scales from 2 to 4 instances
mid-stream with a <40 ms, zero-state-transfer reconfiguration.

    PYTHONPATH=src:. python examples/quickstart.py
"""

import numpy as np
import jax

from repro.core.aggregate import longest_aggregate
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.runtime import VSNPipeline
from repro.core.tuples import make_batch
from repro.core.windows import WindowSpec

K = 32                                  # virtual hashtag keys
MIN = 60 * 1000                         # delta = 1 ms


def tweets(rng, t0, n):
    """Tuples <tau, [len]> with hashtag key sets (f_MK output)."""
    taus = np.sort(t0 + rng.integers(0, 10 * MIN, n)).astype(np.int32)
    keys = rng.integers(0, K, (n, 2)).astype(np.int32)   # up to 2 hashtags
    keys[rng.random((n, 2)) < 0.3] = -1                  # some have fewer
    length = rng.integers(5, 140, (n, 1)).astype(np.float32)
    return make_batch(taus, length, keys=keys, kmax=2), int(taus.max())


def main():
    op = longest_aggregate(WindowSpec(wa=30 * MIN, ws=60 * MIN, wt="multi"),
                           k_virt=K, out_cap=256)
    pipe = VSNPipeline(op, n_max=4, n_active=2, stash_cap=64)
    rng = np.random.default_rng(0)

    t0 = 0
    for step in range(6):
        batch, t0 = tweets(rng, t0, 48)
        rc = None
        if step == 3:   # provision two more instances, instantly
            rc = Reconfiguration(epoch=1, n_active=4,
                                 fmu=balanced_fmu(K, 4, 4),
                                 active=active_mask(4, 4))
        o1, o2, switched = pipe.step(batch, reconfig=rc)
        for outs in (o1, o2):
            tau = np.asarray(outs.tau); pay = np.asarray(outs.payload)
            ok = np.asarray(outs.valid)
            for j in range(tau.shape[0]):
                for t, p, v in zip(tau[j], pay[j], ok[j]):
                    if v:
                        print(f"  window closing at {t//MIN:4d} min: "
                              f"hashtag {int(p[0]):2d} longest {int(p[1])} chars")
        if bool(switched):
            print(f"[step {step}] reconfigured 2 -> 4 instances "
                  f"(epoch {int(pipe.epoch.e)}, zero state moved)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
