"""End-to-end driver: train a ~100M-param qwen3-family model on a streaming
token pipeline with async checkpointing and crash-resume.

Default runs a short smoke (--steps 30 on a ~10M config); the full run of
the deliverable is:

    PYTHONPATH=src:. python examples/streaming_train.py --full --steps 300
"""

import argparse
import dataclasses

from repro.configs import get_config, reduced
from repro.launch import train as T
from repro.models.config import ModelConfig


def config_100m() -> ModelConfig:
    base = get_config("qwen3_14b")
    return dataclasses.replace(
        base, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=2048, vocab=32000, n_microbatches=1)   # ~100M params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params (default: reduced smoke config)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/streaming_train_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "qwen3-14b", "--steps", str(args.steps),
            "--ckpt-dir", args.ckpt_dir, "--batch", "4", "--seq", "128"]
    if not args.full:
        argv.append("--reduced")
        T.main(argv)
    else:
        import repro.configs as RC
        cfg100 = config_100m()
        orig = RC.get_config
        RC.get_config = lambda name: cfg100 if name == "qwen3_14b" else orig(name)
        try:
            T.main(argv)
        finally:
            RC.get_config = orig
    print("streaming_train OK")


if __name__ == "__main__":
    main()
