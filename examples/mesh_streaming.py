"""Mesh streaming: the quickstart A+ on a real device mesh.

The same longest-tweet-per-hashtag pipeline as examples/quickstart.py, but
executed by ``MeshPipeline``: sigma sharded over the devices in fixed key
blocks, ticks ingested in batched stacks (one compiled shard_map call for
T ticks), and a mid-stream reconfiguration that swaps only the replicated
f_mu/active tables — the compiled step moves zero bytes of state between
devices, which this example prints from the compiled HLO.

Run with emulated devices (the flag must precede the first jax import —
this script sets it for you):

    PYTHONPATH=src:. python examples/mesh_streaming.py [n_devices]
"""

import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 4
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEV}").strip()

import numpy as np
import jax

from repro.core.aggregate import longest_aggregate
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.runtime import MeshPipeline
from repro.core.tuples import make_batch
from repro.core.windows import WindowSpec
from repro.launch.mesh import make_stream_mesh

K = 32                                  # virtual hashtag keys
MIN = 60 * 1000                         # delta = 1 ms


def tweets(rng, t0, n):
    taus = np.sort(t0 + rng.integers(0, 10 * MIN, n)).astype(np.int32)
    keys = rng.integers(0, K, (n, 2)).astype(np.int32)
    keys[rng.random((n, 2)) < 0.3] = -1
    length = rng.integers(5, 140, (n, 1)).astype(np.float32)
    return make_batch(taus, length, keys=keys, kmax=2), int(taus.max())


def main():
    op = longest_aggregate(WindowSpec(wa=30 * MIN, ws=60 * MIN, wt="multi"),
                           k_virt=K, out_cap=256)
    mesh = make_stream_mesh(N_DEV)
    print(f"mesh: {N_DEV} devices, {K // N_DEV} keys per shard")
    pipe = MeshPipeline(op, mesh, stash_cap=64, mode="general",
                        n_max=4, n_active=2)
    rng = np.random.default_rng(0)

    # batched ingest: stack 3 ticks, scan them in one compiled call
    t0 = 0
    stack = []
    for _ in range(3):
        b, t0 = tweets(rng, t0, 48)
        stack.append(b)
    o1, o2, _ = pipe.run(stack)
    n_out = int(np.asarray(o1.valid).sum() + np.asarray(o2.valid).sum())
    print(f"ticks 0-2 (one shard_map call): {n_out} window outputs")

    # scale 2 -> 4 mid-stream: tables swap, sigma rows stay put
    rc = Reconfiguration(epoch=1, n_active=4, fmu=balanced_fmu(K, 4, 4),
                         active=active_mask(4, 4))
    b, t0 = tweets(rng, t0, 48)
    _, _, switched = pipe.step(b, reconfig=rc)
    b, t0 = tweets(rng, t0, 48)
    _, _, switched2 = pipe.step(b)
    print(f"reconfig 2->4: switched={bool(switched) or bool(switched2)}, "
          f"table bytes={pipe.switch_bytes()}")
    coll = pipe.collective_bytes()
    print(f"cross-device state transfer (compiled HLO collectives): "
          f"{sum(coll.values())} B {coll or ''}")
    assert sum(coll.values()) == 0


if __name__ == "__main__":
    main()
