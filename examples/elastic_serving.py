"""Elastic LLM serving on the VSN slot pool: requests stream in, replicas
scale with zero KV-cache movement (vs the SN baseline that ships slots).

    PYTHONPATH=src:. python examples/elastic_serving.py
"""

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import transformer
from repro.serving.kv_pool import Request, ServingEngine


def main():
    cfg = reduced(get_config("qwen3_14b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=6, max_seq=48, n_instances=4)
    eng.pool.reconfigure_vsn(2)          # start with 2 active replicas

    rng = np.random.default_rng(1)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, cfg.vocab, 4),
                           max_new=6, arrived=uid))
    finished = []
    tick = 0
    while len(finished) < 5 and tick < 40:
        finished += eng.tick()
        tick += 1
        if tick == 2:       # load spike: scale 2 -> 4 replicas
            sn = eng.pool.reconfigure_sn(4)     # what SN would ship now
            eng.pool.kv_bytes_moved = 0
            moved = eng.pool.reconfigure_vsn(4)
            print(f"[tick {tick}] scaled to 4 replicas: VSN moved {moved} B "
                  f"(tables), SN baseline would ship {sn} B of live KV")
        if tick == 6:       # drain: scale back down
            moved = eng.pool.reconfigure_vsn(2)
            print(f"[tick {tick}] scaled to 2 replicas, moved {moved} B")
    for r in finished:
        print(f"request {r.uid}: {len(r.out)} tokens {r.out}")
    print("elastic_serving OK")


if __name__ == "__main__":
    main()
