"""Unified observability layer (repro.obs) — PR-8 acceptance.

The contracts under test:
  * MetricsRegistry: exact counter/gauge totals, fixed-memory histogram
    sketch with bounded quantile error, versioned-schema snapshot
    (validated + rejected on tamper) and Prometheus text exposition;
  * Tracer: nested spans fold into ``span.*`` histograms with parent
    paths; disabled tracing is a shared null-object no-op;
  * FlightRecorder: fixed-size ring, structured events, JSON dump format;
  * cross-process propagation: counter deltas + finished spans + events
    drained from a child ``Obs`` fold into the parent; real spawned
    ingest-leaf processes surface their metrics/spans/events in the
    parent snapshot;
  * MetricsBus: bounded per-tick retention with exact full-run totals and
    sketch-backed quantiles after eviction; the pending-detection leak is
    flushed at stop() and surfaced in the run report;
  * end-to-end: a ``build_runtime`` run with obs on produces the per-tick
    stage-latency breakdown across every instrumented stage, and a
    planted chaos failure (SIGKILLed ingest leaf) dumps a flight-recorder
    JSON timeline spanning leaf, root/tier, runtime, and controller
    events.
"""

import dataclasses
import glob
import json
import os
import threading

import numpy as np
import pytest

from repro import api, obs
from repro.obs import ObsConfig
from repro.obs.flight import FlightRecorder
from repro.obs.registry import (Histogram, MetricsRegistry, SCHEMA_VERSION,
                                validate_snapshot)
from repro.obs.trace import Tracer, _NULL_SPAN

K = 64
N_SRC = 4


@pytest.fixture
def obs_env():
    """Install a fresh Obs for the test; always restore the previous
    global afterwards (the suite must not leak instrumentation)."""
    prev = obs.get()

    def make(**kw):
        return obs.install(ObsConfig(**kw))

    yield make
    obs.set_current(prev)


def agg_stream(n_ticks=6, seed=0, tick=16, n_sources=N_SRC):
    from repro.data import datagen
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=n_sources))


# ------------------------------------------------------------ registry ----

def test_registry_exact_totals_and_snapshot():
    reg = MetricsRegistry()
    for _ in range(100):
        reg.inc("a.ticks")
    reg.inc("a.tuples", 2.5)
    reg.set_gauge("a.depth", 3)
    reg.set_gauge("a.depth", 7)
    for v in (1e-4, 2e-4, 3e-4):
        reg.observe("a.lat", v)
    snap = reg.snapshot()
    validate_snapshot(snap)
    assert snap["schema_version"] == SCHEMA_VERSION
    assert snap["counters"]["a.ticks"] == 100
    assert snap["counters"]["a.tuples"] == 2.5
    assert snap["gauges"]["a.depth"] == 7            # last write wins
    h = snap["histograms"]["a.lat"]
    assert h["count"] == 3 and h["min"] == 1e-4 and h["max"] == 3e-4
    assert abs(h["sum"] - 6e-4) < 1e-12


def test_histogram_sketch_quantiles_bounded_error():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-7.0, sigma=1.0, size=5000)   # ~ms latencies
    h = Histogram()
    for v in vals:
        h.record(float(v))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        approx = h.quantile(q)
        # geometric buckets are 2**(1/8) wide: midpoint error <= ~4.5%,
        # plus rank granularity — 10% is a safe hard bound
        assert abs(approx - exact) / exact < 0.10, (q, exact, approx)
    # quantiles are clamped to the observed range
    assert h.min <= h.quantile(0.0001) and h.quantile(0.9999) <= h.max


def test_snapshot_validation_rejects_tampering():
    reg = MetricsRegistry()
    reg.inc("x")
    reg.observe("y", 0.5)
    snap = reg.snapshot()
    validate_snapshot(snap)

    bad = dict(snap)
    bad["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        validate_snapshot(bad)
    bad = dict(snap)
    del bad["histograms"]
    with pytest.raises(ValueError, match="histograms"):
        validate_snapshot(bad)
    bad = json.loads(json.dumps(snap))
    bad["counters"]["x"] = "not-a-number"
    with pytest.raises(ValueError, match="number"):
        validate_snapshot(bad)
    bad = json.loads(json.dumps(snap))
    del bad["histograms"]["y"]["p99"]
    with pytest.raises(ValueError, match="p99"):
        validate_snapshot(bad)


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.inc("bus.ticks", 5)
    reg.set_gauge("bus.queue-depth", 2)
    reg.observe("span.root.merge", 0.01)
    text = reg.to_prometheus()
    assert "# TYPE bus_ticks counter" in text
    assert "bus_ticks 5" in text
    assert "# TYPE bus_queue_depth gauge" in text     # sanitized name
    assert "# TYPE span_root_merge summary" in text
    assert 'span_root_merge{quantile="0.99"}' in text
    assert text.endswith("\n")


def test_prometheus_escaping_and_sampled_series():
    """Exposition-format conformance: HELP strings escape backslash and
    newline, label values additionally escape double-quotes, and every
    family — including the sampling-metadata series — carries # TYPE."""
    reg = MetricsRegistry()
    reg.inc('weird"name\nwith\\slashes', 2)
    sampling = {"event_sample": 1.0, "span_sample": 1.0,
                "budget_per_s": 0.0, "adaptive": False,
                "events": {'k"ind\n\\': {"attempts": 10, "kept": 1,
                                         "rate": 0.1}},
                "spans": {}}
    text = reg.to_prometheus(sampling=sampling)
    lines = text.splitlines()
    help_line = next(l for l in lines if l.startswith("# HELP weird_"))
    assert "\\n" in help_line and "\\\\" in help_line
    typed = {l.split()[2] for l in lines if l.startswith("# TYPE")}
    assert "obs_sampled_total" in typed
    sampled = [l for l in lines if l.startswith("obs_sampled_total{")]
    assert any('outcome="attempted"} 10' in l for l in sampled)
    assert any('outcome="kept"} 1' in l for l in sampled)
    assert any('kind="k\\"ind\\n\\\\"' in l for l in sampled)
    # no line may contain a raw (unescaped) newline: splitlines is exact
    assert all("\n" not in l for l in lines)


def test_v2_snapshot_v1_legacy_and_tamper():
    """Schema v2 adds sampling + exemplars; v1 payloads (older children)
    still validate without them, but a v2 snapshot missing them — or any
    unknown version — is rejected."""
    reg = MetricsRegistry()
    reg.inc("x")
    reg.observe("y", 0.5)
    snap = reg.snapshot()
    assert snap["schema_version"] == SCHEMA_VERSION == 2
    assert snap["sampling"] == {} and snap["exemplars"] == []
    validate_snapshot(snap)
    v1 = {k: v for k, v in snap.items()
          if k not in ("sampling", "exemplars")}
    v1["schema_version"] = 1
    validate_snapshot(v1)                              # legacy accepted
    bad = dict(v1)
    bad["schema_version"] = 2
    with pytest.raises(ValueError, match="sampling"):
        validate_snapshot(bad)                         # v2 requires them
    bad = dict(snap)
    bad["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        validate_snapshot(bad)
    bad = dict(snap)
    bad["exemplars"] = {"not": "an array"}
    with pytest.raises(ValueError, match="exemplars"):
        validate_snapshot(bad)


# -------------------------------------------------------------- tracer ----

def test_tracer_nested_spans_paths_and_quantiles():
    reg = MetricsRegistry()
    tr = Tracer(reg, enabled=True)
    with tr.span("runtime.dispatch"):
        with tr.span("pipeline.step"):
            pass
    recs = list(tr.finished)
    assert [r["name"] for r in recs] == ["pipeline.step", "runtime.dispatch"]
    assert recs[0]["path"] == "runtime.dispatch/pipeline.step"
    assert recs[1]["path"] == "runtime.dispatch"
    assert all(r["dur_s"] >= 0 and r["pid"] == os.getpid() for r in recs)
    lat = tr.stage_latency_ms()
    assert set(lat) == {"runtime.dispatch", "pipeline.step"}
    assert lat["runtime.dispatch"]["count"] == 1
    assert {"p50", "p90", "p99", "mean"} <= set(lat["runtime.dispatch"])


def test_disabled_tracing_is_null_object():
    tr = Tracer(MetricsRegistry(), enabled=False)
    assert tr.span("x") is _NULL_SPAN       # shared singleton, no alloc
    with tr.span("x"):
        pass
    assert not tr.finished and not tr.registry.histograms
    # module helpers with no Obs installed: single None test, no effect
    prev = obs.set_current(None)
    try:
        assert obs.span("x") is _NULL_SPAN
        obs.event("tick", tick_id=1)
        obs.counter_inc("c")
        obs.gauge_set("g", 1.0)
        obs.observe("h", 1.0)
        assert obs.drain_payload() is None
    finally:
        obs.set_current(prev)


# ----------------------------------------------------- flight recorder ----

def test_flight_ring_bounded_and_dump_format(tmp_path):
    fr = FlightRecorder(cap=8)
    for i in range(20):
        fr.record("tick", tick_id=i)
    assert len(fr.events) == 8                       # ring, not a log
    assert [e["tick_id"] for e in fr.events] == list(range(12, 20))
    e = fr.events[0]
    assert e["kind"] == "tick" and e["pid"] == os.getpid()
    assert {"t", "wall", "thread"} <= set(e)
    path = fr.dump_json(str(tmp_path / "sub" / "flight.json"),
                        reason="chaos_drill")
    d = json.loads(open(path).read())
    assert d["reason"] == "chaos_drill" and d["n_events"] == 8
    assert [ev["tick_id"] for ev in d["events"]] == list(range(12, 20))
    fr_off = FlightRecorder(cap=8, enabled=False)
    fr_off.record("tick", tick_id=0)
    assert not fr_off.events


def test_flight_cross_process_clock_normalization():
    """The ordering fix: a child whose perf_counter epoch differs from the
    parent's ships its perf->wall offset with its events; ingest
    renormalizes each wall from the raw ``t``, so the merged dump is a
    true cross-process timeline."""
    parent = FlightRecorder(cap=32)
    parent.record("before")
    child = FlightRecorder(cap=32)
    child.record("child_a")
    child.record("child_b")
    parent.record("after")
    # simulate a child perf_counter epoch 500s behind whose shipped walls
    # are garbage (as a stepping wall clock would produce): the raw t
    # shifts, the wall is corrupt, and the shipped offset fixes both
    skew = 500.0
    shipped = [dict(e, t=e["t"] - skew, wall=e["t"] - skew)
               for e in child.drain()]
    # without renormalization the corrupt walls sort before everything
    naive = FlightRecorder(cap=32)
    naive.record("anchor")
    naive.ingest([dict(e) for e in shipped])          # no offset shipped
    kinds = [e["kind"] for e in naive.dump("naive")["events"]]
    assert kinds[0] != "anchor"                       # corrupt order
    # with the handshake offset the merged dump is a true timeline
    parent.ingest(shipped, clock_offset=child.clock_offset + skew)
    d = parent.dump("clock_test")
    assert [e["kind"] for e in d["events"]] == [
        "before", "child_a", "child_b", "after"]
    walls = [e["wall"] for e in d["events"]]
    assert walls == sorted(walls)


# ------------------------------------------- cross-process propagation ----

def test_payload_drain_and_ingest_roundtrip(obs_env):
    parent = obs_env(enabled=True, trace=True)
    child = obs.Obs(ObsConfig(enabled=True, trace=True))
    with child.tracer.span("leaf.push"):
        pass
    child.registry.inc("leaf.rounds", 3)
    child.flight.record("leaf_push", leaf_id=1, round_id=0)

    obs.set_current(child)
    payload = obs.drain_payload()
    obs.set_current(parent)
    assert payload["counters"] == {"leaf.rounds": 3}
    assert len(payload["spans"]) == 1 and len(payload["events"]) == 1

    obs.ingest_payload(payload)
    assert parent.registry.counters["leaf.rounds"].value == 3
    assert parent.registry.histograms["span.leaf.push"].count == 1
    assert parent.flight.events[0]["kind"] == "leaf_push"
    # deltas: a second drain with no new activity ships nothing
    obs.set_current(child)
    assert obs.drain_payload() is None


def test_process_leaf_obs_surfaces_in_parent(obs_env):
    """Real spawned ingest-leaf processes: child counters, span histograms,
    and flight events all land in the parent's snapshot."""
    from repro.ingest import IngestTier

    o = obs_env(enabled=True, trace=True)
    batches = agg_stream(n_ticks=3)
    tier = IngestTier(batches, N_SRC, 2, worker="process", leaf_cap=32,
                      root_cap=64)
    list(tier)
    snap = o.snapshot()
    validate_snapshot(snap)
    assert snap["counters"]["leaf.rounds"] >= 2 * 3   # 2 leaves x 3+ rounds
    assert snap["counters"]["leaf.tuples_ready"] > 0
    assert snap["histograms"]["span.leaf.push"]["count"] >= 2 * 3
    pids = {e["pid"] for e in o.flight.events if e["kind"] == "leaf_push"}
    assert pids and os.getpid() not in pids            # shipped from children
    assert len(pids) == 2                              # one per leaf process


# ----------------------------------------------------------- MetricsBus ----

def test_metrics_bus_bounded_retention_exact_totals():
    from repro.io.metrics import MetricsBus

    bus = MetricsBus(window=4, retain=8)
    bus.start()
    lats = [0.001 * (i % 10 + 1) for i in range(100)]
    for i, lat in enumerate(lats):
        bus.record_tick(i, 10, lat, None, 0, n_active=2)
    bus.stop()
    assert len(bus.records) == 8                      # bounded
    assert bus.n_ticks == 100                         # exact
    assert bus.total_tuples == 1000                   # exact
    p50, p99 = bus.latency_quantiles_ms()             # sketch fallback
    # empirical (non-interpolated) quantiles: the sketch's contract
    e50, e99 = np.percentile(np.asarray(lats) * 1e3, [50, 99],
                             method="lower")
    assert abs(p50 - e50) / e50 < 0.10
    assert abs(p99 - e99) / e99 < 0.10
    assert bus.measured_rate_tps() > 0


def test_metrics_bus_exact_quantiles_before_eviction():
    from repro.io.metrics import MetricsBus

    bus = MetricsBus(retain=64)
    for i in range(10):
        bus.record_tick(i, 1, 0.002, None, 0)
    p50, p99 = bus.latency_quantiles_ms()
    assert p50 == pytest.approx(2.0) and p99 == pytest.approx(2.0)


def test_unresolved_detections_flushed_at_stop(obs_env):
    from repro.io.metrics import MetricsBus

    o = obs_env(enabled=True)
    bus = MetricsBus()
    bus.start()
    bus.record_detection(epoch=1, tick_id=3, rc="rc1")
    bus.record_detection(epoch=2, tick_id=5, rc="rc2")
    assert bus.record_switch(4) == ["rc1"]            # resolves tick<=4
    bus.stop()
    assert len(bus.unresolved_detections) == 1        # rc2 never switched
    assert bus.unresolved_detections[0][2] == 5
    assert not bus._pending_detections                # leak flushed
    assert o.registry.counters["bus.unresolved_detections"].value == 1
    kinds = [e["kind"] for e in o.flight.events]
    assert "unresolved_detections" in kinds


# ------------------------------------------------------------- wiring -----

def test_runtime_config_obs_json_roundtrip():
    cfg = api.RuntimeConfig(obs=ObsConfig(enabled=True, trace=True,
                                          dump_dir="/tmp/x"))
    d = json.loads(json.dumps(cfg.to_json()))
    back = api.RuntimeConfig.from_json(d)
    assert isinstance(back.obs, ObsConfig)
    assert back.obs == cfg.obs and back == cfg


def test_runtime_end_to_end_stage_breakdown(obs_env):
    """build_runtime with obs on: every instrumented stage appears in the
    report's per-tick latency breakdown, bus counters match the report,
    and the exported snapshot validates against the schema."""
    from repro.io.sources import ReplaySource

    obs_env(enabled=False)      # build_runtime installs from the config
    batches = agg_stream(n_ticks=6)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=64, n_sources=N_SRC,
        ingest_hosts=2, leaf_cap=32, root_cap=64,
        controller="threshold", capacity_per_instance=50.0,
        obs=ObsConfig(enabled=True, trace=True))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    rep = rt.run()
    o = obs.get()
    assert o is not None and o.cfg.trace
    stages = set(rep.stage_latency_ms)
    assert {"ingest.stage", "leaf.push", "root.merge", "runtime.dispatch",
            "runtime.drain", "controller.decide"} <= stages
    snap = o.snapshot()
    validate_snapshot(snap)
    assert snap["counters"]["bus.ticks"] == rep.ticks
    assert snap["counters"]["leaf.rounds"] > 0
    assert snap["counters"]["root.rounds"] > 0
    kinds = {e["kind"] for e in o.flight.events}
    assert {"tick", "leaf_push", "controller_decide"} <= kinds
    ticks = [e for e in o.flight.events if e["kind"] == "tick"]
    assert {"tick_id", "n_tuples", "latency_ms", "queue_depth",
            "wmark_frontier"} <= set(ticks[0])


def test_chaos_failure_dumps_flight_timeline(tmp_path, obs_env):
    """The acceptance drill: a SIGKILLed process ingest leaf mid-stream
    crashes the runtime, and the flight-recorder JSON dump contains the
    failing tick's timeline across leaf, root/tier, runtime, and
    controller events — with the child processes' events interleaved."""
    from repro.ingest import LeafFailure
    from repro.io.sources import ReplaySource
    from repro.launch.recovery import _kill_leaf_when

    obs_env(enabled=False)
    dump_dir = tmp_path / "dump"
    batches = agg_stream(n_ticks=12, tick=32)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=256, n_sources=N_SRC,
        ingest_hosts=2, ingest_worker="process", chan_cap=2,
        leaf_cap=128, root_cap=256,
        checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=4,
        controller="threshold", capacity_per_instance=1.0,
        obs=ObsConfig(enabled=True, trace=True, dump_dir=str(dump_dir)))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    wd = threading.Thread(target=_kill_leaf_when, args=(rt.tier, 6),
                          daemon=True)
    wd.start()
    with pytest.raises(LeafFailure):
        rt.run()

    dumps = glob.glob(str(dump_dir / "flight-*.json"))
    assert dumps, "chaos failure produced no flight dump"
    d = json.loads(open(dumps[0]).read())
    assert "ingest_error" in d["reason"] or "runtime_crash" in d["reason"]
    kinds = {e["kind"] for e in d["events"]}
    # the four layers of the failing timeline
    assert "leaf_push" in kinds                       # leaf tier (children)
    assert "leaf_failure" in kinds                    # root/tier detection
    assert "tick" in kinds                            # runtime drain loop
    assert "controller_decide" in kinds               # control loop
    assert "tier_snapshot" in kinds                   # checkpoint cut rode by
    fail = [e for e in d["events"] if e["kind"] == "leaf_failure"][0]
    assert "leaf_id" in fail and "round_id" in fail
    # child events shipped over the channels, interleaved by wall clock
    pids = {e["pid"] for e in d["events"]}
    assert len(pids) >= 2 and os.getpid() in pids
    walls = [e["wall"] for e in d["events"]]
    assert walls == sorted(walls)                     # dump is a timeline
