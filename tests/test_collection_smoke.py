"""Collection smoke: every test module and every library entry point must
import under the installed jax — the failure mode this guards against is a
jax API move (e.g. ``from jax import shard_map``) breaking collection of
half the suite without any test reporting it."""

import importlib
import pathlib
import sys

import pytest

TESTS_DIR = pathlib.Path(__file__).parent
TEST_MODULES = sorted(p.stem for p in TESTS_DIR.glob("test_*.py"))

LIB_MODULES = [
    "repro.compat",
    "repro.kernels.dispatch",
    "repro.kernels.scalegate_merge.ops",
    "repro.kernels.segment_aggregate.ops",
    "repro.kernels.window_join.ops",
    "repro.kernels.flash_attention.ops",
    "repro.kernels.linear_scan.ops",
    "repro.core.scalegate",
    "repro.core.aggregate",
    "repro.core.join",
    "repro.core.vsn",
    "repro.core.runtime",
    "repro.models.moe",
    "repro.launch.train",
]


@pytest.mark.parametrize("mod", TEST_MODULES)
def test_test_module_imports(mod):
    if str(TESTS_DIR) not in sys.path:
        sys.path.insert(0, str(TESTS_DIR))
    importlib.import_module(mod)


@pytest.mark.parametrize("mod", LIB_MODULES)
def test_library_module_imports(mod):
    importlib.import_module(mod)


def test_shard_map_call_sites_use_compat():
    """No module may import shard_map from jax directly — only via compat
    (the 0.4.x/0.6 move is exactly what broke the seed)."""
    src = pathlib.Path(__file__).parent.parent / "src"
    offenders = []
    for py in src.rglob("*.py"):
        if py.name == "compat.py":
            continue
        text = py.read_text()
        if ("from jax import shard_map" in text
                or "from jax.experimental.shard_map" in text):
            offenders.append(str(py))
    assert not offenders, offenders
