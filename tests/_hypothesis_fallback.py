"""Minimal stand-in for ``hypothesis`` when it is not installed.

The test container may lack hypothesis (it is an optional test extra in
pyproject.toml).  Rather than skipping three whole property-based test
modules, ``conftest.py`` installs this module as ``hypothesis`` when the
real package is absent.  It implements the small API surface the tests use
— ``given``, ``settings``, and ``strategies.integers/lists/tuples`` — as a
deterministic random sampler: no shrinking, no database, fixed per-test
seed (derived from the test name) so failures reproduce exactly.

``max_examples`` is honored but capped (REPRO_FALLBACK_MAX_EXAMPLES,
default 15): each distinct drawn list length traces a fresh jit shape, and
the point of tier-1 is a fast green signal.  Installing the real
hypothesis restores full-strength property testing with no code changes.
"""

from __future__ import annotations

import os
import zlib
from typing import Any, Callable, List

import numpy as np

_MAX_EXAMPLES_CAP = int(os.environ.get("REPRO_FALLBACK_MAX_EXAMPLES", "8"))


class SearchStrategy:
    def example(self, rng: np.random.Generator) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Lists(SearchStrategy):
    """Lengths are drawn from <= 3 bucketed sizes spanning [min, max], not
    the full range: every distinct length is a fresh jit trace for the
    array-shaped tests, and a few examples over {min, mid, max} exercise
    the same boundaries at a fraction of the compile cost."""

    def __init__(self, elem: SearchStrategy, min_size=0, max_size=10):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10
        span = self.max_size - self.min_size
        self.sizes = sorted({self.min_size, self.min_size + span // 2,
                             self.max_size})

    def example(self, rng):
        n = self.sizes[int(rng.integers(0, len(self.sizes)))]
        return [self.elem.example(rng) for _ in range(n)]


class _Tuples(SearchStrategy):
    def __init__(self, *elems: SearchStrategy):
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _Booleans(SearchStrategy):
    def example(self, rng):
        return bool(rng.integers(0, 2))


class _Floats(SearchStrategy):
    def __init__(self, lo=0.0, hi=1.0):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(SearchStrategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return self.options[int(rng.integers(0, len(self.options)))]


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements, *, min_size: int = 0, max_size: int = None):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def tuples(*elements):
        return _Tuples(*elements)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_ignored):
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


def settings(max_examples: int = 100, deadline=None, **_ignored) -> Callable:
    """Decorator recording example budget; composes under ``given``."""
    def apply(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return apply


def given(*strats: SearchStrategy) -> Callable:
    """Run the test body over deterministically sampled examples."""
    def wrap(fn):
        budget = getattr(fn, "_fallback_max_examples", 100)
        n_examples = max(1, min(budget, _MAX_EXAMPLES_CAP))
        seed = zlib.crc32(fn.__qualname__.encode())

        def runner(*pytest_args, **pytest_kwargs):
            rng = np.random.default_rng(seed)
            for i in range(n_examples):
                example = [s.example(rng) for s in strats]
                try:
                    fn(*example, *pytest_args, **pytest_kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: "
                        f"{example!r}") from e

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner.hypothesis_fallback = True
        return runner
    return wrap


def install() -> None:
    """Register this module as ``hypothesis`` in ``sys.modules``."""
    import sys
    import types

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__is_repro_fallback__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in dir(strategies):
        if not name.startswith("_"):
            setattr(st_mod, name, getattr(strategies, name))
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
