"""Deliverable (f): per-assigned-architecture smoke tests — reduced config,
one forward/train step on CPU, output shapes + no NaNs; plus a decode step
(every assigned arch is decoder-family)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import model as M, transformer
from repro.optim import adamw

B, S = 2, 16


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = transformer.init_params(rng, cfg)
    opt = adamw.init_opt(params)
    if cfg.frontend == "token":
        inputs = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    else:
        inputs = jax.random.normal(rng, (B, S, cfg.d_model), jnp.bfloat16)
    batch = {"inputs": inputs,
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab),
             "mask": jnp.ones((B, S), jnp.float32)}
    step = jax.jit(lambda p, o, b: M.train_step(
        p, o, b, cfg=cfg, opt_cfg=adamw.AdamWConfig(), chunk=8))
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch, rng):
    cfg = reduced(get_config(arch))
    params = transformer.init_params(rng, cfg)
    caches, states = transformer.init_caches(cfg, B, S)
    tok = (jnp.zeros((B,), jnp.int32) if cfg.frontend == "token"
           else jnp.zeros((B, cfg.d_model), jnp.bfloat16))
    logits, caches, states = jax.jit(
        lambda p, c, s, t: M.decode_step(p, c, s, t, jnp.int32(S - 1),
                                         cfg=cfg, chunk=8))(
        params, caches, states, tok)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_exact_configs_match_assignment():
    """Pin the full configs to the assignment block."""
    expect = {
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "rwkv6_7b": (32, 4096, 0, 0, 14336, 65536),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (l, d, h, kv, ff, v), arch
    # MoE structure
    ds = get_config("deepseek_moe_16b").moe
    assert (ds.n_experts, ds.top_k, ds.n_shared) == (64, 6, 2)
    qw = get_config("qwen3_moe_30b_a3b").moe
    assert (qw.n_experts, qw.top_k) == (128, 8)
    # hybrid / rwkv details
    assert get_config("hymba_1_5b").ssm_state == 16
    assert get_config("gemma3_12b").window_pattern == (1024, 6)


def test_param_counts_near_names():
    """Total parameter counts should be within ~20% of the checkpoint names."""
    targets = {"chameleon_34b": 34e9, "stablelm_12b": 12e9,
               "gemma3_12b": 12e9, "gemma3_4b": 4e9, "qwen3_14b": 14e9,
               "hymba_1_5b": 1.5e9, "deepseek_moe_16b": 16e9,
               "qwen3_moe_30b_a3b": 30e9, "rwkv6_7b": 7e9}
    for arch, t in targets.items():
        n = get_config(arch).param_count()
        assert 0.75 * t < n < 1.35 * t, (arch, n / 1e9)
    # a3b: ~3B active
    a = get_config("qwen3_moe_30b_a3b").active_param_count()
    assert 2e9 < a < 4.5e9, a / 1e9
