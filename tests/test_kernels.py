"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle.

The sweeps force ``backend="pallas-interpret"`` and are ``slow``-marked
(deselected by default; run with ``pytest -m slow`` on TPU/nightly).  The
always-on small-shape backend parity lives in test_kernel_parity.py."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.window_join.ops import window_join_op, window_join_ref_op
from repro.kernels.segment_aggregate.ops import (segment_aggregate_op,
                                                 segment_aggregate_ref_op)
from repro.kernels.scalegate_merge.ops import (scalegate_merge_op,
                                               scalegate_merge_ref_op)
from repro.kernels.flash_attention.ops import (attention_ref_op,
                                               flash_attention_op)
from repro.kernels.linear_scan.ops import linear_scan_op, linear_scan_ref_op


@pytest.mark.slow
@pytest.mark.parametrize("b,k,r,p,tile", [
    (8, 128, 4, 2, 64), (16, 256, 8, 4, 128), (4, 64, 16, 2, 64),
])
def test_window_join_sweep(b, k, r, p, tile):
    rng = np.random.default_rng(b + k)
    nt = np.sort(rng.integers(100, 300, b)).astype(np.int32)
    ns = rng.integers(0, 2, b).astype(np.int32)
    npay = rng.uniform(0, 40, (b, p)).astype(np.float32)
    st = rng.integers(0, 280, (k, r)).astype(np.int32)
    st[rng.random((k, r)) < 0.3] = -1
    ss = rng.integers(0, 2, (k, r)).astype(np.int32)
    sp = rng.uniform(0, 40, (k, r, p)).astype(np.float32)
    c1, n1 = window_join_op(nt, ns, npay, st, ss, sp, ws=60, tile_k=tile,
                            backend="pallas-interpret")
    c2, n2 = window_join_ref_op(nt, ns, npay, st, ss, sp, ws=60)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert int(n1) == int(n2)


@pytest.mark.slow
@pytest.mark.parametrize("n,k,s,w,dtype", [
    (32, 128, 2, 1, np.float32), (64, 256, 4, 3, np.float32),
    (16, 64, 1, 2, np.float32),
])
def test_segment_aggregate_sweep(n, k, s, w, dtype):
    rng = np.random.default_rng(n + k)
    keys = rng.integers(-1, k, n).astype(np.int32)
    slots = rng.integers(0, s, n).astype(np.int32)
    vals = rng.uniform(0, 1, (n, w)).astype(dtype)
    acc = rng.uniform(0, 1, (k, s, w)).astype(dtype)
    a = segment_aggregate_op(keys, slots, vals, acc, tile_k=64,
                             backend="pallas-interpret")
    b = segment_aggregate_ref_op(keys, slots, vals, acc)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("n,srcs", [(32, 2), (64, 3), (128, 5)])
def test_scalegate_merge_sweep(n, srcs):
    rng = np.random.default_rng(n)
    tau = rng.integers(0, 500, n).astype(np.int32)
    src = rng.integers(0, srcs, n).astype(np.int32)
    valid = rng.random(n) < 0.85
    o1, r1, w1 = scalegate_merge_op(tau, src, valid, n_sources=srcs,
                                    backend="pallas-interpret")
    o2, r2, w2 = scalegate_merge_ref_op(tau, src, valid, n_sources=srcs)
    assert int(w1[0]) == int(w2[0])
    assert int(r1.sum()) == int(r2.sum())
    t1 = np.asarray(tau)[np.asarray(o1)][np.asarray(valid)[np.asarray(o1)]]
    assert (np.diff(t1) >= 0).all()          # total order


@pytest.mark.slow
@pytest.mark.parametrize("causal,window,sq,skv,n_rep", [
    (True, None, 64, 64, 1), (True, 16, 64, 64, 1), (False, None, 32, 64, 1),
    (True, None, 1, 128, 1),                     # decode
    (True, None, 64, 64, 4), (True, 32, 64, 64, 2),  # GQA
])
def test_flash_attention_sweep(causal, window, sq, skv, n_rep):
    rng = np.random.default_rng(sq + skv)
    bh_kv, d = 2, 32
    q = rng.normal(0, 1, (bh_kv * n_rep, sq, d)).astype(np.float32)
    k = rng.normal(0, 1, (bh_kv, skv, d)).astype(np.float32)
    v = rng.normal(0, 1, (bh_kv, skv, d)).astype(np.float32)
    a = flash_attention_op(q, k, v, causal=causal, window=window,
                           n_rep=n_rep, blk_q=min(32, sq), blk_k=32,
                           backend="pallas-interpret")
    b = attention_ref_op(q, k, v, causal=causal, window=window, n_rep=n_rep)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("bh,t,dk,dv,chunk,bonus", [
    (2, 64, 8, 8, 16, True), (3, 128, 16, 24, 32, True),
    (2, 64, 8, 8, 16, False), (1, 256, 32, 32, 64, False),
])
def test_linear_scan_sweep(bh, t, dk, dv, chunk, bonus):
    rng = np.random.default_rng(t + dk)
    r = rng.normal(0, 1, (bh, t, dk)).astype(np.float32)
    k = rng.normal(0, 1, (bh, t, dk)).astype(np.float32)
    v = rng.normal(0, 1, (bh, t, dv)).astype(np.float32)
    w = rng.uniform(0.5, 0.99, (bh, t, dk)).astype(np.float32)
    u = rng.normal(0, 1, (bh, dk)).astype(np.float32) if bonus else None
    a = linear_scan_op(r, k, v, w, u, chunk=chunk,
                       backend="pallas-interpret")
    b = linear_scan_ref_op(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_linear_scan_matches_rwkv_block():
    """The kernel is the oracle for models/rwkv.py's time-mix recurrence."""
    from repro.models.rwkv import time_mix_forward, init_time_mix, init_rwkv_state
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=0,
                      n_kv_heads=0, d_ff=64, vocab=64, kind="rwkv",
                      rwkv_head=8, dtype="float32")
    key = jax.random.PRNGKey(0)
    p = init_time_mix(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, 32), jnp.float32)
    st = init_rwkv_state(cfg, 2)
    y, _, wkv = time_mix_forward(p, x, cfg, st["shift_tm"], st["wkv"])
    assert y.shape == (2, 16, 32)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(wkv)).max() > 0  # state actually evolved
