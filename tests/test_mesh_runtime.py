"""Mesh VSN runtime: exact output-set parity between 1-device and n-device
execution over the same tuple stream — including across a mid-stream
reconfiguration — with zero cross-device state transfer (the ISSUE-2 /
paper-§8.4 acceptance contract).

The n-way cases need n visible devices; the ``multi-device`` CI job
provides them via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
set before the first jax import.  On a bare host the 8-way cases skip and
the 1-way mesh (shard_map plumbing with n_shards=1) still runs.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import collect_outputs
from repro.core import vsn
from repro.core.aggregate import count_aggregate, fast_init
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.join import band_predicate, fast_join_init
from repro.core.join import tick_fast as join_fast
from repro.core.runtime import MeshPipeline, VSNPipeline
from repro.core.windows import WindowSpec
from repro.data import datagen
from repro.launch.mesh import collective_bytes, make_stream_mesh

K = 64
WS = WindowSpec(wa=50, ws=100, wt="multi")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 devices (XLA_FLAGS="
                      "--xla_force_host_platform_device_count=8)")


def op():
    return count_aggregate(WS, k_virt=K, out_cap=512, extra_slots=2)


def stream(n_ticks=5, seed=0):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=16,
                               words_per_tweet=3, vocab=500, k_virt=K,
                               rate_per_tick=30))


def reconfig():
    fmu = balanced_fmu(K, 3, 8)
    fmu = np.where(fmu >= 2, fmu + 1, fmu).astype(np.int32)
    active = active_mask(4, 8)
    active[2] = False
    return Reconfiguration(epoch=1, n_active=3, fmu=fmu, active=active)


def host_oracle(batches, rc_at=None):
    pipe = VSNPipeline(op(), n_max=8, n_active=4, stash_cap=64)
    outs = []
    for i, b in enumerate(batches):
        o1, o2, _ = pipe.step(b, reconfig=reconfig() if i == rc_at else None)
        outs += collect_outputs(o1) + collect_outputs(o2)
    return sorted(outs), pipe


def mesh_run(batches, n_shards, mode, rc_at=None, batched=False):
    pipe = MeshPipeline(op(), make_stream_mesh(n_shards), stash_cap=64,
                        mode=mode, n_max=8, n_active=4)
    outs = []
    if batched:
        o1, o2, sw = pipe.run(batches, reconfig=(reconfig() if rc_at
                                                 is not None else None),
                              reconfig_at=rc_at or 0)
        outs += collect_outputs(o1) + collect_outputs(o2)
        switched = int(np.asarray(sw).sum())
    else:
        switched = 0
        for i, b in enumerate(batches):
            o1, o2, sw = pipe.step(
                b, reconfig=reconfig() if i == rc_at else None)
            outs += collect_outputs(o1) + collect_outputs(o2)
            switched += int(np.asarray(sw).sum())
    return sorted(outs), switched, pipe


def test_mesh1_matches_host_pipeline():
    """shard_map plumbing on a 1-device mesh == the vmap host executor."""
    batches = stream()
    oracle, _ = host_oracle(batches)
    assert oracle
    got, _, pipe = mesh_run(batches, 1, "general")
    assert got == oracle
    assert sum(pipe.collective_bytes().values()) == 0


def test_mesh1_fast_agg_and_batched_ingest():
    batches = stream()
    oracle, _ = host_oracle(batches)
    got, _, _ = mesh_run(batches, 1, "fast-agg")
    assert got == oracle
    got_b, _, _ = mesh_run(batches, 1, "fast-agg", batched=True)
    assert got_b == oracle


@needs8
@pytest.mark.parametrize("mode", ["general", "fast-agg"])
def test_mesh8_parity(mode):
    """Identical sorted output tuples for 1-device vs 8-device runs."""
    batches = stream()
    one, _, _ = mesh_run(batches, 1, mode)
    eight, _, pipe = mesh_run(batches, 8, mode)
    assert one == eight
    assert sum(pipe.collective_bytes().values()) == 0


@needs8
@pytest.mark.parametrize("batched", [False, True])
def test_mesh8_reconfig_zero_transfer(batched):
    """The acceptance gate: 8-way parity across a mid-stream f_mu switch
    with measured cross-device state transfer of 0 bytes."""
    batches = stream(n_ticks=6)
    oracle, hp = host_oracle(batches, rc_at=2)
    got, switched, pipe = mesh_run(batches, 8, "general", rc_at=2,
                                   batched=batched)
    assert got == oracle
    assert switched == 1 and int(pipe.epoch.reconfigs) == 1
    # zero bytes crossed devices (every compiled step variant's HLO)
    assert pipe.collective_bytes() == {}
    # the switch itself moved only the replicated tables (vsn_switch_bytes)
    assert pipe.switch_bytes() == 4 * K + 8 + 12
    # ... while the SN baseline's sn_transfer ships sigma rows for the
    # very same reconfiguration (the Fig. 9 story)
    from repro.core.runtime import SNPipeline
    sn = SNPipeline(op(), n_max=8, n_active=4, stash_cap=64)
    for i, b in enumerate(batches):
        sn.step(b, reconfig=reconfig() if i == 2 else None)
    assert sn.bytes_transferred > 0


@needs8
def test_mesh8_batched_equals_per_tick():
    """Batched multi-tick ingest (scan inside one shard_map call) produces
    exactly the per-tick outputs."""
    batches = stream(n_ticks=6)
    per_tick, _, _ = mesh_run(batches, 8, "fast-agg")
    batched, _, _ = mesh_run(batches, 8, "fast-agg", batched=True)
    assert per_tick == batched


# --------------------------------------------------------------- join -----

JWS = WindowSpec(wa=1, ws=5000, wt="single")
FJ = band_predicate(500.0, 2)


def join_stream(n_ticks=5):
    rng = np.random.default_rng(3)
    return list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=32, k_virt=1))


def join_collect(outs):
    tau = np.asarray(outs.tau).reshape(-1)
    val = np.asarray(outs.valid).reshape(-1)
    pay = np.asarray(outs.payload)
    pay = pay.reshape(-1, pay.shape[-1])
    return sorted((int(t), tuple(np.round(p, 3)))
                  for t, p, ok in zip(tau, pay, val) if ok)


def run_join_mesh(n_shards, batches):
    mesh = make_stream_mesh(n_shards)
    sigma = fast_join_init(K, 8, 4)
    sigma = dataclasses.replace(
        sigma, comparisons=jnp.zeros((n_shards,), jnp.float32))
    sigma = vsn.mesh_device_put(sigma, mesh, "i", K)
    step = jax.jit(vsn.shard_tick(
        mesh, "i", K, vsn.join_local_tick(JWS, FJ, K, out_cap=2048), sigma))
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
    sigma, outs = step(sigma, stack)
    hlo = step.lower(sigma, stack).compile().as_text()
    return join_collect(outs), np.asarray(sigma.comparisons), hlo


def test_join_mesh1_matches_monolithic():
    batches = join_stream()
    st = fast_join_init(K, 8, 4)
    resp = jnp.ones((K,), bool)
    oracle, comps = [], 0.0
    for b in batches:
        st, outs = join_fast(JWS, FJ, st, b, resp, out_cap=2048)
        oracle += join_collect(outs)
        comps += float(st.comparisons)
    got, comps_mesh, _ = run_join_mesh(1, batches)
    assert sorted(oracle) == got
    assert comps_mesh.sum() == pytest.approx(comps)


@needs8
def test_join_mesh8_parity_and_work_partition():
    """q3-style join stream: 1-shard vs 8-shard output parity; comparisons
    partition exactly (Pi-invariant total) with zero collectives."""
    batches = join_stream()
    one, comps1, _ = run_join_mesh(1, batches)
    eight, comps8, hlo = run_join_mesh(8, batches)
    assert one == eight
    assert comps8.sum() == pytest.approx(comps1.sum())
    assert (comps8 > 0).all()          # every shard did a share of the work
    assert collective_bytes(hlo) == {}
