"""Live runtime: async double-buffered ingest == synchronous host loop.

The contracts under test (ISSUE-3 acceptance):
  * exact output-set parity, async vs sync, on q1-style aggregation and
    q3-style join streams;
  * parity holds across a controller-triggered mid-stream reconfiguration,
    and the live elastic run matches the static max-width oracle;
  * the bounded in-flight queue never exceeds its cap under a slow
    consumer (backpressure blocks the producer instead of growing memory);
  * per-instance load and detection→switch latency are exposed to the
    metrics loop.
"""

import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import collect_outputs
from repro.core.aggregate import count_aggregate
from repro.core.async_runtime import AsyncStreamRuntime, run_sync, tick_meta
from repro.core.controller import ThresholdController
from repro.core.join import band_predicate, fast_join_init, scalejoin_def
from repro.core.join import tick_fast as join_fast
from repro.core.runtime import VSNPipeline
from repro.core.vsn import merge_fast_state
from repro.core.windows import WindowSpec
from repro.data import datagen
from repro.io import (TIMEOUT, BoundedQueue, QueueClosed, RateSchedule,
                      ReplaySource, SyntheticSource, load_stream,
                      save_stream)

K = 64
WS = WindowSpec(wa=50, ws=100, wt="multi")


def agg_op():
    return count_aggregate(WS, k_virt=K, out_cap=512, extra_slots=2)


def agg_stream(n_ticks=6, seed=0):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=16,
                               words_per_tweet=3, vocab=500, k_virt=K,
                               rate_per_tick=30))


def agg_pipe(n_active=4, n_max=8):
    return VSNPipeline(agg_op(), n_max=n_max, n_active=n_active,
                       stash_cap=64)


# ------------------------------------------------------------- parity -----

def test_async_matches_sync_q1_style():
    batches = agg_stream()
    rt = AsyncStreamRuntime(agg_pipe(), ReplaySource(batches), queue_cap=3)
    rep = rt.run()
    _, sink = run_sync(agg_pipe(), ReplaySource(batches))
    assert rt.sink.results() == sink.results()
    assert rep.ticks == len(batches)
    assert rt.sink.results()          # non-trivial stream
    assert rep.queue_high_water <= 3


def test_async_matches_sync_q3_style_join():
    jws = WindowSpec(wa=1, ws=5000, wt="single")
    fj = band_predicate(500.0, 2)
    op = scalejoin_def(jws, K, fj, payload_width=4, ring=8)

    def join_tick(op_, st, ready, resp, explicit_w=None):
        return join_fast(jws, fj, st, ready, resp, out_cap=2048)

    def pipe():
        return VSNPipeline(op, n_max=4, n_active=4, stash_cap=16,
                           tick_fn=join_tick, merge_fn=merge_fast_state,
                           init_sigma=lambda: fast_join_init(K, 8, 4))

    rng = np.random.default_rng(3)
    batches = list(datagen.scalejoin(rng, n_ticks=5, tick=32, k_virt=1))
    rt = AsyncStreamRuntime(pipe(), ReplaySource(batches, n_inputs=2),
                            queue_cap=2)
    rt.run()
    _, sink = run_sync(pipe(), ReplaySource(batches, n_inputs=2))
    assert rt.sink.results() == sink.results()
    assert rt.sink.results()


def test_async_reconfig_parity_and_static_oracle():
    """A controller-triggered mid-stream reconfiguration: the live run's
    outputs equal (a) a sync run replaying the same reconfig trace and
    (b) the static max-width oracle."""
    batches = agg_stream(n_ticks=8)
    # 2 x 2000 t/s capacity; the 9000 t/s phase crosses the 0.90 threshold
    sched = RateSchedule(((3, 1500.0), (5, 9000.0)))
    ctl = ThresholdController(n_max=8, k_virt=K,
                              capacity_per_instance=2000.0, n_active=2)
    rt = AsyncStreamRuntime(agg_pipe(n_active=2),
                            ReplaySource(batches, schedule=sched),
                            controller=ctl, queue_cap=3)
    rep = rt.run()
    assert rep.reconfig_trace, "the rate spike never triggered the controller"
    assert rep.switches >= 1
    assert len(rep.detect_to_switch_ms) == len(rep.detect_to_switch_ticks)
    # every switch resolves >= 1 detection; coalesced reconfigs mean a
    # single switch may resolve several, but none can outlive the run by
    # more than the still-pending tail
    assert rep.switches <= len(rep.detect_to_switch_ms)
    assert len(rep.detect_to_switch_ms) <= len(rep.reconfig_trace)
    assert all(d >= 0.0 for d in rep.detect_to_switch_ms)

    outs = rt.sink.results()
    _, replay_sink = run_sync(agg_pipe(n_active=2), ReplaySource(batches),
                              reconfig_trace=rep.reconfig_trace)
    assert outs == replay_sink.results()

    _, oracle_sink = run_sync(agg_pipe(n_active=8), ReplaySource(batches))
    assert outs == oracle_sink.results()


def test_no_spurious_scaledown_before_rate_signal():
    """Without a rate hint, the controller must not act until a measured
    rate exists — at stream start the measured rate is 0.0, which would
    otherwise read as idle and collapse capacity on the first tick."""
    batches = agg_stream(n_ticks=4)
    ctl = ThresholdController(n_max=8, k_virt=K,
                              capacity_per_instance=2000.0, n_active=4)
    rt = AsyncStreamRuntime(agg_pipe(n_active=4), ReplaySource(batches),
                            controller=ctl, queue_cap=2)
    rep = rt.run()
    assert all(t >= 2 for t, _ in rep.reconfig_trace)


def test_sync_controller_matches_static_oracle():
    """The closed loop through run_sync (controller consulted per tick)
    also stays exact — elasticity never changes the output set."""
    batches = agg_stream(n_ticks=8)
    sched = RateSchedule(((2, 1500.0), (3, 9000.0), (3, 400.0)))
    ctl = ThresholdController(n_max=8, k_virt=K,
                              capacity_per_instance=2000.0, n_active=2)
    rep, sink = run_sync(agg_pipe(n_active=2),
                         ReplaySource(batches, schedule=sched),
                         controller=ctl)
    assert rep.reconfig_trace
    _, oracle_sink = run_sync(agg_pipe(n_active=8), ReplaySource(batches))
    assert sink.results() == oracle_sink.results()


# ------------------------------------------------------ metrics/load -----

def test_per_instance_load_exposed():
    pipe = agg_pipe(n_active=4)
    b = agg_stream(n_ticks=1)[0]
    _, _, _, inst_load = pipe.step_staged(pipe.stage(b))
    load = np.asarray(inst_load)
    assert load.shape == (8,)
    # 16 tuples x 3 keys routed to the 4 active instances
    assert load.sum() == 48
    assert (load[4:] == 0).all()

    # the host-side fallback (mesh path) agrees with the device count
    meta = tick_meta(b, 0, 1, K, np.zeros((1,), np.int64))
    fmu = np.asarray(pipe.epoch.fmu)
    host_load = np.bincount(fmu, weights=meta.key_hist, minlength=8)
    np.testing.assert_array_equal(host_load, load)


def test_snapshot_pairs_load_with_observed_active():
    """A load sample is judged under the active count it was measured
    with, not whatever the shadow says later (no phantom skew)."""
    from repro.io import MetricsBus
    m = MetricsBus()
    m.start()
    m.record_tick(0, 10, 0.01, np.array([5.0, 5.0, 0.0, 0.0]), 0,
                  n_active=2)
    snap = m.snapshot(rate_hint=100.0)
    assert snap.n_active_observed == 2
    assert snap.load_skew(snap.n_active_observed) == 1.0


def test_detection_to_switch_accounting():
    batches = agg_stream(n_ticks=6)
    sched = RateSchedule(((2, 1500.0), (4, 9000.0)))
    ctl = ThresholdController(n_max=8, k_virt=K,
                              capacity_per_instance=2000.0, n_active=2)
    rt = AsyncStreamRuntime(agg_pipe(n_active=2),
                            ReplaySource(batches, schedule=sched),
                            controller=ctl, queue_cap=2)
    rep = rt.run()
    assert rep.switches >= 1
    # switch can never be observed before its detection
    assert all(t >= 0 for t in rep.detect_to_switch_ticks)


# ------------------------------------------------------- backpressure -----

def test_bounded_queue_backpressure_slow_consumer():
    """Depth never exceeds the cap while a fast producer feeds a slow
    consumer; the producer blocks instead."""
    q = BoundedQueue(3)
    seen, depths = [], []

    def produce():
        for i in range(20):
            q.put(i)
        q.close()

    t = threading.Thread(target=produce)
    t.start()
    try:
        while True:
            depths.append(q.depth)
            item = q.get(timeout=5)
            if item is TIMEOUT:
                pytest.fail("starved: producer made no progress in 5s")
            seen.append(item)
            time.sleep(0.002)       # slow consumer
    except QueueClosed:
        pass
    t.join()
    assert seen == list(range(20))  # FIFO, nothing lost
    assert q.high_water <= 3        # never exceeded the cap
    assert max(depths) <= 3
    assert q.blocked_puts > 0       # the producer actually blocked


def test_bounded_queue_put_after_close_raises():
    q = BoundedQueue(2)
    q.close()
    with pytest.raises(QueueClosed):
        q.put(1)
    with pytest.raises(QueueClosed):
        q.get()


def test_bounded_queue_get_disambiguates_timeout_from_close():
    """Regression (ISSUE-4 satellite): ``get`` used to look the same on a
    timed-out wait and on end-of-stream.  Now: TIMEOUT sentinel while the
    queue is open, items enqueued before close still drain, and only the
    drained+closed queue raises QueueClosed."""
    q = BoundedQueue(2)
    assert q.get(timeout=0.01) is TIMEOUT      # open + empty: not an end
    q.put("a")
    q.put("b")
    q.close()
    assert q.get(timeout=0.01) == "a"          # close never loses items
    assert q.get() == "b"
    with pytest.raises(QueueClosed):           # ...and only then ends
        q.get(timeout=0.01)


def test_runtime_queue_respects_cap():
    batches = agg_stream(n_ticks=6)
    rt = AsyncStreamRuntime(agg_pipe(), ReplaySource(batches), queue_cap=2)
    rt.run()
    assert rt.queue.high_water <= 2


# ------------------------------------------------------------ io misc -----

def test_save_load_stream_roundtrip(tmp_path):
    batches = agg_stream(n_ticks=3)
    path = str(tmp_path / "stream.npz")
    save_stream(path, batches, n_inputs=1)
    src = load_stream(path)
    assert src.n_inputs == 1 and len(src) == 3
    for a, b in zip(batches, src):
        np.testing.assert_array_equal(np.asarray(a.tau), np.asarray(b.tau))
        np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
        np.testing.assert_array_equal(np.asarray(a.payload),
                                      np.asarray(b.payload))


def test_rate_schedule():
    s = RateSchedule(((2, 100.0), (3, 900.0)))
    assert [s.rate_at(i) for i in range(7)] == [100., 100., 900., 900.,
                                                900., 900., 900.]
    assert s.total_ticks == 5


def test_paced_source_spacing():
    batches = agg_stream(n_ticks=3)
    src = SyntheticSource(batches, schedule=RateSchedule(((3, 3200.0),)),
                          pace=True, tick_size=16)
    t0 = time.perf_counter()
    got = list(src)
    dt = time.perf_counter() - t0
    assert len(got) == 3
    assert dt >= 2 * 16 / 3200.0    # at least two inter-tick gaps
