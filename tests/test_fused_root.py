"""Fused on-device root merge (ISSUE-6 tentpole): the stacked-leaf kernel
(``scalegate_merge_stacked``), its ScaleGate wrapper (``push_stacked``) and
the ``RootMerge(device=True)`` round loop.  The contracts under test:

  * kernel conformance: the dispatched stacked op equals the reference on
    fuzzed rounds — tied taus across leaves, all-invalid rows, non-trivial
    watermark reports;
  * round-for-round ready-set parity between the device root and the flat
    per-leaf host root (the ``push_stacked``-vs-``push`` contract: same
    ready set and tau grouping, tie order may differ) and against the
    single-ScaleGate oracle;
  * steady-state output-shape stability: a leaf with nothing ready still
    reserves its chunk, so the emitted round shape never flip-flops (the
    persistent super-batcher depends on this to fill K-tick groups);
  * the full ``IngestTier(root_device=True)`` matches the host tier and the
    oracle, including across mid-stream ``add_host``/``remove_host``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import datagen
from repro.ingest import (IngestTier, SourcePartitioner, collect_tuples,
                          emitted_taus, single_gate_stream)
from repro.ingest import leaf as L
from repro.ingest.root import RootMerge
from repro.kernels.scalegate_merge.ops import scalegate_merge_stacked_op
from repro.kernels.scalegate_merge.ref import scalegate_merge_stacked_ref

K = 64
N_SRC = 4
TICK = 16


def agg_stream(n_ticks=6, seed=0, n_sources=N_SRC):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=TICK,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=n_sources))


def leaf_rounds(batches, n_sources, n_leaves, cap=TICK):
    """Mirror the tier's routing: slice each tick per leaf, push through
    real LeafGates, and return the per-round LeafOut lists (+ final
    flush round)."""
    part = SourcePartitioner(n_sources, range(n_leaves))
    kmax, pw = batches[0].kmax, batches[0].payload_width
    gates = {l: L.LeafGate(l, n_sources, part.owned_mask(l), cap, kmax, pw)
             for l in part.leaves}
    rounds = []
    for r, b in enumerate(batches):
        b_np = L.batch_to_np(b)
        keep = b_np["valid"]
        leaf_of = part.assignment[np.clip(b_np["source"], 0,
                                          n_sources - 1)]
        rounds.append([gates[l].push_round(
            r, {f: b_np[f][keep & (leaf_of == l)] for f in L.FIELDS})
            for l in part.leaves])
    fin = []
    for l in part.leaves:
        gates[l].flush_all()
        fin.append(gates[l].push_round(len(batches), None, final=True))
    rounds.append(fin)
    return part, kmax, pw, rounds


def drive_root(rounds, part, kmax, pw, device, check_every=1):
    n_leaves = len(part.leaves)
    root = RootMerge(max(2 * n_leaves, n_leaves + 4), 2 * TICK, kmax, pw,
                     part.leaves, out_pad=2 * TICK, device=device,
                     check_every=check_every)
    emitted = [root.push(outs) for outs in rounds]
    root.sync_stats()
    return emitted


# ------------------------------------------------ kernel conformance ------

@pytest.mark.parametrize("rows,c,seed", [(2, 32, 0), (3, 32, 1), (4, 48, 2),
                                         (6, 96, 3)])
def test_stacked_kernel_matches_ref(rows, c, seed):
    """Fuzzed rounds: duplicate taus across leaves (forced ties), whole
    all-invalid rows, and reports that hold some taus back."""
    rng = np.random.default_rng(seed)
    tau2 = rng.integers(0, 40, (rows, c)).astype(np.int32)
    valid2 = (rng.random((rows, c)) < 0.7).astype(np.int32)
    valid2[rng.integers(rows)] = 0          # one fully-invalid row
    src2 = rng.integers(0, 4, (rows, c)).astype(np.int32)
    reports = rng.integers(5, 35, (rows,)).astype(np.int32)

    got = scalegate_merge_stacked_op(jnp.asarray(tau2), jnp.asarray(src2),
                                     jnp.asarray(valid2),
                                     jnp.asarray(reports))
    want = scalegate_merge_stacked_ref(jnp.asarray(tau2),
                                       jnp.asarray(src2),
                                       jnp.asarray(valid2),
                                       jnp.asarray(reports))
    for g, w in zip(got, want):
        assert (np.asarray(g) == np.asarray(w)).all()


def test_stacked_kernel_emits_sorted_ready_prefix():
    rng = np.random.default_rng(7)
    tau2 = rng.integers(0, 100, (4, 32)).astype(np.int32)
    valid2 = (rng.random((4, 32)) < 0.5).astype(np.int32)
    src2 = np.zeros((4, 32), np.int32)
    reports = np.full((4,), 60, np.int32)
    order2, ready2, w = scalegate_merge_stacked_op(
        jnp.asarray(tau2), jnp.asarray(src2), jnp.asarray(valid2),
        jnp.asarray(reports))
    order = np.asarray(order2).reshape(-1)
    ready = np.asarray(ready2).reshape(-1).astype(bool)
    taus = tau2.reshape(-1)[order]
    assert int(w[0]) == 60
    assert (np.diff(taus[ready]) >= 0).all(), "ready lanes out of order"
    assert (taus[ready] <= 60).all()
    # every valid tau at-or-below the watermark is released, none dropped
    assert ready.sum() == ((tau2.reshape(-1) <= 60)
                           & valid2.reshape(-1).astype(bool)).sum()


# ------------------------------------- device vs host root, per round -----

@pytest.mark.parametrize("n_leaves", [1, 2, 3])
def test_device_root_matches_host_root_per_round(n_leaves):
    batches = agg_stream()
    part, kmax, pw, rounds = leaf_rounds(batches, N_SRC, n_leaves)
    host = drive_root(rounds, part, kmax, pw, device=False)
    dev = drive_root(rounds, part, kmax, pw, device=True)
    assert len(host) == len(dev)
    for i, (h, d) in enumerate(zip(host, dev)):
        assert collect_tuples([h]) == collect_tuples([d]), \
            f"round {i}: device ready set != host ready set"
    taus = emitted_taus(dev)
    assert (np.diff(taus) >= 0).all(), "device stream lost total order"


def test_device_root_matches_single_gate_oracle():
    batches = agg_stream(n_ticks=8)
    part, kmax, pw, rounds = leaf_rounds(batches, N_SRC, 2)
    dev = drive_root(rounds, part, kmax, pw, device=True)
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    assert collect_tuples(dev) == collect_tuples(oracle)


def test_device_root_output_shape_is_stable_with_idle_leaf():
    """Source 1 ticks only every other round, so its leaf regularly has
    ZERO ready rows — yet every emitted round keeps the same lane count
    (an idle leaf still reserves its chunk).  The persistent super-batcher
    groups ticks by shape, so a flip-flopping round shape would flush
    partial K-tick groups and pay full compute for the padding."""
    from conftest import make_stream_batch

    batches = []
    for r in range(8):
        taus = [r * 10 + i for i in range(10)]
        srcs = [0] * 10
        if r % 2 == 0:               # source 1 advances every other round
            taus.append(r * 10 + 5)
            srcs.append(1)
        batches.append(make_stream_batch(taus, source=np.asarray(
            srcs, np.int32)))
    part, kmax, pw, rounds = leaf_rounds(batches, 2, 2, cap=64)
    dev = drive_root(rounds, part, kmax, pw, device=True)
    host = drive_root(rounds, part, kmax, pw, device=False)
    assert len({rb.batch for rb in dev}) == 1, \
        f"device round shapes flip-flop: {sorted({rb.batch for rb in dev})}"
    for i, (h, d) in enumerate(zip(host, dev)):
        assert collect_tuples([h]) == collect_tuples([d]), \
            f"round {i}: device ready set != host ready set"


# --------------------------------------------- full tier, with churn ------

def tier_kw(**over):
    kw = dict(worker="thread", leaf_cap=32, root_cap=64)
    kw.update(over)
    return kw


def test_tier_device_root_matches_host_tier_and_oracle():
    batches = agg_stream(n_ticks=8)
    dev = list(IngestTier(batches, N_SRC, 2,
                          **tier_kw(root_device=True, record=True)))
    host = list(IngestTier(batches, N_SRC, 2, **tier_kw()))
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    assert collect_tuples(dev) == collect_tuples(oracle)
    assert collect_tuples(dev) == collect_tuples(host)
    taus = emitted_taus(dev)
    assert (np.diff(taus) >= 0).all()


def test_tier_device_root_across_membership_change():
    """add_host/remove_host while the device root is live: leaf count (and
    with it the stacked kernel's row shape) changes mid-stream; the output
    multiset must still equal the flat oracle."""
    batches = agg_stream(n_ticks=8)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw(root_device=True))
    new_leaf = tier.add_host(at_tick=2)
    tier.remove_host(0, at_tick=5)
    outs = list(tier)
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)
    st = tier.stats()
    assert st.tuples_out == st.tuples_in
    assert 0 not in st.leaves and new_leaf in st.leaves


def test_tier_device_root_join_stream():
    rng = np.random.default_rng(3)
    batches = list(datagen.scalejoin(rng, n_ticks=6, tick=TICK, k_virt=1))
    dev = list(IngestTier(batches, 2, 2, **tier_kw(root_device=True)))
    oracle = single_gate_stream(batches, 2, cap=96)
    assert collect_tuples(dev) == collect_tuples(oracle)
