"""Direct unit tests for the elasticity controllers (§8.4-§8.5).

ThresholdController: provision the *smallest* number of new instances that
brings average load below target (0.70) when load crosses upper (0.90);
decommission the *largest* number that keeps it below target when load
drops under lower (0.45); no action inside the band.

PredictiveController: the [0.70, 0.80] band over *predicted* comparisons
(rate^2 * WS + backlog), sized to the band midpoint.

Plus the live-metrics interface both expose to the async runtime.
"""

import numpy as np
import pytest

from repro.core.controller import (LiveMetrics, PredictiveController,
                                   ThresholdController)

K = 64


def threshold(n_active=2, cap=1000.0, n_max=16):
    return ThresholdController(n_max=n_max, k_virt=K,
                               capacity_per_instance=cap, n_active=n_active)


class TestThreshold:
    def test_band_is_quiet(self):
        ctl = threshold(n_active=2)
        # load in [0.45, 0.90] x 2 instances x 1000 t/s -> no action
        for rate in (900.0, 1400.0, 1799.0):
            assert ctl.observe(rate) is None
        assert ctl.n_active == 2 and ctl.epoch == 0

    def test_provision_smallest_below_target(self):
        ctl = threshold(n_active=2)
        rc = ctl.observe(1900.0)          # load 0.95 > 0.90
        # smallest Pi with 1900 / (Pi * 1000) <= 0.70 is ceil(1900/700) = 3
        assert rc is not None and rc.n_active == 3
        assert 1900.0 / (rc.n_active * 1000.0) <= ctl.target
        # minimality: one fewer instance would sit above target
        assert 1900.0 / ((rc.n_active - 1) * 1000.0) > ctl.target
        assert rc.epoch == 1 and ctl.n_active == 3

    def test_decommission_largest_below_target(self):
        ctl = threshold(n_active=8)
        rc = ctl.observe(1900.0)          # load 0.24 < 0.45
        assert rc is not None and rc.n_active == 3   # ceil(1900/700)
        assert 1900.0 / (rc.n_active * 1000.0) <= ctl.target

    def test_boundaries_are_exclusive(self):
        ctl = threshold(n_active=2)
        assert ctl.observe(1800.0) is None   # load exactly 0.90
        assert ctl.observe(900.0) is None    # load exactly 0.45

    def test_clamped_to_n_max_and_one(self):
        ctl = threshold(n_active=2, n_max=4)
        rc = ctl.observe(100000.0)
        assert rc.n_active == 4
        ctl2 = threshold(n_active=1)
        assert ctl2.observe(0.0) is None     # already at the floor

    def test_reconfiguration_tables(self):
        ctl = threshold(n_active=2, n_max=8)
        rc = ctl.observe(1900.0)
        assert rc.fmu.shape == (K,) and rc.active.shape == (8,)
        assert set(np.unique(rc.fmu)) == set(range(rc.n_active))
        assert rc.active[:rc.n_active].all()
        assert not rc.active[rc.n_active:].any()

    def test_epoch_monotone(self):
        ctl = threshold(n_active=1)
        e = []
        for rate in (5000.0, 200.0, 8000.0):
            rc = ctl.observe(rate)
            if rc:
                e.append(rc.epoch)
        assert e == sorted(e) and len(set(e)) == len(e)


class TestPredictive:
    def ctl(self, n_active=1, cap=1e6, ws=1.0, n_max=16):
        return PredictiveController(n_max=n_max, k_virt=K,
                                    comparisons_per_s_per_instance=cap,
                                    ws_seconds=ws, n_active=n_active)

    def test_band_is_quiet(self):
        ctl = self.ctl()
        # work = rate^2 * 1.0; band [0.70, 0.80] x 1e6
        assert ctl.observe(866.0) is None     # work 7.50e5, load 0.75
        assert ctl.observe(880.0) is None     # load 0.774

    def test_scale_up_to_band_midpoint(self):
        ctl = self.ctl()
        rc = ctl.observe(1000.0)              # work 1e6, load 1.0 > 0.8
        # ceil(1e6 / (0.75 * 1e6)) = 2
        assert rc is not None and rc.n_active == 2

    def test_scale_down_when_under_band(self):
        ctl = self.ctl(n_active=8)
        rc = ctl.observe(1000.0)              # load 1e6/8e6 = 0.125 < 0.70
        assert rc is not None and rc.n_active == 2

    def test_backlog_counts_as_pending_work(self):
        quiet = self.ctl()
        assert quiet.observe(866.0) is None   # in-band without backlog
        loaded = self.ctl()
        loaded.backlog = 3e5                  # pending comparisons push over
        rc = loaded.observe(866.0)
        assert rc is not None and rc.n_active == 2

    def test_quadratic_in_rate(self):
        """Doubling the rate quadruples the work: sizing follows rate^2."""
        a, b = self.ctl(), self.ctl()
        ra = a.observe(2000.0)                # work 4e6 -> ceil(4/0.75)=6
        rb = b.observe(4000.0)                # work 16e6 -> ceil(16/.75)=22
        assert ra.n_active == 6 and rb.n_active == 16   # clamped to n_max


class TestLiveInterface:
    def test_threshold_observe_live_plain(self):
        ctl = threshold(n_active=2)
        m = LiveMetrics(rate_tps=1900.0)
        rc = ctl.observe_live(m)
        assert rc is not None and rc.n_active == 3

    def test_threshold_skew_inflates(self):
        # balanced: 1600 t/s over 2 instances is in-band (load 0.8)
        ctl = threshold(n_active=2)
        assert ctl.observe_live(LiveMetrics(
            rate_tps=1600.0, inst_load=np.array([10, 10, 0, 0]),
            n_active_observed=2)) is None
        # all work on one instance: skew 2.0 -> effective 3200 -> provision
        ctl2 = threshold(n_active=2)
        rc = ctl2.observe_live(LiveMetrics(
            rate_tps=1600.0, inst_load=np.array([20, 0, 0, 0]),
            n_active_observed=2))
        assert rc is not None and rc.n_active > 2

    def test_threshold_skew_uses_observed_not_pending(self):
        """A pending (uncommitted) provision must not inflate the skew of a
        load sample measured under the old active set: under a steady rate
        the controller settles after one decision instead of churning."""
        ctl = threshold(n_active=2, cap=1000.0, n_max=16)
        rc = ctl.observe_live(LiveMetrics(
            rate_tps=9000.0, inst_load=np.array([30, 30] + [0] * 14),
            n_active_observed=2))
        assert rc is not None and rc.n_active == 13  # ceil(9000/(0.7*1000))
        # next tick: switch not yet committed, load still measured over 2;
        # judging skew against the pending 13 would read 6.5x and cascade
        rc2 = ctl.observe_live(LiveMetrics(
            rate_tps=9000.0, inst_load=np.array([30, 30] + [0] * 14),
            n_active_observed=2))
        assert rc2 is None, "steady rate must not cascade reconfigurations"

    def test_threshold_queue_pressure(self):
        ctl = threshold(n_active=2)
        assert ctl.observe_live(LiveMetrics(
            rate_tps=1600.0, queue_depth=0, queue_cap=4)) is None
        ctl2 = threshold(n_active=2)
        rc = ctl2.observe_live(LiveMetrics(
            rate_tps=1600.0, queue_depth=4, queue_cap=4))   # 2x pressure
        assert rc is not None and rc.n_active > 2

    def test_predictive_backlog_from_queue(self):
        ctl = PredictiveController(
            n_max=16, k_virt=K, comparisons_per_s_per_instance=1e6,
            ws_seconds=1.0, n_active=1)
        assert ctl.observe_live(LiveMetrics(rate_tps=866.0)) is None
        ctl2 = PredictiveController(
            n_max=16, k_virt=K, comparisons_per_s_per_instance=1e6,
            ws_seconds=1.0, n_active=1)
        rc = ctl2.observe_live(LiveMetrics(rate_tps=866.0,
                                           backlog_tuples=400.0))
        assert rc is not None and rc.n_active >= 2

    def test_load_skew_edge_cases(self):
        assert LiveMetrics(rate_tps=1.0).load_skew() == 1.0
        assert LiveMetrics(rate_tps=1.0,
                           inst_load=np.zeros(4)).load_skew() == 1.0
        assert LiveMetrics(rate_tps=1.0,
                           inst_load=np.array([4, 4, 4, 4])).load_skew() == 1.0
