"""Hierarchical multi-host ScaleGate (repro.ingest) — ISSUE-4 acceptance.

The contracts under test:
  * exact output-set parity between N-leaf hierarchical ingest and the
    single-ScaleGate oracle, on q1-style aggregation and q3-style join
    streams — per round while membership is static, as a multiset across a
    mid-stream ``add_host``/``remove_host`` (the reconfig rounds shift tick
    boundaries but never the content);
  * the merged ready stream stays totally ordered and the root watermark
    never regresses (RootMerge additionally asserts both on every round);
  * membership changes move zero tuple state and report attach/detach
    latency;
  * backpressure: a slow tier consumer stalls the source iterator through
    the bounded channels;
  * stash overflow is counted and surfaced (warning + stats) at both the
    leaf and root levels, including under a mid-stream remove_host flush;
  * the ``merge_order`` tie-break contract is explicit per backend, the
    two contracts agree on everything but the tie order, and the root
    merge tolerates either.
"""

import threading
import time
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import scalegate, tuples as T
from repro.core import watermark as wm
from repro.data import datagen
from repro.ingest import (IngestTier, SourcePartitioner, collect_tuples,
                          emitted_taus, single_gate_stream)

K = 64
N_SRC = 4


def agg_stream(n_ticks=6, seed=0, tick=16, n_sources=N_SRC):
    """q1-style: multi-key aggregation tuples spread over n_sources."""
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=n_sources))


def join_stream(n_ticks=5, seed=3, tick=16):
    """q3-style: the two-stream band-join workload (source = L/R)."""
    rng = np.random.default_rng(seed)
    return list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=tick, k_virt=1))


def tier_kw(**over):
    kw = dict(worker="thread", leaf_cap=32, root_cap=64)
    kw.update(over)
    return kw


def assert_ordered(outs):
    taus = emitted_taus(outs)
    assert (np.diff(taus) >= 0).all(), "ready stream lost total order"


# ----------------------------------------------------------- parity -------

@pytest.mark.parametrize("worker", ["inline", "thread"])
@pytest.mark.parametrize("n_leaves", [1, 2, 3])
def test_parity_q1_style(worker, n_leaves):
    batches = agg_stream()
    tier = IngestTier(batches, N_SRC, n_leaves, **tier_kw(worker=worker))
    outs = list(tier)
    assert_ordered(outs)
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    # static membership: the tier is round-for-tick exact, not just a
    # multiset — every data round emits exactly the oracle's ready set
    assert len(outs) == len(oracle)           # n_ticks + final flush
    for got, want in zip(outs, oracle):
        assert collect_tuples([got]) == collect_tuples([want])
    st = tier.stats()
    assert st.tuples_out == st.tuples_in
    assert st.total_overflow == 0


def test_parity_q3_style_join_stream():
    batches = join_stream()
    tier = IngestTier(batches, 2, 2, **tier_kw())
    outs = list(tier)
    assert_ordered(outs)
    oracle = single_gate_stream(batches, 2, cap=96)
    for got, want in zip(outs, oracle):
        assert collect_tuples([got]) == collect_tuples([want])
    assert tier.stats().tuples_out > 0


def test_parity_across_add_and_remove_host():
    """Hosts join and leave mid-stream: the output multiset still exactly
    equals the flat oracle, order and watermark monotonicity hold (the
    root asserts them every round), and both membership latencies are
    measured."""
    batches = agg_stream(n_ticks=8)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw())
    new_leaf = tier.add_host(at_tick=2)
    tier.remove_host(0, at_tick=5)
    outs = list(tier)
    assert_ordered(outs)
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)
    st = tier.stats()
    assert st.tuples_out == st.tuples_in
    assert 0 not in st.leaves and new_leaf in st.leaves
    assert len(st.attach_ms) == 1 and len(st.detach_ms) == 1
    assert st.attach_ms[0] >= 0 and st.detach_ms[0] >= 0


def test_parity_join_stream_across_membership_change():
    batches = join_stream(n_ticks=7)
    tier = IngestTier(batches, 2, 1, **tier_kw())
    tier.add_host(at_tick=2)                  # 1 -> 2 leaves mid-stream
    outs = list(tier)
    assert_ordered(outs)
    oracle = single_gate_stream(batches, 2, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)


def test_process_workers_parity():
    """Leaf workers as real spawned processes (one per ingest host)."""
    batches = agg_stream(n_ticks=3)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw(worker="process"))
    outs = list(tier)
    assert_ordered(outs)
    oracle = single_gate_stream(batches, N_SRC, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)


# ------------------------------------------------- runtime integration ----

def test_tier_feeds_async_runtime_with_churn():
    """The tier as a drop-in AsyncStreamRuntime source upstream of
    stage(): pipeline outputs over the live tier (with a mid-stream host
    join) equal a sync run over the tier's recorded stream."""
    from repro.core.aggregate import count_aggregate
    from repro.core.async_runtime import AsyncStreamRuntime, run_sync
    from repro.core.runtime import VSNPipeline
    from repro.core.windows import WindowSpec
    from repro.io import ReplaySource

    op = count_aggregate(WindowSpec(wa=50, ws=100, wt="multi"), k_virt=K,
                         out_cap=512, extra_slots=2, n_inputs=N_SRC)
    batches = agg_stream(n_ticks=6)
    tier = IngestTier(batches, N_SRC, 2, record=True, **tier_kw())
    tier.add_host(at_tick=3)
    pipe = VSNPipeline(op, n_max=8, n_active=4, stash_cap=256)
    rt = AsyncStreamRuntime(pipe, tier, queue_cap=3)
    rep = rt.run()
    assert rep.ticks == len(tier.emitted)

    pipe2 = VSNPipeline(op, n_max=8, n_active=4, stash_cap=256)
    _, sink = run_sync(pipe2, ReplaySource(tier.emitted, n_inputs=N_SRC))
    assert rt.sink.results() == sink.results()
    assert rt.sink.results()


# ------------------------------------------------------- backpressure -----

def test_backpressure_reaches_source_iterator():
    """A slow tier consumer must stall the source: with bounded channels
    the router can only run ahead by the channel capacities, never the
    whole stream."""
    produced = [0]

    def counting_stream():
        for b in agg_stream(n_ticks=30):
            produced[0] += 1
            yield b

    tier = IngestTier(counting_stream(), N_SRC, 2,
                      **tier_kw(chan_cap=1))
    it = iter(tier)
    for _ in range(3):
        next(it)
    time.sleep(0.3)          # router runs as far ahead as the caps allow
    ahead = produced[0]
    assert ahead < 30, "backpressure failed: source fully drained"
    assert ahead <= 3 + 12   # 3 consumed + bounded in-flight slack
    list(it)                 # drain; shutdown must leave no stuck threads
    assert produced[0] == 30


# ------------------------------------------------ overflow accounting -----

def lagging_stream(n_ticks=5, tick=16, racer=0, crawler=1, n_sources=2):
    """Source ``racer`` runs far ahead while ``crawler`` barely advances:
    the racer's tuples cannot become ready and must stash."""
    base = 0
    for _ in range(n_ticks):
        tau = np.sort(np.concatenate([
            base + 5 + 7 * np.arange(tick - 1, dtype=np.int32),
            np.asarray([base + 1], dtype=np.int32)]))
        src = np.full((tick,), racer, np.int32)
        src[int(np.argmin(tau))] = crawler
        yield T.make_batch(tau, np.zeros((tick, 1), np.float32),
                           keys=np.zeros((tick, 1), np.int32), source=src)
        base += 2


def test_leaf_overflow_counted_and_surfaced():
    """Both lagging sources on ONE leaf: the stash pressure is leaf-local
    and must be counted there and surfaced as a warning + in stats."""
    batches = list(lagging_stream())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        tier = IngestTier(batches, 2, 1, **tier_kw(worker="inline",
                                                   leaf_cap=4, root_cap=256))
        list(tier)
    st = tier.stats()
    assert st.leaf_overflow[0] > 0
    assert any("leaf 0 stash overflow" in str(w.message) for w in rec)


def test_root_overflow_counted_and_surfaced():
    """Lagging sources on DIFFERENT leaves: each leaf's stream is locally
    ready, the stash pressure lands at the root."""
    batches = list(lagging_stream())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        tier = IngestTier(batches, 2, 2, **tier_kw(worker="inline",
                                                   leaf_cap=64, root_cap=4))
        list(tier)
    st = tier.stats()
    assert st.root_overflow > 0
    assert sum(st.leaf_overflow.values()) == 0
    assert any("root stash overflow" in str(w.message) for w in rec)


def test_overflow_under_remove_host_flush():
    """remove_host flushes the leaving leaf's stash in one round; a root
    too small for the flood must *count* the drop, not hide it."""
    batches = list(lagging_stream(n_ticks=6, tick=24))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        # leaf 0 owns the racing source and builds a large stash (its
        # crawling co-source gates W); removing it flushes that stash
        # through a 4-lane root in one round
        tier = IngestTier(batches, 2, 1, **tier_kw(worker="inline",
                                                   leaf_cap=256, root_cap=4))
        tier.add_host(at_tick=3)
        tier.remove_host(0, at_tick=4)
        outs = list(tier)
    assert_ordered(outs)
    st = tier.stats()
    assert st.root_overflow > 0, "flush overflow went uncounted"
    assert any("overflow" in str(w.message) for w in rec)
    # accounting is exact: everything not dropped was delivered
    assert st.tuples_out == st.tuples_in - st.total_overflow


# ------------------------------------------- merge_order tie contract -----

def tied_batch(n=32, n_sources=4, seed=7):
    rng = np.random.default_rng(seed)
    tau = np.sort(rng.integers(0, 6, n)).astype(np.int32)   # heavy ties
    src = rng.integers(0, n_sources, n).astype(np.int32)
    valid = rng.random(n) > 0.1
    return (jnp.asarray(tau), jnp.asarray(src), jnp.asarray(valid))


@pytest.mark.parametrize("backend,key_fields", [
    ("xla", ("tau", "source", "arrival")),
    ("pallas-interpret", ("tau", "arrival")),
])
def test_merge_order_tie_break_contract(backend, key_fields):
    """Each backend's documented tie-break is exactly what it sorts by."""
    tau, src, valid = tied_batch()
    assert scalegate.tie_break(backend) == key_fields
    order = np.asarray(scalegate.merge_order(tau, src, valid, 4,
                                             backend=backend))
    arrival = np.arange(tau.shape[0])
    cols = {"tau": np.where(np.asarray(valid), np.asarray(tau),
                            np.iinfo(np.int32).max),
            "source": np.asarray(src), "arrival": arrival}
    # np.lexsort keys: least-significant first
    want = np.lexsort(tuple(cols[f] for f in reversed(key_fields)))
    np.testing.assert_array_equal(order, want)


def test_merge_order_backends_agree_up_to_tie_order():
    """Cross-backend parity on tied-tau batches: same ready content, same
    per-tau lane groups — only the order within a tau group may differ."""
    tau, src, valid = tied_batch()
    o_xla = np.asarray(scalegate.merge_order(tau, src, valid, 4,
                                             backend="xla"))
    o_pal = np.asarray(scalegate.merge_order(tau, src, valid, 4,
                                             backend="pallas-interpret"))
    tau_np = np.where(np.asarray(valid), np.asarray(tau),
                      np.iinfo(np.int32).max)
    for o in (o_xla, o_pal):
        assert (np.diff(tau_np[o]) >= 0).all()      # both tau-sorted
    for t in np.unique(tau_np):
        g_xla = set(o_xla[tau_np[o_xla] == t].tolist())
        g_pal = set(o_pal[tau_np[o_pal] == t].tolist())
        assert g_xla == g_pal                        # identical tau groups


def test_push_ready_set_identical_across_backends():
    """scalegate.push emits the same ready multiset under either backend
    (the tie order inside a tau group is the only degree of freedom)."""
    tau, src, valid = tied_batch()
    b = T.make_batch(tau, np.zeros((tau.shape[0], 1), np.float32),
                     source=src, valid=valid)
    outs = {}
    for backend in ("xla", "pallas-interpret"):
        st = scalegate.init_scalegate(4, 32, 1, 1)
        _, out = scalegate.push(st, b, backend=backend)
        outs[backend] = collect_tuples([out])
    assert outs["xla"] == outs["pallas-interpret"]


def test_root_merge_tolerates_either_leaf_tie_break():
    """Leaves running different merge_order contracts feed the same root:
    output sets identical, order valid in both tiers."""
    batches = agg_stream(n_ticks=4)
    results = {}
    for backend in ("xla", "pallas-interpret"):
        tier = IngestTier(batches, N_SRC, 2,
                          **tier_kw(worker="inline", backend=backend))
        outs = list(tier)
        assert_ordered(outs)
        results[backend] = collect_tuples(outs)
    assert results["xla"] == results["pallas-interpret"]
    assert results["xla"] == collect_tuples(
        single_gate_stream(batches, N_SRC, cap=96))


# ------------------------------------------------------- partitioner ------

def test_partitioner_balanced_and_minimal_moves():
    p = SourcePartitioner(8, [0, 1])
    assert sorted(p.counts().values()) == [4, 4]
    moves = p.rebalance(add=[2])
    assert sorted(p.counts().values()) == [2, 3, 3]
    assert len(moves) == 2                     # minimal: only into leaf 2
    assert all(new == 2 for _, new in moves.values())

    moves = p.rebalance(remove=[0])
    assert 0 not in p.leaves
    assert sorted(p.counts().values()) == [4, 4]
    assert all(old == 0 for old, _ in moves.values())

    # disjoint cover at every step
    owned = [p.owned_mask(l) for l in p.leaves]
    assert np.logical_or.reduce(owned).all()
    assert (np.sum(owned, axis=0) == 1).all()


def test_partitioner_cannot_drop_last_leaf():
    p = SourcePartitioner(4, [0])
    with pytest.raises(AssertionError):
        p.rebalance(remove=[0])


# ------------------------------------------------- watermark helpers ------

def test_observe_explicit_and_clamp_frontier():
    st = wm.init_watermark(3)
    st = wm.observe_explicit(st, jnp.asarray([5, 7, 9]),
                             jnp.asarray([True, True, False]))
    np.testing.assert_array_equal(np.asarray(st.frontier), [5, 7, 0])
    # reports fold with max (never regress)
    st = wm.observe_explicit(st, jnp.asarray([3, 8, 0]),
                             jnp.asarray([True, True, True]))
    np.testing.assert_array_equal(np.asarray(st.frontier), [5, 8, 0])
    # the rebalance clamp lowers only the masked entry
    st = wm.clamp_frontier(st, jnp.asarray([False, True, False]), 6)
    np.testing.assert_array_equal(np.asarray(st.frontier), [5, 6, 0])
    assert int(st.value()) == 0
