"""Coverage for the sharded execution layouts + §Perf regression guards."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_stream_batch
from repro.core.join import band_predicate, fast_join_init
from repro.core.join import tick_fast as join_fast
from repro.core.windows import WindowSpec

WS = WindowSpec(wa=1, ws=60, wt="single")
FJ = band_predicate(3.0, 2)


def _stream(rng, b):
    taus = np.sort(rng.integers(0, 150, b)).astype(np.int32)
    src = rng.integers(0, 2, b).astype(np.int32)
    pay = rng.uniform(0, 12, (b, 2)).astype(np.float32)
    return make_stream_batch(taus, payload=pay, source=src)


@pytest.mark.parametrize("n_inst", [1, 2, 4])
def test_sliced_join_equals_monolithic(n_inst):
    """The owner-computes sliced layout (vsn.shard_tick's partitioning,
    used by benchmarks/q3) matches the monolithic reference: same total
    comparisons and same stored-ring contents, with zero duplicated work."""
    K, RING = 32, 8
    rng = np.random.default_rng(0)
    batches = [_stream(rng, 16) for _ in range(3)]

    # monolithic
    st_m = fast_join_init(K, RING, 2)
    comps_m = 0.0
    for b in batches:
        st_m, _ = join_fast(WS, FJ, st_m, b, jnp.ones((K,), bool),
                            out_cap=64, emit=False)
        comps_m += float(st_m.comparisons)

    # sliced
    k_loc = K // n_inst
    st_s = fast_join_init(K, RING, 2)
    st_s = jax.tree.map(
        lambda a: (a.reshape((n_inst, k_loc) + a.shape[1:])
                   if a.ndim and a.shape and a.shape[0] == K
                   else jnp.broadcast_to(a, (n_inst,) + a.shape)), st_s)
    offs = jnp.arange(n_inst) * k_loc

    def one(st_j, off, batch):
        return join_fast(WS, FJ, st_j, batch, jnp.ones((k_loc,), bool),
                         out_cap=64, emit=False, k_global=K, k_offset=off)

    comps_s = 0.0
    for b in batches:
        st_s, _ = jax.vmap(one, in_axes=(0, 0, None))(st_s, offs, b)
        comps_s += float(jnp.sum(st_s.comparisons))

    assert comps_m == comps_s
    # ring contents identical (concatenated slices == monolithic rows)
    np.testing.assert_array_equal(
        np.asarray(st_s.tau).reshape(K, RING), np.asarray(st_m.tau))
    np.testing.assert_array_equal(
        np.asarray(st_s.n).reshape(K), np.asarray(st_m.n))


def test_shard_no_opinion_regression():
    """§Perf A3 guard: all-None logical specs must NOT force replication
    (with_sharding_constraint) — they return the input untouched."""
    from jax.sharding import Mesh
    from repro.models import sharding as S

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = dict(S.DEFAULT_RULES, heads=None, head_dim=None)
    x = jnp.ones((4, 4))
    with S.use_rules(mesh, rules):
        y = S.shard(x, "heads", "head_dim")   # resolves all-None
        assert y is x                          # no constraint inserted
        assert not S.axis_resolves("heads")
        assert S.axis_resolves("mlp")


@given(st.lists(st.integers(0, 100), min_size=2, max_size=24),
       st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_scalegate_exactly_once_across_tick_partitions(taus, cut):
    """ScaleGate delivers each ready tuple exactly once regardless of how
    the stream is partitioned into ticks (Definition 6)."""
    from repro.core import scalegate
    taus = sorted(taus)
    cut = min(cut, len(taus) - 1)

    def run(parts):
        state = scalegate.init_scalegate(1, capacity=64, kmax=1,
                                         payload_width=1)
        got = []
        for part in parts:
            if not part:
                continue
            state, out = scalegate.push(state, make_stream_batch(part))
            got += [int(t) for t, ok in zip(np.asarray(out.tau),
                                            np.asarray(out.valid)) if ok]
        return got

    whole = run([taus])
    split = run([taus[:cut], taus[cut:]])
    assert whole == split == sorted(t for t in taus if t <= max(taus))
