"""Chaos/soak for the elastic ingest path (ISSUE 5 satellite).

``tests/test_ingest_tier.py`` pins *scripted* membership changes; this
module drives **seeded random** ``add_host``/``remove_host`` schedules —
including command bursts on one tick boundary and the
remove-host-during-backpressure interleaving the scripted tests never
reach — and holds the tier to the same oracle: exact output-multiset
parity with the flat single-ScaleGate run (which is also the static
oracle: the schedule must change *nothing* about the delivered stream),
total order, monotone watermark (RootMerge asserts it every round), zero
tuple-state transfer, and measured attach/detach latency for every
command.

The tier-1 versions are short and deterministic (fixed seeds, membership
simulated alongside the issued commands so every schedule is valid); the
long randomized soak across many seeds and the process-worker transport
lives behind ``@pytest.mark.slow``.
"""

import time

import numpy as np
import pytest

from repro.data import datagen
from repro.ingest import (IngestTier, collect_tuples, emitted_taus,
                          single_gate_stream)

K = 64
N_SRC = 4


def agg_stream(n_ticks=8, seed=0, tick=16):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=N_SRC))


def join_stream(n_ticks=8, seed=3, tick=16):
    rng = np.random.default_rng(seed)
    return list(datagen.scalejoin(rng, n_ticks=n_ticks, tick=tick, k_virt=1))


def tier_kw(**over):
    kw = dict(worker="thread", leaf_cap=32, root_cap=64, max_leaves=16)
    kw.update(over)
    return kw


def chaos_commands(tier, rng, n_ticks, n_leaves, max_cmds=4):
    """Issue a random but always-valid membership schedule on ``tier``.

    Membership is simulated alongside (commands release in issue order at
    nondecreasing tick boundaries, exactly like the tier's router), so a
    remove always targets a live leaf and at least one leaf survives.
    Returns the issued (kind, leaf_id, at_tick) triples.
    """
    members = set(range(n_leaves))
    issued = []
    for t in sorted(int(rng.integers(1, n_ticks)) for _ in range(max_cmds)):
        if rng.random() < 0.5:
            new = tier.add_host(at_tick=t)
            members.add(new)
            issued.append(("add", new, t))
        elif len(members) > 1:
            victim = sorted(members)[int(rng.integers(0, len(members)))]
            tier.remove_host(victim, at_tick=t)
            members.discard(victim)
            issued.append(("remove", victim, t))
    return issued


def assert_chaos_invariants(tier, outs, issued, oracle_batches):
    taus = emitted_taus(outs)
    assert (np.diff(taus) >= 0).all(), "ready stream lost total order"
    oracle = single_gate_stream(oracle_batches, N_SRC, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)
    st = tier.stats()
    assert st.tuples_out == st.tuples_in
    assert st.total_overflow == 0
    n_add = sum(1 for k, _, _ in issued if k == "add")
    assert len(st.attach_ms) == n_add
    assert len(st.detach_ms) == len(issued) - n_add
    assert all(lat >= 0 for lat in st.attach_ms + st.detach_ms)


# ------------------------------------------------------- tier-1 (short) --

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_schedule_parity(seed):
    """Random membership churn, thread workers: the delivered stream is
    tuple-for-tuple the static oracle's."""
    batches = agg_stream(n_ticks=8, seed=seed)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw())
    issued = chaos_commands(tier, np.random.default_rng(1000 + seed),
                            n_ticks=8, n_leaves=2)
    outs = list(tier)
    assert_chaos_invariants(tier, outs, issued, batches)


def test_chaos_schedule_parity_inline_worker():
    """Same chaos schedule through the synchronous inline transport (no
    threads): parity cannot depend on worker interleaving."""
    batches = agg_stream(n_ticks=8, seed=5)
    for worker in ("inline", "thread"):
        tier = IngestTier(batches, N_SRC, 3, **tier_kw(worker=worker))
        issued = chaos_commands(tier, np.random.default_rng(42),
                                n_ticks=8, n_leaves=3)
        outs = list(tier)
        assert_chaos_invariants(tier, outs, issued, batches)


def test_chaos_command_burst_single_tick():
    """All commands released on one tick boundary (add+remove+add back to
    back): each reconfig round applies alone, parity survives the burst."""
    batches = agg_stream(n_ticks=6, seed=7)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw())
    a = tier.add_host(at_tick=3)
    tier.remove_host(0, at_tick=3)
    b = tier.add_host(at_tick=3)
    outs = list(tier)
    assert_chaos_invariants(tier, outs,
                            [("add", a, 3), ("remove", 0, 3), ("add", b, 3)],
                            batches)
    st = tier.stats()
    assert 0 not in st.leaves and a in st.leaves and b in st.leaves


def test_remove_host_during_backpressure():
    """The interleaving test_ingest_tier.py doesn't reach: the consumer
    stalls (bounded channels fill, leaves block on the root channel, the
    router blocks on the leaf channels) while a remove_host releases —
    the flush round must thread through the congested channels without
    deadlock or parity loss."""
    batches = agg_stream(n_ticks=10, seed=9)
    tier = IngestTier(batches, N_SRC, 3, **tier_kw(chan_cap=1))
    tier.remove_host(1, at_tick=4)
    outs = []
    for i, out in enumerate(tier):
        if i < 6:
            time.sleep(0.05)     # slow consumer: keep every channel full
        outs.append(out)
    assert_chaos_invariants(tier, outs, [("remove", 1, 4)], batches)
    assert 1 not in tier.stats().leaves


def test_chaos_join_stream_parity():
    """The q3-style two-stream workload under churn (source = L/R: a
    rebalance moves a whole stream side between leaves)."""
    batches = join_stream(n_ticks=8)
    tier = IngestTier(batches, 2, 2, **tier_kw())
    tier.add_host(at_tick=2)
    tier.remove_host(0, at_tick=5)
    outs = list(tier)
    taus = emitted_taus(outs)
    assert (np.diff(taus) >= 0).all()
    oracle = single_gate_stream(batches, 2, cap=96)
    assert collect_tuples(outs) == collect_tuples(oracle)


# ------------------------------------- kill-and-restore drills (ISSUE 7) --

def recovery_cfg(tmp, **over):
    from repro import api
    kw = dict(op="count", wa=50, ws=100, k_virt=K, out_cap=512,
              n_max=8, n_active=4, stash_cap=64,
              n_sources=N_SRC, ingest_hosts=2, leaf_cap=32, root_cap=64,
              checkpoint_dir=str(tmp), checkpoint_every=4)
    kw.update(over)
    return api.RuntimeConfig(**kw)


def test_recovery_sigkill_leaf_mid_backpressure(tmp_path):
    """Unplanned host loss under congestion: a *process*-worker ingest
    leaf is SIGKILLed while every channel is full (chan_cap=1), plus a
    torn save planted on disk — the restore must come from the latest
    *complete* manifest and the committed+replayed output multiset must
    equal the uninterrupted oracle's, tuple for tuple (exactly-once)."""
    from repro.launch.recovery import kill_restore_drill
    batches = agg_stream(n_ticks=12, seed=21)
    cfg = recovery_cfg(tmp_path, ingest_worker="process", chan_cap=1)
    rep = kill_restore_drill(cfg, batches, mode="sigkill", crash_after=6,
                             crash_mid_save=True)
    assert rep.parity, rep.summary()
    assert rep.restored_step >= cfg.checkpoint_every
    assert rep.restored_step % cfg.checkpoint_every == 0
    assert rep.detect_to_recover_ms > 0


def test_recovery_stop_crash_mid_save_join_stream(tmp_path):
    """The q3-style two-stream workload through the full stack (tier +
    pipeline + checkpoints, thread workers): crash after 7 ticks with a
    torn newer save on disk; restore falls back to the previous complete
    step and replay closes the gap exactly."""
    from repro.launch.recovery import kill_restore_drill
    batches = join_stream(n_ticks=12, seed=23)
    cfg = recovery_cfg(tmp_path, k_virt=1, n_sources=2)
    rep = kill_restore_drill(cfg, batches, mode="stop", crash_after=7,
                             crash_mid_save=True)
    assert rep.parity, rep.summary()
    assert rep.restored_step == 4    # torn step-8 dir must be invisible
    assert rep.n_committed + rep.n_replayed == rep.n_oracle


# ------------------------------------------------------------ soak @slow --

@pytest.mark.slow
@pytest.mark.parametrize("seed", range(8))
def test_chaos_soak_many_seeds(seed):
    """Long randomized soak: more ticks, more commands, per-seed random
    leaf counts — the elastic path must never drift from the oracle."""
    rng = np.random.default_rng(seed)
    n_ticks = 16
    n_leaves = int(rng.integers(1, 4))
    batches = agg_stream(n_ticks=n_ticks, seed=seed)
    tier = IngestTier(batches, N_SRC, n_leaves, **tier_kw())
    issued = chaos_commands(tier, rng, n_ticks, n_leaves, max_cmds=6)
    outs = list(tier)
    assert_chaos_invariants(tier, outs, issued, batches)


@pytest.mark.slow
def test_chaos_soak_process_workers():
    """One soak pass over the spawned-process transport: churn parity must
    not depend on the channel implementation."""
    batches = agg_stream(n_ticks=10, seed=11)
    tier = IngestTier(batches, N_SRC, 2, **tier_kw(worker="process"))
    issued = chaos_commands(tier, np.random.default_rng(11),
                            n_ticks=10, n_leaves=2)
    outs = list(tier)
    assert_chaos_invariants(tier, outs, issued, batches)
