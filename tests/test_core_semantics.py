"""Core O+ semantics: window math, watermarks, ScaleGate, the Appendix-E
trace, Observation 1 and Lemma 2."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from conftest import collect_outputs, make_stream_batch
from repro.core import scalegate, tuples as T, watermark as wm
from repro.core.aggregate import count_aggregate, longest_aggregate
from repro.core.operator import tick as gen_tick
from repro.core.windows import WindowSpec


# ---------------------------------------------------------------- windows --
@given(st.integers(1, 20), st.integers(1, 60), st.integers(-1000, 1000))
@settings(max_examples=200, deadline=None)
def test_window_index_invariants(wa, ws_extra, tau):
    ws = wa + ws_extra  # WS > WA (sliding, §3)
    spec = WindowSpec(wa=wa, ws=ws)
    l_min, l_max = spec.window_indices(jnp.int32(tau))
    l_min, l_max = int(l_min), int(l_max)
    # tuple falls in every window of the range and no window outside it
    for l in range(l_min - 1, l_max + 2):
        inside = l * wa <= tau < l * wa + ws
        assert inside == (l_min <= l <= l_max)
    # at most ceil(WS/WA) windows (paper §2.1)
    assert 1 <= l_max - l_min + 1 <= -(-ws // wa)


def test_expiry_boundary():
    spec = WindowSpec(wa=10, ws=20)
    # window [0, 20) is expired exactly once W >= 20 (Definition 2)
    assert not bool(spec.expired(0, 19))
    assert bool(spec.expired(0, 20))


# -------------------------------------------------------------- watermark --
def test_watermark_min_over_sources():
    st_ = wm.init_watermark(3)
    st_ = wm.observe(st_, jnp.asarray([0, 1, 2]), jnp.asarray([5, 9, 3]),
                     jnp.ones(3, bool))
    assert int(st_.value()) == 3  # Definition 3: min over per-source max


def test_watermark_remove_source_unblocks():
    st_ = wm.init_watermark(2)
    st_ = wm.observe(st_, jnp.asarray([0]), jnp.asarray([50]),
                     jnp.ones(1, bool))
    assert int(st_.value()) == 0          # source 1 silent
    st_ = wm.remove_sources(st_, jnp.asarray([False, True]))
    assert int(st_.value()) == 50         # flush semantics (§6)


def test_watermark_add_source_lemma3():
    st_ = wm.init_watermark(2, active=jnp.asarray([True, False]))
    st_ = wm.observe(st_, jnp.asarray([0]), jnp.asarray([40]),
                     jnp.ones(1, bool))
    st_ = wm.add_sources(st_, jnp.asarray([False, True]), gamma=40)
    # the provisioned source starts at gamma, not 0 (Lemma 3)
    assert int(st_.value()) == 40


# -------------------------------------------------------------- scalegate --
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 100)),
                min_size=1, max_size=40))
@settings(max_examples=100, deadline=None)
def test_scalegate_invariants(items):
    """Ready tuples are sorted, exactly-once, and never exceed W."""
    n_sources = 4
    # per-source sorted streams
    per_src = {i: sorted(t for s, t in items if s == i)
               for i in range(n_sources)}
    taus, srcs = [], []
    idxs = {i: 0 for i in range(n_sources)}
    for s, _ in items:
        taus.append(per_src[s][idxs[s]])
        srcs.append(s)
        idxs[s] += 1
    state = scalegate.init_scalegate(n_sources, capacity=64, kmax=1,
                                     payload_width=1)
    batch = make_stream_batch(taus, source=np.asarray(srcs, np.int32))
    state, out = scalegate.push(state, batch)
    w = int(state.wmark.value())
    got = [(int(t), int(s)) for t, s, ok in
           zip(np.asarray(out.tau), np.asarray(out.source),
               np.asarray(out.valid)) if ok]
    # sorted
    assert all(got[i][0] <= got[i + 1][0] for i in range(len(got) - 1))
    # never beyond the watermark (Definition 3)
    assert all(t <= w for t, _ in got)
    # exactly the input tuples with tau <= w (exactly-once, Definition 6)
    expect = sorted((t, s) for t, s in zip(taus, srcs) if t <= w)
    assert sorted(got) == expect
    assert int(state.overflow) == 0


def test_scalegate_carryover():
    state = scalegate.init_scalegate(2, capacity=8, kmax=1, payload_width=1)
    b1 = make_stream_batch([5, 9], source=np.asarray([0, 0], np.int32))
    state, out1 = scalegate.push(state, b1)      # source 1 silent: W=0
    assert collect_outputs(out1) == []
    b2 = make_stream_batch([7], source=np.asarray([1], np.int32))
    state, out2 = scalegate.push(state, b2)      # W=min(9,7)=7 -> 5,7 ready
    assert [t for t, _ in collect_outputs(out2)] == [5, 7]


# ------------------------------------------------- Appendix E trace (A+) ---
def test_appendix_e_longest_tweet_trace():
    """The paper's Execution Trace 1: A+ (WA=30min, WS=1h, WT=multi) on the
    running example; we use minutes as delta ticks."""
    ws = WindowSpec(wa=30, ws=60, wt="multi")
    # virtual keys: pink=0, red=1
    op = longest_aggregate(ws, k_virt=2, out_cap=16).resolved()
    st_ = op.init_state()
    resp = jnp.ones((2,), bool)
    # 09:30->570, 09:50->590, 09:58->598; payload[0] = length
    b1 = make_stream_batch([590], keys=[[0, -1]],
                           payload=np.asarray([[11.]], np.float32), kmax=2)
    st_, _ = gen_tick(op, st_, b1, resp)
    b2 = make_stream_batch([598], keys=[[1, 0]],
                           payload=np.asarray([[13.]], np.float32), kmax=2)
    st_, _ = gen_tick(op, st_, b2, resp)
    acc = np.asarray(st_.zeta["acc"])[:, :, 0]
    occ = np.asarray(st_.occupied)
    # windows 09:00 (l=18) and 09:30 (l=19): pink=13, red=13 in both
    for l in (18, 19):
        s = l % op.slots
        assert occ[0, s] and occ[1, s]
        assert acc[0, s] == 13.0 and acc[1, s] == 13.0
    # advance watermark past 10:00 (=600): both keys output at 600 (Fig. 15)
    b3 = make_stream_batch([640], keys=[[-1, -1]], kmax=2)
    st_, outs = gen_tick(op, st_, b3, resp)
    got = collect_outputs(outs)
    assert (600, (0.0, 13.0)) in got and (600, (1.0, 13.0)) in got


# -------------------------------------------- Observation 1 and Lemma 2 ----
def test_output_timestamps_after_inputs_and_sorted():
    ws = WindowSpec(wa=5, ws=10, wt="multi")
    op = count_aggregate(ws, k_virt=4, out_cap=128).resolved()
    st_ = op.init_state()
    rng = np.random.default_rng(0)
    taus = np.sort(rng.integers(0, 200, 64))
    keys = rng.integers(0, 4, 64)
    all_out = []
    for i in range(0, 64, 16):
        b = make_stream_batch(taus[i:i + 16], keys=keys[i:i + 16])
        st_, outs = gen_tick(op, st_, b, jnp.ones((4,), bool))
        all_out += collect_outputs(outs)
    # Observation 1: every output tau exceeds every contributing input tau
    # (weakly: output tau = right boundary > window tuples)
    # Lemma 2: the f_O output stream is timestamp-sorted
    ts = [t for t, _ in all_out]
    assert ts == sorted(ts)
    assert min(ts) > int(taus.min())
