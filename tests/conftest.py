"""Shared test helpers.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512."""

try:                                   # optional test extra (pyproject.toml)
    import hypothesis                  # noqa: F401
except ImportError:                    # deterministic minimal stand-in
    from _hypothesis_fallback import install as _install_hypothesis
    _install_hypothesis()

import os
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Persistent XLA compilation cache: the suite is compile-dominated on CPU
# (hundreds of distinct jit shapes), and the cache cuts repeat tier-1 runs
# to a fraction of the cold time.  Opt out with REPRO_NO_COMPILE_CACHE=1.
if not os.environ.get("REPRO_NO_COMPILE_CACHE"):
    _cache = pathlib.Path(__file__).parent.parent / ".jax_cache"
    jax.config.update("jax_compilation_cache_dir", str(_cache))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)

from repro.core import tuples as T


def make_stream_batch(taus, keys=None, payload=None, source=None, kmax=1):
    taus = np.asarray(taus, np.int32)
    n = len(taus)
    if payload is None:
        payload = np.zeros((n, 1), np.float32)
    if keys is not None:
        keys = np.asarray(keys, np.int32)
        if keys.ndim == 1:
            keys = keys[:, None]
    return T.make_batch(jnp.asarray(taus), jnp.asarray(payload),
                        keys=None if keys is None else jnp.asarray(keys),
                        source=None if source is None else jnp.asarray(source),
                        kmax=kmax)


def collect_outputs(outs, n_instances=None):
    """Flatten (possibly per-instance stacked) Outputs to a sorted list of
    (tau, payload tuple) — the repo-wide parity currency
    (repro.io.sinks.flatten_outputs)."""
    from repro.io.sinks import flatten_outputs
    return sorted(flatten_outputs(outs))
