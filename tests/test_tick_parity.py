"""tick_fast (vectorized aggregate) vs the general O+ tick: output-emission
parity on extra_slots configs (ROADMAP item, ISSUE 2 satellite).

Intended semantics pinned here:

* ``f_MK`` returns a key *set* (Definition 4): a key repeated inside one
  tuple's KMAX-padded key array contributes exactly once.  The general path
  always honored this (union of one-hots); tick_fast's per-column scatter
  used to double-count duplicates for additive reducers — the fast path was
  wrong and is fixed by masking earlier-column duplicates.
* With a collision-free slot ring (``extra_slots`` large enough for the
  tick's window span) the two paths agree *exactly* — state, accumulators,
  and emitted outputs.  Ring overruns are counted in ``collisions`` and are
  the only licensed divergence.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import collect_outputs
from repro.core import tuples as T
from repro.core.aggregate import (count_aggregate, fast_init,
                                  longest_aggregate, tick_fast)
from repro.core.operator import tick as gen_tick
from repro.core.windows import WindowSpec

K = 8


def drive_both(op, kind, batches):
    op = op.resolved()
    resp = jnp.ones((K,), bool)
    st_g = op.init_state()
    st_f = fast_init(op)
    out_g, out_f, colls = [], [], 0
    for b in batches:
        st_g, o = gen_tick(op, st_g, b, resp)
        out_g += collect_outputs(o)
        st_f, o = tick_fast(op, kind, st_f, b, resp)
        out_f += collect_outputs(o)
        colls += int(st_f.collisions)
    return out_g, out_f, colls, st_g, st_f


def test_duplicate_keys_count_once():
    """Definition 4: the key set {4, 4} is the set {4}."""
    op = count_aggregate(WindowSpec(wa=10, ws=20, wt="multi"), k_virt=K,
                         out_cap=128, extra_slots=2)
    b1 = T.make_batch(jnp.asarray([5]), jnp.zeros((1, 1)),
                      keys=jnp.asarray([[4, 4]]), kmax=2)
    flush = T.make_batch(jnp.asarray([25]), jnp.zeros((1, 1)),
                         keys=jnp.asarray([[-1, -1]]), kmax=2)
    out_g, out_f, colls, _, _ = drive_both(op, "count", [b1, flush])
    assert colls == 0
    assert out_g == out_f
    # both windows containing tau=5 report count 1, not 2
    assert sorted(out_g) == [(10, (4.0, 1.0)), (20, (4.0, 1.0))]


@pytest.mark.parametrize("extra_slots", [1, 2, 3])
@pytest.mark.parametrize("kind,maker", [("count", count_aggregate),
                                        ("max", longest_aggregate)])
def test_three_tick_stream_parity(extra_slots, kind, maker):
    """The ROADMAP repro: drive both paths over the same 3-tick stream with
    multi-key sets (duplicates included) and padded lanes; collision-free
    configs must agree exactly on state AND emission."""
    op = maker(WindowSpec(wa=10, ws=20, wt="multi"), k_virt=K, out_cap=512,
               extra_slots=extra_slots)
    rng = np.random.default_rng(extra_slots)
    batches, tau0 = [], 0
    for _ in range(3):
        taus = np.sort(tau0 + rng.integers(0, 8, 10)).astype(np.int32)
        tau0 = int(taus.max()) + 1
        keys = rng.integers(0, K, (10, 3)).astype(np.int32)
        keys[rng.random((10, 3)) < 0.25] = -1
        valid = rng.random(10) > 0.15
        pay = rng.uniform(0, 5, (10, 1)).astype(np.float32)
        batches.append(T.make_batch(jnp.asarray(taus), jnp.asarray(pay),
                                    keys=jnp.asarray(keys),
                                    valid=jnp.asarray(valid), kmax=3))
    out_g, out_f, colls, st_g, st_f = drive_both(op, kind, batches)
    assert colls == 0, "test stream must stay within the slot ring"
    assert out_g == out_f
    np.testing.assert_allclose(np.asarray(st_g.zeta["acc"]),
                               np.asarray(st_f.op_state.zeta["acc"]))
    assert int(st_g.next_l) == int(st_f.op_state.next_l)
    assert int(st_g.watermark) == int(st_f.op_state.watermark)


def test_ring_overrun_is_counted_never_silent():
    """With extra_slots=0 a wide tick overruns the ring: divergence is
    licensed but must be visible in the collisions counter."""
    op = count_aggregate(WindowSpec(wa=10, ws=20, wt="multi"), k_virt=K,
                         out_cap=512, extra_slots=0)
    taus = jnp.asarray([0, 15, 35], jnp.int32)   # spans 5 generations
    b = T.make_batch(taus, jnp.zeros((3, 1)),
                     keys=jnp.asarray([[0], [1], [2]]), kmax=1)
    _, _, colls, _, _ = drive_both(op, "count", [b])
    assert colls > 0
