"""Epoch-consistent checkpoint/restore behind the runtime-config API
(ISSUE 7 tentpole).

Covers the contracts the recovery drills rely on, bottom-up:

* ``RuntimeConfig`` — JSON round-trip (the manifest carries it so restore
  rebuilds an *identical* stack), unknown-key tolerance on older
  manifests, and the checkpoint/super-batch alignment guard;
* ``Checkpointer`` — the atomic-manifest commit point (a torn save is
  invisible to ``latest_step``), per-object pending state (concurrent
  async saves from racing threads never cross-talk), shape-checked
  restore;
* full-runtime resume — single device and 8-way mesh (runtime-skipped
  below 8 devices): kill at tick N, restore from the last complete step,
  replay from the snapshot's frontier, committed+replayed == oracle
  tuple for tuple;
* the bounded-queue / mp-channel prompt-close contract that keeps a
  restore from hanging behind a dead consumer's full channel.
"""

import json
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro import api
from repro.checkpoint import Checkpointer
from repro.data import datagen
from repro.io.queues import BoundedQueue, QueueClosed

K = 64


def wordcount_stream(n_ticks=12, seed=2, tick=32, n_sources=1):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=n_sources))


def base_cfg(tmp, **over):
    kw = dict(op="count", wa=50, ws=100, k_virt=K, out_cap=512,
              n_max=8, n_active=4, stash_cap=64,
              checkpoint_dir=str(tmp), checkpoint_every=4)
    kw.update(over)
    return api.RuntimeConfig(**kw)


# ---------------------------------------------------------- RuntimeConfig --

def test_runtime_config_json_roundtrip(tmp_path):
    cfg = base_cfg(tmp_path, n_sources=4, ingest_hosts=2,
                   ingest_worker="process", mesh_devices=0,
                   super_batch=2, checkpoint_every=8)
    blob = json.dumps(cfg.to_json())          # must be JSON-serializable
    back = api.RuntimeConfig.from_json(json.loads(blob))
    assert back == cfg


def test_runtime_config_ignores_unknown_keys(tmp_path):
    d = base_cfg(tmp_path).to_json()
    d["some_future_field"] = 123              # older code, newer manifest
    cfg = api.RuntimeConfig.from_json(d)
    assert cfg.checkpoint_every == 4


def test_runtime_config_checkpoint_super_batch_alignment(tmp_path):
    with pytest.raises(AssertionError):
        base_cfg(tmp_path, super_batch=3, checkpoint_every=4)
    base_cfg(tmp_path, super_batch=2, checkpoint_every=4)  # aligned: fine


# ----------------------------------------------------------- Checkpointer --

def test_torn_save_invisible_to_latest_step(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": np.arange(6, dtype=np.int64).reshape(2, 3)}
    ck.save(4, tree, async_=False, extra={"step": 4})
    # a save torn mid-write: arrays on disk, no manifest
    torn = os.path.join(str(tmp_path), "step_00000008")
    os.makedirs(torn)
    np.save(os.path.join(torn, "leaf_00000.npy"), np.zeros(3))
    assert ck.latest_step() == 4
    step, got = ck.restore_latest({"a": np.zeros((2, 3), np.int64)})
    assert step == 4
    np.testing.assert_array_equal(got["a"], tree["a"])


def test_async_saves_from_racing_threads(tmp_path):
    """Per-object pending bookkeeping: N threads each drive their own
    async save stream into the same Checkpointer; wait() must block until
    every write landed and every step must restore bit-exact."""
    ck = Checkpointer(str(tmp_path))
    steps = list(range(1, 9))

    def _save(s):
        ck.save(s, {"x": np.full((4,), s, np.int64)}, async_=True,
                extra={"step": s})

    ths = [threading.Thread(target=_save, args=(s,)) for s in steps]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    ck.wait()
    assert ck.latest_step() == 8
    for s in steps:
        got = ck.restore(s, {"x": np.zeros((4,), np.int64)})
        assert (got["x"] == s).all()
        assert ck.manifest(s)["extra"]["step"] == s


def test_manifest_carries_runtime_config(tmp_path):
    """The commit record is self-describing: restore rebuilds the stack
    from the manifest's RuntimeConfig, not from the caller's flags."""
    from repro.io import ReplaySource
    cfg = base_cfg(tmp_path)
    batches = wordcount_stream(n_ticks=8)
    rt = api.build_runtime(cfg, ReplaySource(batches))
    rt.run()
    rt.checkpointer.wait()
    ck = Checkpointer(str(tmp_path))
    step = ck.latest_step()
    assert step is not None and step % cfg.checkpoint_every == 0
    extra = ck.manifest(step)["extra"]
    assert api.RuntimeConfig.from_json(extra["config"]) == cfg
    assert extra["step"] == step
    assert 0 <= extra["source_ticks"] <= len(batches)


# ---------------------------------------------------- full-runtime resume --

def test_resume_single_device_exactly_once(tmp_path):
    from repro.launch.recovery import kill_restore_drill
    cfg = base_cfg(tmp_path)
    batches = wordcount_stream(n_ticks=12, seed=13)
    rep = kill_restore_drill(cfg, batches, mode="stop", crash_after=7,
                             crash_mid_save=True)
    assert rep.parity, rep.summary()
    assert rep.restored_step == 4        # torn step-8 dir skipped
    assert rep.n_committed + rep.n_replayed == rep.n_oracle


def test_resume_after_stream_end_flush_only(tmp_path):
    """A tier snapshot can land on the final flush round, covering the
    *whole* recorded stream: resume then has an empty replay suffix and
    must still rebuild the gates at their exact restored shapes and flush
    without new input."""
    from repro.io import ReplaySource
    cfg = base_cfg(tmp_path, n_sources=4, ingest_hosts=2,
                   leaf_cap=32, root_cap=64, checkpoint_every=4)
    batches = wordcount_stream(n_ticks=8, seed=17, n_sources=4)
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=4))
    rt.run()
    rt.checkpointer.wait()
    saved = rt.checkpointer.saved_steps
    assert saved, "no snapshot landed"
    resumed = api.resume_runtime(str(tmp_path), batches)
    resumed.run()
    assert resumed.restored_step == max(saved)
    # committed-below-S plus replayed-from-S must equal the full run
    committed = rt.sink.results(before_tick=resumed.restored_step)
    oracle = rt.sink.results()
    assert sorted(committed + resumed.sink.results()) == sorted(oracle)


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_resume_mesh8_exactly_once(tmp_path):
    """Same kill-and-restore contract with the key-block-sharded sigma on
    an 8-way mesh: the snapshot must be consistent across every shard and
    the rebuilt mesh stack must replay to exact parity."""
    from repro.launch.recovery import kill_restore_drill
    cfg = base_cfg(tmp_path, mesh_devices=8, n_max=8, n_active=8)
    batches = wordcount_stream(n_ticks=12, seed=19)
    rep = kill_restore_drill(cfg, batches, mode="stop", crash_after=7,
                             crash_mid_save=True)
    assert rep.parity, rep.summary()
    assert rep.restored_step == 4


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
def test_resume_mesh8_with_ingest_tier(tmp_path):
    """Mesh pipeline fed by the hierarchical multi-host tier: one snapshot
    covers ingest stash + watermark + sharded sigma consistently."""
    from repro.launch.recovery import kill_restore_drill
    cfg = base_cfg(tmp_path, mesh_devices=8, n_max=8, n_active=8,
                   n_sources=4, ingest_hosts=2, leaf_cap=32, root_cap=64)
    batches = wordcount_stream(n_ticks=12, seed=29, n_sources=4)
    rep = kill_restore_drill(cfg, batches, mode="stop", crash_after=6,
                             crash_mid_save=False)
    assert rep.parity, rep.summary()
    assert rep.restored_step >= cfg.checkpoint_every


# ------------------------------------------------- prompt-close contracts --

def test_bounded_queue_close_unblocks_put():
    """close() during a blocked put raises QueueClosed immediately — not
    after the put's timeout — so teardown never hangs behind a full
    queue whose consumer died."""
    q = BoundedQueue(1)
    q.put("a")
    err, done = [], threading.Event()

    def _blocked_put():
        t0 = time.perf_counter()
        try:
            q.put("b", timeout=30.0)
        except QueueClosed:
            err.append(time.perf_counter() - t0)
        done.set()

    th = threading.Thread(target=_blocked_put)
    th.start()
    time.sleep(0.1)                  # let the put block on the full queue
    q.close()
    assert done.wait(timeout=5.0)
    th.join()
    assert err and err[0] < 5.0, "put waited out its timeout past close()"
    assert q.get() == "a"            # enqueued-before-close still delivered
    with pytest.raises(QueueClosed):
        q.get()


def test_mp_channel_close_unblocks_put():
    """The process-transport adapter honors the same contract within its
    poll granularity: a put blocked on a full mp queue observes close()
    promptly instead of waiting out a long timeout."""
    import multiprocessing as mp
    from repro.ingest.channels import MpChannel
    ch = MpChannel(mp.get_context("spawn"), cap=1)
    ch.put("a")
    err, done = [], threading.Event()

    def _blocked_put():
        t0 = time.perf_counter()
        try:
            ch.put("b", timeout=30.0)
        except QueueClosed:
            err.append(time.perf_counter() - t0)
        done.set()

    th = threading.Thread(target=_blocked_put)
    th.start()
    time.sleep(0.2)
    ch.close()
    assert done.wait(timeout=5.0)
    th.join()
    assert err and err[0] < 5.0, "mp put ignored close()"
