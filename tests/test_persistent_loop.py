"""Persistent compiled K-tick driver (ISSUE-6 tentpole): ``run_persistent``
scans K ticks inside ONE compiled ``lax.scan`` with donated ScaleGate and
sigma buffers.  The contracts under test:

  * tick-for-tick output parity with K sequential ``step`` calls — per-tick
    multisets, switch flags and instance loads, across consecutive
    super-batches (the donated carry must thread exactly);
  * a mid-scan reconfiguration (control tuples injected into the ctrl pad
    lanes *inside* the compiled program) lands on the exact tick the
    sequential oracle switches on, with identical outputs before and after;
  * donation safety: the pre-call state buffers are consumed by the scan
    (use-after-donate raises) while the pipeline object stays live;
  * the zero-host-transfer witness: the compiled persistent HLO contains no
    host transfer ops on the data lane;
  * the async runtime's ``super_batch=K`` grouping is output-identical to
    the per-tick synchronous loop;
  * the mesh pipeline's persistent scan matches its own sequential steps
    (1-device always; 8-device under the multi-device CI job).
"""

import numpy as np
import jax
import pytest

from repro.core.aggregate import count_aggregate
from repro.core.controller import Reconfiguration, active_mask, balanced_fmu
from repro.core.runtime import MeshPipeline, VSNPipeline
from repro.core.windows import WindowSpec
from repro.data import datagen
from repro.io.sinks import flatten_outputs
from repro.launch.mesh import host_transfer_ops, make_stream_mesh

K = 64
WS = WindowSpec(wa=50, ws=100, wt="multi")

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 devices (XLA_FLAGS="
                      "--xla_force_host_platform_device_count=8)")


def op():
    return count_aggregate(WS, k_virt=K, out_cap=512, extra_slots=2)


def stream(n_ticks=6, seed=0):
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=16,
                               words_per_tweet=3, vocab=500, k_virt=K,
                               rate_per_tick=30))


def make_vsn():
    return VSNPipeline(op(), n_max=8, n_active=4, stash_cap=64)


def make_mesh(n_shards):
    return MeshPipeline(op(), make_stream_mesh(n_shards), stash_cap=64,
                        mode="fast-agg", agg_kind="count")


def reconfig():
    fmu = balanced_fmu(K, 3, 8)
    return Reconfiguration(epoch=1, n_active=3, fmu=fmu,
                           active=active_mask(3, 8))


def mesh_reconfig(n_shards):
    """A reconfiguration at mesh width: the epoch tables are per-shard, so
    the active mask must be n_shards wide (a 1-shard mesh gets the
    epoch-bump-only switch — tables unchanged, switch still observable)."""
    n_act = max(n_shards // 2, 1)
    return Reconfiguration(epoch=1, n_active=n_act,
                           fmu=balanced_fmu(K, n_act, n_shards),
                           active=active_mask(n_act, n_shards))


def sequential_ticks(pipe, batches, rc=None, rc_at=0):
    """The oracle: K individual steps; per-tick sorted output multiset +
    switch flag (+ inst load where the pipeline computes one)."""
    ticks = []
    for i, b in enumerate(batches):
        r = rc if (rc is not None and i == rc_at) else None
        if isinstance(pipe, VSNPipeline):
            o1, o2, sw, il = pipe.step_staged(b, reconfig=r)
            il = np.asarray(il)
        else:
            o1, o2, sw = pipe.step(b, reconfig=r)
            il = None
        ticks.append((sorted(flatten_outputs(o1) + flatten_outputs(o2)),
                      bool(np.asarray(sw)), il))
    return ticks


def persistent_ticks(out):
    k = int(np.asarray(out.switched).shape[0])
    ticks = []
    for i in range(k):
        o1 = jax.tree.map(lambda a: a[i], out.outs_pre)
        o2 = jax.tree.map(lambda a: a[i], out.outs_post)
        il = (None if out.inst_load is None
              else np.asarray(out.inst_load)[i])
        ticks.append((sorted(flatten_outputs(o1) + flatten_outputs(o2)),
                      bool(np.asarray(out.switched)[i]), il))
    return ticks


def assert_tickwise_equal(got, want):
    assert len(got) == len(want)
    for i, ((g_out, g_sw, g_il), (w_out, w_sw, w_il)) in enumerate(
            zip(got, want)):
        assert g_out == w_out, f"tick {i}: output multisets differ"
        assert g_sw == w_sw, f"tick {i}: switch flag differs"
        if g_il is not None and w_il is not None:
            assert (g_il == w_il).all(), f"tick {i}: inst loads differ"


# ----------------------------------------------------- steady state -------

def test_persistent_matches_sequential():
    batches = stream(n_ticks=6)
    want = sequential_ticks(make_vsn(), batches)
    out = make_vsn().run_persistent(batches)
    assert_tickwise_equal(persistent_ticks(out), want)


def test_consecutive_super_batches_thread_state():
    """Two back-to-back persistent scans over one pipeline must continue the
    (donated, updated-in-place) state exactly where the first left off."""
    batches = stream(n_ticks=8)
    want = sequential_ticks(make_vsn(), batches)
    pipe = make_vsn()
    got = (persistent_ticks(pipe.run_persistent(batches[:4]))
           + persistent_ticks(pipe.run_persistent(batches[4:])))
    assert_tickwise_equal(got, want)


# ------------------------------------------------- mid-scan reconfig ------

@pytest.mark.parametrize("rc_at", [0, 3])
def test_midscan_reconfig_matches_sequential(rc_at):
    batches = stream(n_ticks=6)
    rc = reconfig()
    want = sequential_ticks(make_vsn(), batches, rc=rc, rc_at=rc_at)
    out = make_vsn().run_persistent(batches, reconfig=rc, reconfig_at=rc_at)
    got = persistent_ticks(out)
    assert any(sw for _, sw, _ in got), "reconfig never switched"
    assert_tickwise_equal(got, want)


def test_midscan_reconfig_matches_static_outputs():
    """Zero state transfer means the switch is semantically invisible: the
    total output multiset with a mid-scan reconfig equals the run that
    never reconfigures."""
    batches = stream(n_ticks=6)
    static = make_vsn().run_persistent(batches)
    moved = make_vsn().run_persistent(batches, reconfig=reconfig(),
                                      reconfig_at=2)
    flat = lambda t: sorted(sum((o for o, _, _ in persistent_ticks(t)), []))
    assert flat(moved) == flat(static)


# ------------------------------------------------------- donation ---------

def test_donated_buffers_consumed_and_pipeline_live():
    pipe = make_vsn()
    batches = stream(n_ticks=4)
    pipe.step(batches[0])                       # realize sg at stream shape
    old_sg = jax.tree.leaves(pipe.sg)
    pipe.run_persistent(batches)
    donated = [a for a in old_sg
               if isinstance(a, jax.Array) and a.is_deleted()]
    if not donated:
        pytest.skip("backend does not honor buffer donation")
    with pytest.raises(RuntimeError):
        np.asarray(donated[0])
    # the pipeline itself is fine: its state was replaced, not freed
    pipe.run_persistent(stream(n_ticks=4, seed=1))


# ------------------------------------------- zero-host-transfer HLO -------

def test_persistent_hlo_has_no_host_transfers():
    pipe = make_vsn()
    pipe.run_persistent(stream(n_ticks=4))
    hlo = pipe.persistent_hlo()
    assert hlo.strip(), "no persistent executable was compiled"
    assert host_transfer_ops(hlo) == []


# ------------------------------------------------- async super-batch ------

def test_async_super_batch_matches_sync():
    from repro.core.async_runtime import AsyncStreamRuntime, run_sync
    from repro.io import SyntheticSource

    batches = stream(n_ticks=8)
    pipe_a = make_vsn()
    rt = AsyncStreamRuntime(pipe_a, SyntheticSource(iter(batches)),
                            queue_cap=4, super_batch=4)
    rt.run()
    _, sink_s = run_sync(make_vsn(), SyntheticSource(iter(batches)))
    assert rt.sink.results() == sink_s.results()


# ------------------------------------------------------------ mesh --------

@pytest.mark.parametrize("n_shards", [
    1, pytest.param(8, marks=needs8)])
def test_mesh_persistent_matches_sequential(n_shards):
    batches = stream(n_ticks=5)
    want = sequential_ticks(make_mesh(n_shards), batches)
    out = make_mesh(n_shards).run_persistent(batches)
    assert_tickwise_equal(persistent_ticks(out), want)


@pytest.mark.parametrize("n_shards", [
    1, pytest.param(8, marks=needs8)])
def test_mesh_persistent_midscan_reconfig(n_shards):
    batches = stream(n_ticks=5)
    rc = mesh_reconfig(n_shards)
    want = sequential_ticks(make_mesh(n_shards), batches, rc=rc, rc_at=2)
    out = make_mesh(n_shards).run_persistent(batches, reconfig=rc,
                                             reconfig_at=2)
    got = persistent_ticks(out)
    assert any(sw for _, sw, _ in got), "reconfig never switched"
    assert_tickwise_equal(got, want)


def test_mesh_persistent_hlo_has_no_host_transfers():
    pipe = make_mesh(1)
    pipe.run_persistent(stream(n_ticks=4))
    assert host_transfer_ops(pipe.persistent_hlo()) == []
