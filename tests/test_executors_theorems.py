"""The paper's theorems as executable tests.

Theorem 1 — SN must duplicate multi-key tuples (duplication factor > 1);
            VSN never duplicates (Observation 2).
Theorem 2 — A+ on O+ == the M-then-A expansion (Corollary 1).
Theorem 3 — VSN outputs are invariant under elastic reconfigurations, and
            equal to SN's and the sequential oracle's.
Theorem 4 — concurrent control tuples: the latest epoch wins, exactly once.
Lemma 3   — reconfig trigger tau is a safe watermark lower bound.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import collect_outputs, make_stream_batch
from repro.core import elastic, sn, tuples as T, vsn
from repro.core.aggregate import count_aggregate
from repro.core.controller import (Reconfiguration, active_mask,
                                   balanced_fmu)
from repro.core.operator import tick as gen_tick
from repro.core.runtime import SNPipeline, VSNPipeline
from repro.core.windows import WindowSpec

K = 8
WS = WindowSpec(wa=10, ws=20, wt="multi")


def op():
    return count_aggregate(WS, k_virt=K, out_cap=128)


def multi_key_stream(rng, n_ticks=4, tick=12, kmax=3):
    """Tuples with key *sets* (Definition 4) — the Theorem 1 setting."""
    tau = 0
    for _ in range(n_ticks):
        taus = np.sort(tau + rng.integers(0, 12, tick))
        tau = int(taus.max()) + 1
        keys = rng.integers(0, K, (tick, kmax)).astype(np.int32)
        keys[rng.random((tick, kmax)) < 0.2] = -1
        yield make_stream_batch(taus, keys=keys, kmax=kmax)


def run_pipeline(P, reconfig_at=None, n_active=2, seed=1):
    rng = np.random.default_rng(seed)
    pipe = P(op(), n_max=4, n_active=n_active, stash_cap=32)
    outs = []
    for i, b in enumerate(multi_key_stream(rng)):
        rc = None
        if reconfig_at is not None and i == reconfig_at:
            rc = Reconfiguration(epoch=1, n_active=4,
                                 fmu=balanced_fmu(K, 4, 4),
                                 active=active_mask(4, 4))
        o1, o2, _ = pipe.step(b, reconfig=rc)
        outs += collect_outputs(o1) + collect_outputs(o2)
    # flush with a late watermark-advancing tick
    o1, o2, _ = pipe.step(make_stream_batch([500], keys=[[-1, -1, -1]], kmax=3))
    outs += collect_outputs(o1) + collect_outputs(o2)
    return sorted(outs), pipe


def sequential_oracle(seed=1):
    rng = np.random.default_rng(seed)
    o = op().resolved()
    st = o.init_state()
    outs = []
    for b in multi_key_stream(rng):
        st, ob = gen_tick(o, st, b, jnp.ones((K,), bool))
        outs += collect_outputs(ob)
    st, ob = gen_tick(o, st, make_stream_batch([500], keys=[[-1, -1, -1]], kmax=3),
                      jnp.ones((K,), bool))
    outs += collect_outputs(ob)
    return sorted(outs)


def test_theorem3_vsn_sn_oracle_equivalence():
    oracle = sequential_oracle()
    assert oracle, "oracle produced no outputs — bad test setup"
    for P in (VSNPipeline, SNPipeline):
        for rc in (None, 1, 2):
            got, _ = run_pipeline(P, reconfig_at=rc)
            assert got == oracle, (P.__name__, rc)


def test_theorem1_duplication():
    """SN duplicates multi-key tuples; VSN shares them (Observation 2)."""
    _, snp = run_pipeline(SNPipeline)
    dup = [d for d in snp.duplication if d > 0]
    assert max(dup) > 1.0 + 1e-6, "multi-key stream must duplicate under SN"
    # and the more instances, the more duplication
    _, snp4 = run_pipeline(SNPipeline, n_active=4)
    assert np.mean([d for d in snp4.duplication if d > 0]) >= \
        np.mean(dup) - 1e-6


def test_state_transfer_vsn_zero_sn_positive():
    _, vp = run_pipeline(VSNPipeline, reconfig_at=1)
    _, sp = run_pipeline(SNPipeline, reconfig_at=1)
    assert int(vp.epoch.reconfigs) == 1 and int(sp.epoch.reconfigs) == 1
    # SN ships sigma rows; VSN ships only the tables (the paper's headline)
    assert sp.bytes_transferred > 0
    assert elastic.vsn_switch_bytes(vp.epoch) == 4 * K + 4 + 12


def test_state_transfer_scales_with_state_not_tables():
    """The decisive scaling property: SN transfer grows with sigma row
    width; the VSN epoch switch cost is constant (tables only)."""
    import functools
    from repro.core.aggregate import reduce_aggregate

    def fat_op(width):
        return reduce_aggregate(WS, K, width=width,
                                f_r=lambda acc, p: acc + 1.0, init_val=0.0,
                                out_cap=128)

    costs = {}
    for width in (1, 64):
        rng = np.random.default_rng(1)
        pipe = SNPipeline(fat_op(width), n_max=4, n_active=2, stash_cap=32)
        for i, b in enumerate(multi_key_stream(rng)):
            rc = (Reconfiguration(epoch=1, n_active=4,
                                  fmu=balanced_fmu(K, 4, 4),
                                  active=active_mask(4, 4))
                  if i == 1 else None)
            pipe.step(b, reconfig=rc)
        costs[width] = pipe.bytes_transferred
    assert costs[64] > 16 * costs[1]          # SN: ~width-linear
    # VSN: table bytes are width-independent by construction
    assert elastic.vsn_switch_bytes(pipe.epoch) == 4 * K + 4 + 12


def test_theorem4_latest_control_wins():
    st = elastic.init_epoch(jnp.zeros(K, jnp.int32), jnp.ones(4, bool))
    b = make_stream_batch([10, 11], keys=[[-1], [-1]])
    b = dataclasses.replace(
        b, is_control=jnp.asarray([True, True]),
        ctrl_epoch=jnp.asarray([2, 1], jnp.int32))
    fmu2 = jnp.full((K,), 3, jnp.int32)
    st = elastic.prepare_reconfig(st, b, fmu2, jnp.ones(4, bool))
    assert int(st.e_next) == 2           # latest epoch id adopted
    assert int(st.gamma) == 10           # gamma of the *newest* control tuple
    st, switched = elastic.advance_epoch(st, jnp.int32(11))
    assert bool(switched) and int(st.e) == 2
    # re-applying the same watermark does not re-switch (exactly once)
    st, again = elastic.advance_epoch(st, jnp.int32(12))
    assert not bool(again) and int(st.reconfigs) == 1


def test_epoch_split_masks():
    st = elastic.init_epoch(jnp.zeros(K, jnp.int32), jnp.ones(4, bool))
    st = dataclasses.replace(st, gamma=jnp.int32(15))
    b = make_stream_batch([10, 15, 16, 20], keys=[[0], [0], [0], [0]])
    pre, post = elastic.split_epoch_masks(st, b)
    assert list(np.asarray(pre)) == [True, True, False, False]
    assert list(np.asarray(post)) == [False, False, True, True]


def test_lemma3_trigger_tau_is_safe():
    """Outputs produced before the switch have tau <= gamma; outputs after
    depend only on tuples > gamma — so gamma is a valid watermark for a
    provisioned instance."""
    oracle, _ = run_pipeline(VSNPipeline, reconfig_at=1)
    # equivalence test already proves content; here assert the boundary:
    got, pipe = run_pipeline(VSNPipeline, reconfig_at=1)
    assert int(pipe.epoch.reconfigs) == 1
    assert got == oracle
