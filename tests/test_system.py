"""End-to-end behaviour tests for the paper's system.

Corollary 1: A+ on O+ == the M-then-A shared-nothing expansion.
Chained operators: O+ -> TB -> O+ (ESG_out feeds ESG_in composably, §7).
Hypothesis: streaming invariants over random sorted streams.
E2E: streaming wordcount with elastic scaling + an LM train loop with
checkpoint resume, through the public APIs only.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from conftest import collect_outputs, make_stream_batch
from repro.core import scalegate, tuples as T
from repro.core.aggregate import count_aggregate
from repro.core.operator import tick as gen_tick
from repro.core.runtime import SNPipeline, VSNPipeline
from repro.core.windows import WindowSpec

K = 16
WS = WindowSpec(wa=10, ws=20, wt="multi")


# ----------------------------------------------------- Corollary 1 / Thm 2 -
def test_corollary1_aplus_equals_map_then_a():
    """A+ with multi-key tuples == Map-expansion (one single-key copy per
    key, Corollary 1) into a plain A."""
    rng = np.random.default_rng(3)
    n = 24
    taus = np.sort(rng.integers(0, 60, n)).astype(np.int32)
    keys = rng.integers(0, K, (n, 3)).astype(np.int32)
    keys[rng.random((n, 3)) < 0.3] = -1
    dedup = []
    for row in keys:                       # a key set, not a multiset
        seen = set()
        dedup.append([k if k >= 0 and k not in seen and not seen.add(k)
                      else -1 for k in row])
    keys = np.asarray(dedup, np.int32)

    op = count_aggregate(WS, k_virt=K, out_cap=512)
    flush = make_stream_batch([200], keys=[[-1, -1, -1]], kmax=3)

    # A+ path: multi-key tuples straight in
    st_ = op.resolved().init_state()
    b = make_stream_batch(taus, keys=keys, kmax=3)
    st_, o1 = gen_tick(op.resolved(), st_, b, jnp.ones((K,), bool))
    st_, o2 = gen_tick(op.resolved(), st_, flush, jnp.ones((K,), bool))
    aplus = collect_outputs(o1) + collect_outputs(o2)

    # M-then-A path: expand each tuple into one copy per key (Corollary 1)
    ex_tau, ex_key = [], []
    for t, row in zip(taus, keys):
        for k in row:
            if k >= 0:
                ex_tau.append(t)
                ex_key.append([k])
    st2 = op.resolved().init_state()
    b2 = make_stream_batch(ex_tau, keys=np.asarray(ex_key), kmax=1)
    st2, o1 = gen_tick(op.resolved(), st2, b2, jnp.ones((K,), bool))
    flush1 = make_stream_batch([200], keys=[[-1]], kmax=1)
    st2, o2 = gen_tick(op.resolved(), st2, flush1, jnp.ones((K,), bool))
    m_then_a = collect_outputs(o1) + collect_outputs(o2)

    assert sorted(aplus) == sorted(m_then_a)


# --------------------------------------------------------- operator chains -
def test_chained_operators_via_tb():
    """O+ -> TB -> O+: the first stage's outputs (Lemma 2 sorted) feed a
    downstream ScaleGate as a valid source set, per §6 composability."""
    rng = np.random.default_rng(5)
    op1 = count_aggregate(WS, k_virt=K, out_cap=512)
    # stage 2 counts stage-1 windows per key over a coarser window
    op2 = count_aggregate(WindowSpec(wa=40, ws=40, wt="multi"), k_virt=K,
                          out_cap=512)
    st1 = op1.resolved().init_state()
    st2 = op2.resolved().init_state()
    sg2 = scalegate.init_scalegate(1, capacity=128, kmax=1, payload_width=2)
    resp = jnp.ones((K,), bool)
    got2 = []
    for i in range(4):
        taus = np.sort(rng.integers(i * 30, i * 30 + 30, 16)).astype(np.int32)
        keys = rng.integers(0, K, 16).astype(np.int32)
        st1, outs1 = gen_tick(op1.resolved(), st1,
                              make_stream_batch(taus, keys=keys), resp)
        # feed stage-1 outputs into stage 2's TB (key = payload[0])
        o_tau = outs1.tau
        o_keys = outs1.payload[:, :1].astype(jnp.int32)
        b2 = T.TupleBatch(tau=o_tau, keys=o_keys, payload=outs1.payload,
                          source=jnp.zeros_like(o_tau),
                          valid=outs1.valid,
                          is_control=jnp.zeros_like(outs1.valid),
                          ctrl_epoch=jnp.zeros_like(o_tau))
        sg2, ready2 = scalegate.push(sg2, b2)
        st2, outs2 = gen_tick(op2.resolved(), st2, ready2, resp)
        got2 += collect_outputs(outs2)
    # downstream windows produce sorted, keyed counts of upstream outputs
    ts = [t for t, _ in got2]
    assert got2 and ts == sorted(ts)


# ------------------------------------------------------------- hypothesis --
@given(st.lists(st.tuples(st.integers(0, 80), st.integers(0, K - 1)),
                min_size=4, max_size=40),
       st.integers(2, 4))
@settings(max_examples=30, deadline=None)
def test_vsn_equals_oracle_random_streams(items, n_inst):
    items = sorted(items)
    taus = [t for t, _ in items]
    keys = [[k] for _, k in items]
    op = count_aggregate(WS, k_virt=K, out_cap=1024)

    st_ = op.resolved().init_state()
    b = make_stream_batch(taus, keys=np.asarray(keys))
    f = make_stream_batch([500], keys=[[-1]])
    st_, o1 = gen_tick(op.resolved(), st_, b, jnp.ones((K,), bool))
    st_, o2 = gen_tick(op.resolved(), st_, f, jnp.ones((K,), bool))
    oracle = sorted(collect_outputs(o1) + collect_outputs(o2))

    pipe = VSNPipeline(op, n_max=4, n_active=n_inst, stash_cap=64)
    outs = []
    for batch in (b, f):
        r1, r2, _ = pipe.step(batch)
        outs += collect_outputs(r1) + collect_outputs(r2)
    assert sorted(outs) == oracle


# -------------------------------------------------------------------- e2e --
def test_e2e_streaming_wordcount_with_scaling():
    from repro.core.controller import ThresholdController
    from repro.data import datagen
    rng = np.random.default_rng(2)
    op = count_aggregate(WindowSpec(wa=100, ws=200, wt="multi"),
                         k_virt=64, out_cap=1024)
    pipe = VSNPipeline(op, n_max=8, n_active=2, stash_cap=128)
    ctl = ThresholdController(n_max=8, k_virt=64,
                              capacity_per_instance=500.0, n_active=2)
    n_out, reconfigs = 0, 0
    for i, b in enumerate(datagen.tweets(
            rng, n_ticks=6, tick=64, words_per_tweet=3, vocab=300,
            k_virt=64, rate_per_tick=60)):
        rc = ctl.observe(rate=300.0 * (1 + i))
        reconfigs += rc is not None
        o1, o2, _ = pipe.step(b, reconfig=rc)
        n_out += len(collect_outputs(o1)) + len(collect_outputs(o2))
    assert n_out > 0 and reconfigs >= 1
    assert int(pipe.epoch.reconfigs) >= 1


def test_e2e_train_loop(tmp_path):
    """Few steps of the real train driver (reduced config) incl. resume."""
    from repro.launch import train as TR
    d = str(tmp_path / "ckpt")
    rc = TR.main(["--arch", "hymba-1.5b", "--steps", "6", "--reduced",
                  "--batch", "2", "--seq", "32", "--ckpt-dir", d,
                  "--ckpt-every", "3"])
    assert rc == 0
    from repro.checkpoint import checkpoint as C
    assert C.latest_step(d) == 6
    # resume path: runs 2 more steps from the checkpoint
    rc = TR.main(["--arch", "hymba-1.5b", "--steps", "8", "--reduced",
                  "--batch", "2", "--seq", "32", "--ckpt-dir", d])
    assert rc == 0
