"""Small-shape parity: ``xla`` vs ``pallas-interpret`` for all five kernels.

These run by default on every host: the dispatched backends must never
silently diverge from the ref oracle.  The *heavy* interpret-mode shape
sweeps live in test_kernels.py behind ``@pytest.mark.slow``.
"""

import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.linear_scan.ops import linear_scan_op
from repro.kernels.scalegate_merge.ops import scalegate_merge_op
from repro.kernels.segment_aggregate.ops import segment_aggregate_op
from repro.kernels.window_join.ops import window_join_op

KERNELS = ("scalegate_merge", "segment_aggregate", "window_join",
           "flash_attention", "linear_scan")


def test_all_kernels_registered_on_all_backends():
    reg = dispatch.registered()
    for name in KERNELS:
        assert reg.get(name) == ("pallas", "pallas-interpret", "xla"), name


def test_cpu_default_backend_is_xla():
    import jax
    if jax.devices()[0].platform != "tpu":
        assert dispatch.default_backend() == "xla"


def test_backend_resolution_order(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas-interpret")
    assert dispatch.default_backend() == "pallas-interpret"
    dispatch.set_default_backend("xla")          # explicit beats env
    try:
        assert dispatch.default_backend() == "xla"
    finally:
        dispatch.set_default_backend(None)
    with pytest.raises(dispatch.UnknownBackendError):
        dispatch.resolve("cuda")


def test_scalegate_merge_parity():
    rng = np.random.default_rng(0)
    n, srcs = 32, 3
    tau = rng.integers(0, 500, n).astype(np.int32)
    src = rng.integers(0, srcs, n).astype(np.int32)
    valid = rng.random(n) < 0.85
    o1, r1, w1 = scalegate_merge_op(tau, src, valid, n_sources=srcs,
                                    backend="pallas-interpret")
    o2, r2, w2 = scalegate_merge_op(tau, src, valid, n_sources=srcs,
                                    backend="xla")
    # keys are unique (tau, lane): the total order itself must match
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(w1[0]) == int(w2[0])


def test_scalegate_merge_parity_full_tau_range():
    """The lexicographic (tau, lane) network has no packed-key overflow:
    epoch-style timestamps near int32 max still sort correctly."""
    rng = np.random.default_rng(7)
    n, srcs = 64, 2
    tau = rng.integers(1_500_000_000, 2_000_000_000, n).astype(np.int32)
    src = rng.integers(0, srcs, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    o1, r1, w1 = scalegate_merge_op(tau, src, valid, n_sources=srcs,
                                    backend="pallas-interpret")
    o2, r2, w2 = scalegate_merge_op(tau, src, valid, n_sources=srcs,
                                    backend="xla")
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    assert int(w1[0]) == int(w2[0])
    srt = tau[np.asarray(o1)][valid[np.asarray(o1)]]
    assert (np.diff(srt) >= 0).all()


def test_segment_aggregate_parity():
    rng = np.random.default_rng(1)
    n, k, s, w = 16, 32, 2, 2
    keys = rng.integers(-1, k, n).astype(np.int32)
    slots = rng.integers(0, s, n).astype(np.int32)
    vals = rng.uniform(0, 1, (n, w)).astype(np.float32)
    acc = rng.uniform(0, 1, (k, s, w)).astype(np.float32)
    a = segment_aggregate_op(keys, slots, vals, acc, tile_k=32,
                             backend="pallas-interpret")
    b = segment_aggregate_op(keys, slots, vals, acc, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_segment_aggregate_out_of_range_keys_dropped_on_both_backends():
    """keys >= K are dead lanes on *both* backends (the ref used to clip
    them into row K-1 while the kernel dropped them)."""
    import jax.numpy as jnp
    k, s, w = 8, 2, 1
    keys = np.asarray([0, 7, 8, 100, -1], np.int32)     # 2 in range
    slots = np.zeros(5, np.int32)
    vals = np.ones((5, w), np.float32)
    acc = jnp.zeros((k, s, w), jnp.float32)
    a = segment_aggregate_op(keys, slots, vals, acc, tile_k=8,
                             backend="pallas-interpret")
    b = segment_aggregate_op(keys, slots, vals, acc, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert float(np.asarray(b).sum()) == 2.0            # only keys 0 and 7


def test_window_join_parity():
    rng = np.random.default_rng(2)
    b, k, r, p = 8, 64, 4, 2
    nt = np.sort(rng.integers(100, 300, b)).astype(np.int32)
    ns = rng.integers(0, 2, b).astype(np.int32)
    npay = rng.uniform(0, 40, (b, p)).astype(np.float32)
    st = rng.integers(0, 280, (k, r)).astype(np.int32)
    st[rng.random((k, r)) < 0.3] = -1
    ss = rng.integers(0, 2, (k, r)).astype(np.int32)
    sp = rng.uniform(0, 40, (k, r, p)).astype(np.float32)
    c1, n1 = window_join_op(nt, ns, npay, st, ss, sp, ws=60, tile_k=64,
                            backend="pallas-interpret")
    c2, n2 = window_join_op(nt, ns, npay, st, ss, sp, ws=60, backend="xla")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert int(n1) == int(n2)


def test_flash_attention_parity():
    rng = np.random.default_rng(3)
    q = rng.normal(0, 1, (2, 16, 8)).astype(np.float32)
    k = rng.normal(0, 1, (2, 16, 8)).astype(np.float32)
    v = rng.normal(0, 1, (2, 16, 8)).astype(np.float32)
    a = flash_attention_op(q, k, v, causal=True, blk_q=8, blk_k=8,
                           backend="pallas-interpret")
    b = flash_attention_op(q, k, v, causal=True, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_linear_scan_parity():
    rng = np.random.default_rng(4)
    r = rng.normal(0, 1, (2, 16, 4)).astype(np.float32)
    k = rng.normal(0, 1, (2, 16, 4)).astype(np.float32)
    v = rng.normal(0, 1, (2, 16, 4)).astype(np.float32)
    w = rng.uniform(0.5, 0.99, (2, 16, 4)).astype(np.float32)
    u = rng.normal(0, 1, (2, 4)).astype(np.float32)
    a = linear_scan_op(r, k, v, w, u, chunk=8, backend="pallas-interpret")
    b = linear_scan_op(r, k, v, w, u, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_band_join_counts_backends_agree():
    """core/join's dispatched counting path: both CPU backends equal the
    ref oracle's counts and comparison totals."""
    import jax.numpy as jnp
    from repro.core import tuples as T
    from repro.core.join import band_join_counts, fast_join_init
    from repro.core.windows import WindowSpec

    rng = np.random.default_rng(5)
    K, RING, B, P = 32, 4, 8, 2
    st = fast_join_init(K, RING, P)
    st = st.__class__(
        tau=jnp.asarray(rng.integers(-1, 200, (K, RING)), jnp.int32),
        pay=jnp.asarray(rng.uniform(0, 20, (K, RING, P)), jnp.float32),
        stream=jnp.asarray(rng.integers(0, 2, (K, RING)), jnp.int32),
        n=st.n, c=st.c, comparisons=st.comparisons)
    taus = np.sort(rng.integers(50, 250, B)).astype(np.int32)
    ready = T.make_batch(
        jnp.asarray(taus),
        jnp.asarray(rng.uniform(0, 20, (B, P)), jnp.float32),
        keys=None, source=jnp.asarray(rng.integers(0, 2, B), jnp.int32),
        kmax=1)
    ws = WindowSpec(wa=1, ws=60, wt="single")
    c_x, n_x = band_join_counts(st, ready, ws, band=5.0, backend="xla")
    c_p, n_p = band_join_counts(st, ready, ws, band=5.0,
                                backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_p))
    assert int(n_x) == int(n_p)

    # invalid lanes (static-batch padding) match nothing and count nothing
    import dataclasses
    half_valid = jnp.asarray([True] * (B // 2) + [False] * (B // 2))
    masked = dataclasses.replace(ready, valid=half_valid)
    c_m, n_m = band_join_counts(st, masked, ws, band=5.0, backend="xla")
    np.testing.assert_array_equal(np.asarray(c_m)[:B // 2],
                                  np.asarray(c_x)[:B // 2])
    assert not np.asarray(c_m)[B // 2:].any()
    assert int(n_m) < int(n_x)


def test_aggregate_scatter_backends_agree():
    """core/aggregate's dispatched segment-reduce: tick_fast produces the
    same accumulator state on both CPU backends."""
    import jax.numpy as jnp
    from repro.core import tuples as T
    from repro.core.aggregate import count_aggregate, fast_init, tick_fast
    from repro.core.windows import WindowSpec

    rng = np.random.default_rng(6)
    K = 32
    op = count_aggregate(WindowSpec(wa=10, ws=20, wt="multi"), k_virt=K,
                         out_cap=128).resolved()
    taus = np.sort(rng.integers(0, 40, 16)).astype(np.int32)
    keys = rng.integers(0, K, 16).astype(np.int32)
    b = T.make_batch(jnp.asarray(taus), jnp.zeros((16, 1), jnp.float32),
                     keys=jnp.asarray(keys)[:, None], source=None, kmax=1)
    resp = jnp.ones((K,), bool)
    accs = {}
    for backend in ("xla", "pallas-interpret"):
        st, _ = tick_fast(op, "count", fast_init(op), b, resp,
                          backend=backend)
        accs[backend] = np.asarray(st.op_state.zeta["acc"])
    np.testing.assert_allclose(accs["xla"], accs["pallas-interpret"],
                               atol=1e-5)


def test_core_callers_accept_backend():
    """The core integration points run on both CPU backends and agree."""
    import jax.numpy as jnp
    from repro.core import scalegate
    from repro.core import tuples as T

    taus = np.asarray([3, 1, 2, 4, 9, 6, 7, 8], np.int32)
    srcs = np.asarray([0, 1, 0, 1, 0, 1, 0, 1], np.int32)
    batch = T.make_batch(jnp.asarray(taus),
                         jnp.zeros((8, 1), jnp.float32),
                         keys=None, source=jnp.asarray(srcs), kmax=1)
    got = {}
    for backend in ("xla", "pallas-interpret"):
        state = scalegate.init_scalegate(2, capacity=8, kmax=1,
                                         payload_width=1)
        state, out = scalegate.push(state, batch, backend=backend)
        got[backend] = sorted(
            int(t) for t, ok in zip(np.asarray(out.tau),
                                    np.asarray(out.valid)) if ok)
    assert got["xla"] == got["pallas-interpret"] == [1, 2, 3, 4, 6, 7, 8]
