"""Serving-tier parity + elasticity suite.

The two kv_pool regressions this pins down:

* the first generated token must be the argmax of the prefill's final
  logits — the old admission path discarded them and re-fed the last
  prompt token at an already-advanced position (double-feed), so every
  request's first token was wrong;
* ``SlotPool.release`` must zero the slot's recurrent state — a recycled
  slot used to leak the previous request's SSM/RWKV state into the next
  occupant's first step.

Both show up as engine-vs-``reference_decode`` mismatches, which is the
suite's master contract: continuous batching, slot reuse, and mid-decode
reconfiguration must all be token-invisible.
"""

import json

import numpy as np
import pytest
import jax

from repro.configs import canon, get_config, reduced
from repro.models import transformer
from repro.serving import (Request, RequestSource, ServingConfig,
                           ServingEngine, reference_decode)

MAX_SEQ = 24
ARCHS = ["qwen3-14b", "rwkv6-7b"]


@pytest.fixture(scope="module", params=ARCHS)
def model(request):
    cfg = reduced(get_config(canon(request.param)))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


def _prompts(cfg, n, length=4, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, length) for _ in range(n)]


def _run(eng, reqs, cap=200, reconfigure=None):
    for r in reqs:
        eng.submit(r)
    done = []
    while len(done) < len(reqs) and eng.steps < cap:
        done += eng.tick()
        if reconfigure is not None and eng.steps == 2:
            reconfigure()
    assert len(done) == len(reqs)
    return done


# ------------------------------------------------------- decode parity --

def test_engine_matches_reference(model):
    """Continuous batching is token-invisible — including the FIRST output
    token (the double-feed regression: re-feeding prompt[-1] at an
    advanced position shifts every request's token 0)."""
    _, cfg, params = model
    eng = ServingEngine(cfg, params, n_slots=4, max_seq=MAX_SEQ,
                        n_instances=2)
    reqs = [Request(uid=i, prompt=p, max_new=4)
            for i, p in enumerate(_prompts(cfg, 3))]
    for r in _run(eng, reqs):
        assert list(r.out) == reference_decode(cfg, params, r.prompt,
                                               r.max_new, MAX_SEQ), r.uid


def test_slot_reuse_no_state_leak(model):
    """A recycled slot must behave like a fresh one: with one slot, the
    second request decodes through the slot the first just vacated — any
    leaked recurrent state (the release() regression) shifts its tokens
    on the recurrent archs."""
    _, cfg, params = model
    eng = ServingEngine(cfg, params, n_slots=1, max_seq=MAX_SEQ,
                        n_instances=1)
    pa, pb = _prompts(cfg, 2, seed=5)
    (ra,) = _run(eng, [Request(uid=0, prompt=pa, max_new=5)])
    assert ra.slot == 0
    (rb,) = _run(eng, [Request(uid=1, prompt=pb, max_new=5)])
    assert rb.slot == 0            # same physical slot, reused
    assert list(rb.out) == reference_decode(cfg, params, pb, 5, MAX_SEQ)


def test_release_zeroes_slot(model):
    """After release, the freed slot's caches AND recurrent states are
    bit-identical to a fresh pool's."""
    _, cfg, params = model
    eng = ServingEngine(cfg, params, n_slots=2, max_seq=MAX_SEQ,
                        n_instances=1)
    _run(eng, [Request(uid=0, prompt=_prompts(cfg, 1)[0], max_new=3)])
    assert sorted(eng.pool.free) == [0, 1]
    for leaf in jax.tree.leaves((eng.pool.caches, eng.pool.states)):
        assert not np.asarray(leaf).any()


# ---------------------------------------------------------- elasticity --

def test_reconfigure_vsn_mid_decode_invariance(model):
    """The f_mu rewrite mid-decode changes no output token and moves no
    KV bytes."""
    _, cfg, params = model
    eng = ServingEngine(cfg, params, n_slots=4, max_seq=MAX_SEQ,
                        n_instances=4)
    eng.pool.reconfigure_vsn(1)
    rec = {}

    def scale_up():
        rec["moved"], _ = eng.reconfigure(4, mode="vsn")

    reqs = [Request(uid=i, prompt=p, max_new=5)
            for i, p in enumerate(_prompts(cfg, 4, seed=2))]
    for r in _run(eng, reqs, reconfigure=scale_up):
        assert list(r.out) == reference_decode(cfg, params, r.prompt,
                                               r.max_new, MAX_SEQ), r.uid
    assert rec["moved"] == 0
    assert eng.pool.n_active == 4 and eng.pool.kv_bytes_moved == 0


def test_sn_moves_bytes_vsn_does_not():
    """The SN baseline ships the occupied moved slots' KV (free slots are
    skipped by the accounting); VSN moves nothing for the same switch."""
    cfg = reduced(get_config(canon("qwen3-14b")))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=4, max_seq=MAX_SEQ,
                        n_instances=4)
    eng.pool.reconfigure_vsn(1)
    # occupy two slots, leave two free
    for i, p in enumerate(_prompts(cfg, 2, seed=3)):
        eng.submit(Request(uid=i, prompt=p, max_new=8))
    eng.tick()
    occupied = eng.pool.occupied()
    assert len(occupied) == 2
    old = eng.pool.fmu.copy()
    moved, _ = eng.reconfigure(4, mode="sn")
    should_move = [s for s in occupied if old[s] != eng.pool.fmu[s]]
    assert moved == len(should_move) * eng.pool.slot_bytes() > 0
    assert eng.pool.kv_bytes_moved == moved

    eng2 = ServingEngine(cfg, params, n_slots=4, max_seq=MAX_SEQ,
                         n_instances=4)
    eng2.pool.reconfigure_vsn(1)
    moved2, _ = eng2.reconfigure(4, mode="vsn")
    assert moved2 == 0 and eng2.pool.kv_bytes_moved == 0


# ------------------------------------------------------- stream runtime --

def _serving_stack(*, ingest_hosts=0, controller="none", ticks=6,
                   slo_target_ms=50.0, obs=None, seed=11):
    from repro.api import RuntimeConfig, build_runtime
    from repro.io.sources import RateSchedule
    scfg = ServingConfig(arch="qwen3-14b", reduced=True, n_slots=4,
                         max_seq=MAX_SEQ, n_instances=4)
    cfg = RuntimeConfig(serving=scfg, n_sources=2,
                        ingest_hosts=ingest_hosts, n_active=1,
                        controller=controller,
                        slo_target_p99_ms=slo_target_ms,
                        obs=obs or {})
    src = RequestSource(schedule=RateSchedule([(0, 60.0)]), ticks=ticks,
                        lanes=2, prompt_len=4, max_new=4, seed=seed,
                        n_inputs=2, k_virt=4, tick_ms=50,
                        drain_ticks=ticks * 2 * 4 // 4 + 12)
    return build_runtime(cfg, src), src


def test_async_stream_parity():
    """Requests through the full async stack (tuple encode -> runtime ->
    admission -> batched decode) come out token-identical to the
    straight-line reference."""
    rt, src = _serving_stack()
    rt.run()
    pipe = rt.pipeline
    assert len(pipe.finished) == src.total_requests > 0
    cfg, params = pipe.engine.cfg, pipe.engine.params
    for r in pipe.finished:
        assert list(r.out) == reference_decode(cfg, params, r.prompt,
                                               r.max_new, MAX_SEQ), r.uid


def test_ingest_tier_parity():
    """The same request stream through the 2-host hierarchical ingest tier
    serves every request with per-uid outputs identical to the tierless
    run (heartbeat lanes keep the watermark frontier moving)."""
    rt0, src0 = _serving_stack(seed=13)
    rt0.run()
    want = {r.uid: list(r.out) for r in rt0.pipeline.finished}
    rt, src = _serving_stack(ingest_hosts=2, seed=13)
    rt.run()
    got = {r.uid: list(r.out) for r in rt.pipeline.finished}
    assert len(got) == src.total_requests == src0.total_requests
    assert got == want


def test_slo_breach_drives_scale_up():
    """Closed loop: an unmeetably tight p99 decode target makes the SLO
    engine breach and the controller provision replicas mid-run — visible
    in the RunReport (breaches + committed switch) and in the pool."""
    from repro import obs as _obs
    prev = _obs.get()
    try:
        rt, src = _serving_stack(
            controller="slo", ticks=10, slo_target_ms=1e-3,
            obs={"enabled": True, "trace": True,
                 "slo_rules": [{"name": "decode_p99",
                                "metric": "span.serve.decode",
                                "threshold": 1e-6, "min_count": 4,
                                "cooldown_s": 0.0}]})
        rep = rt.run()
    finally:
        _obs.set_current(prev)
    pipe = rt.pipeline
    assert len(pipe.finished) == src.total_requests
    assert rep.switches >= 1 and rep.reconfig_trace
    assert pipe.reconfig_events and pipe.reconfig_events[0]["n_active"] > 1
    assert pipe.reconfig_events[0]["kv_bytes_moved"] == 0
    assert pipe.engine.pool.n_active > 1
    assert rep.slo_breaches


# --------------------------------------------------------------- config --

def test_runtime_config_serving_roundtrip():
    from repro.api import RuntimeConfig
    cfg = RuntimeConfig(serving=ServingConfig(arch="rwkv6-7b", n_slots=2),
                        controller="slo", slo_target_p99_ms=12.5)
    d = json.loads(json.dumps(cfg.to_json()))
    cfg2 = RuntimeConfig.from_json(d)
    assert isinstance(cfg2.serving, ServingConfig)
    assert cfg2.serving == cfg.serving
    assert cfg2.slo_target_p99_ms == 12.5


def test_serving_rejects_checkpointing(tmp_path):
    from repro.api import RuntimeConfig, build_runtime
    cfg = RuntimeConfig(serving=ServingConfig(), checkpoint_dir=str(tmp_path),
                        checkpoint_every=4)
    with pytest.raises(ValueError, match="checkpoint"):
        build_runtime(cfg, [])
