"""Mosaic lowering lint as a tier-1 regression gate (ISSUE 5 satellite).

A rank-1 BlockSpec or a 1-D iota/``jnp.arange`` can never silently
reappear in any registered kernel: the structural lint runs over every
``dispatch.register_lint`` case on every tier-1 run, and the deliberately-
bad fixtures below pin that the lint actually *catches* the offenders the
Pallas interpreter hides.  The full-Mosaic AOT smoke at the bottom runs
only under ``REPRO_TPU=1`` with TPU hardware attached (the CI job stub is
ready for bring-up).
"""

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

# importing the ops modules registers kernels AND their lint cases
import repro.kernels.flash_attention.ops    # noqa: F401
import repro.kernels.linear_scan.ops        # noqa: F401
import repro.kernels.scalegate_merge.ops    # noqa: F401
import repro.kernels.segment_aggregate.ops  # noqa: F401
import repro.kernels.window_join.ops        # noqa: F401
from repro.kernels import dispatch, lowering

KERNELS = ("scalegate_merge", "scalegate_merge_stacked",
           "segment_aggregate", "window_join",
           "flash_attention", "linear_scan")


def test_every_registered_kernel_has_a_lint_case():
    """register_kernel and register_lint must stay paired: a new kernel
    without a lowering case would dodge the whole gate."""
    assert set(dispatch.registered()) == set(dispatch.lint_cases()) \
        == set(KERNELS)


@pytest.mark.parametrize("name", KERNELS)
def test_kernel_passes_structural_lint(name):
    report = lowering.lint_case(dispatch.lint_cases()[name]())
    assert report.ok, "\n".join(report.errors)


def test_lint_registered_runs_all_kernels():
    reports = lowering.lint_registered()
    assert set(reports) == set(KERNELS)
    assert all(r.ok for r in reports.values())


# ------------------------------------------------- the lint catches bugs --

def _bad_case(bad_specs: bool, bad_iota: bool) -> lowering.KernelCase:
    """A minimal kernel reintroducing the exact offenders the 2-D rewrites
    removed: rank-1 BlockSpecs/out_shape and a 1-D ``jnp.arange``."""
    if bad_specs:
        specs = dict(
            grid=(1,),
            in_specs=[pl.BlockSpec((128,), lambda i: (0,))],
            out_specs=pl.BlockSpec((128,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((128,), jnp.int32))
        arg = jnp.zeros((128,), jnp.int32)
    else:
        specs = dict(
            grid=(1,),
            in_specs=[pl.BlockSpec((1, 128), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, 128), jnp.int32))
        arg = jnp.zeros((1, 128), jnp.int32)

    def kern(x_ref, o_ref):
        x = x_ref[...]
        if bad_iota:
            x = x + jnp.arange(128, dtype=jnp.int32).reshape(x.shape)
        o_ref[...] = x

    def fn(x):
        return pl.pallas_call(kern, **specs, interpret=True)(x)

    return lowering.KernelCase("bad", fn=fn, args=(arg,), specs=specs)


def test_lint_rejects_rank1_blockspecs_and_out_shape():
    report = lowering.lint_case(_bad_case(bad_specs=True, bad_iota=False))
    assert not report.ok
    assert any("in_specs[0]" in e for e in report.errors)
    assert any("out_specs[0]" in e for e in report.errors)
    assert any("out_shape[0]" in e for e in report.errors)


def test_lint_rejects_1d_iota_inside_kernel_body():
    report = lowering.lint_case(_bad_case(bad_specs=False, bad_iota=True))
    assert not report.ok
    assert any("1-D iota" in e for e in report.errors)


def test_lint_ignores_1d_iota_outside_pallas_call():
    """The padding shims around the kernels may use jnp.arange freely —
    only the Mosaic-bound body is constrained."""
    good = _bad_case(bad_specs=False, bad_iota=False)

    def fn_with_host_arange(x):
        return good.fn(x + jnp.arange(128, dtype=jnp.int32).reshape(1, 128))

    case = lowering.KernelCase("host-arange", fn=fn_with_host_arange,
                               args=good.args, specs=good.specs)
    assert lowering.lint_case(case).ok


def test_lint_flags_missing_pallas_call():
    case = lowering.KernelCase(
        "no-call", fn=lambda x: x + 1,
        args=(jnp.zeros((1, 128), jnp.int32),),
        specs=dict(in_specs=[], out_specs=[], out_shape=[]))
    report = lowering.lint_case(case)
    assert not report.ok and any("no pallas_call" in e
                                 for e in report.errors)


# ------------------------------------------------------- AOT Mosaic smoke --

@pytest.mark.skipif(not lowering.smoke_requested(),
                    reason="REPRO_TPU=1 not set (TPU bring-up job only)")
@pytest.mark.parametrize("name", KERNELS)
def test_lowering_smoke_full_mosaic(name):
    """jit(...).lower() through the real Mosaic pipeline — the bring-up
    gate for the `pallas` (non-interpret) backend on hardware.

    REPRO_TPU=1 asserts the operator *meant* to run on TPU hardware: a
    missing TPU backend is then a red job, not a silently-green all-skip
    (the CI stub must not look like a passed Mosaic smoke)."""
    case = dispatch.lint_cases()[name]()
    skip = lowering.lowering_smoke(case)
    if skip is not None:
        pytest.fail(f"REPRO_TPU=1 but {skip} — point the job's runner at "
                    "TPU hardware (README runbook step 5)")
