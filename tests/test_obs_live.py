"""Live observability plane (PR-9) — scrape endpoint, sampling, exemplar
timelines, SLO engine.

The contracts under test:
  * HeadSampler: deterministic stride admission per kind, exact
    attempt/kept accounting, adaptive budget backoff and recovery;
    sampling thins flight/tracer *detail* only — registry counters and
    span histograms stay exact;
  * ExemplarTimelines: the shared (src, tau) predicate agrees across
    independent instances (no cross-process coordination), the
    mark/bind/mark_tick lifecycle completes timelines in stage order,
    child mark fragments fold with wall-offset normalization;
  * SloEngine: windowed threshold + burn-rate rules, min_count gating,
    per-rule cooldown; end-to-end, a breach reaches
    ``controller.observe_live``, lands in the RunReport, and triggers a
    flight dump;
  * ObsServer: in-run HTTP scrape serving Prometheus text and the
    schema-v2 JSON snapshot; concurrent scrapes mid-run are
    lock-consistent (schema-valid, counters monotone), and the endpoint
    survives a SIGKILLed ingest leaf (chaos) still serving valid output.
"""

import glob
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import api, obs
from repro.obs import (ExemplarTimelines, HeadSampler, ObsConfig, SloEngine,
                       SloRule)
from repro.obs.registry import MetricsRegistry, validate_snapshot
from repro.obs.sample import _WINDOW

K = 64
N_SRC = 4


@pytest.fixture
def obs_env():
    """Install a fresh Obs for the test; always restore the previous
    global (and stop any server the test started) afterwards."""
    prev = obs.get()
    made = []

    def make(**kw):
        o = obs.install(ObsConfig(**kw))
        made.append(o)
        return o

    yield make
    for o in made:
        o.stop_server()
    obs.set_current(prev)


def agg_stream(n_ticks=6, seed=0, tick=16, n_sources=N_SRC):
    from repro.data import datagen
    rng = np.random.default_rng(seed)
    return list(datagen.tweets(rng, n_ticks=n_ticks, tick=tick,
                               words_per_tweet=3, vocab=300, k_virt=K,
                               rate_per_tick=30, n_sources=n_sources))


def _get(url, timeout=10.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.headers.get("Content-Type", ""), r.read()


# --------------------------------------------------------- head sampler ---

def test_head_sampler_deterministic_strides():
    hs = HeadSampler(event_sample=0.25, span_sample=0.5,
                     rates={"noisy": 1.0 / 8.0})
    kept = [hs.admit_event("tick") for _ in range(100)]
    assert kept[0] and sum(kept) == 25            # 1-in-4, head admitted
    assert sum(hs.admit_event("noisy") for _ in range(80)) == 10
    assert sum(hs.admit_span("leaf.push") for _ in range(10)) == 5
    assert not any(HeadSampler(event_sample=0.0).admit_event("x")
                   for _ in range(10))            # rate 0 drops all
    snap = hs.snapshot()
    assert snap["events"]["tick"] == {"attempts": 100, "kept": 25,
                                      "rate": 0.25}
    assert snap["events"]["noisy"]["kept"] == 10  # per-kind override
    assert snap["adaptive"] is False


def test_head_sampler_adaptive_backoff_and_recovery():
    hs = HeadSampler(event_sample=1.0, budget_per_s=50.0)
    kept = sum(hs.admit_event("storm") for _ in range(5000))
    st = hs.snapshot()["events"]["storm"]
    # a tight loop wildly exceeds 50 events/s: the live rate backs off
    # below the configured ceiling, but attempts stay exactly counted
    assert st["attempts"] == 5000 and st["kept"] == kept < 5000
    assert st["rate"] < 1.0
    # a long quiet window recovers the rate toward the ceiling
    state = hs._events["storm"]
    backed_off = state.rate
    state.win_t0 = time.perf_counter() - 1000.0
    state.win_n = _WINDOW - 1
    hs.admit_event("storm")
    assert hs._events["storm"].rate > backed_off


def test_sampling_thins_detail_never_accounting(obs_env):
    """The core sampling invariant: span histograms and counters are exact
    under any sampling rate; only ring/finished-deque detail thins."""
    o = obs_env(enabled=True, trace=True, span_sample=0.25,
                event_sample=0.25)
    for _ in range(40):
        with obs.span("pipeline.step"):
            pass
        obs.event("tick")
        obs.counter_inc("bus.ticks")
    assert o.registry.histograms["span.pipeline.step"].count == 40
    assert o.registry.counters["bus.ticks"].value == 40
    assert len(o.tracer.finished) == 10
    assert len([e for e in o.flight.events if e["kind"] == "tick"]) == 10
    # the thinning is visible in the v2 snapshot's sampling section
    snap = o.snapshot()
    validate_snapshot(snap)
    assert snap["sampling"]["events"]["tick"] == {
        "attempts": 40, "kept": 10, "rate": 0.25}


# ------------------------------------------------------------ exemplars ---

def test_exemplar_timeline_lifecycle_and_shipping():
    clk = [0.0]
    parent = ExemplarTimelines(rate=0.5, clock=lambda: clk[0])
    child = ExemplarTimelines(rate=0.5, clock=lambda: clk[0])
    # the predicate is pure (src, tau) arithmetic: independent instances
    # (i.e. processes) agree with no coordination
    for src in range(4):
        for tau in range(32):
            assert parent.is_exemplar(src, tau) == child.is_exemplar(src,
                                                                     tau)
    srcs = np.arange(8, dtype=np.int64)
    taus = 3 * np.arange(8, dtype=np.int64)
    hits = [(int(s), int(t)) for s, t in zip(srcs, taus)
            if parent.is_exemplar(int(s), int(t))]
    assert hits
    parent.scan(srcs, taus, np.ones(8, bool), "admit")
    assert len(parent._open) == len(hits)
    # child marks the same tuples at its own stage, ships fragments
    for s, t in hits:
        child.mark(s, t, "leaf_push", wall=100.0)
    frags = child.drain_marks()
    assert frags and not child._open
    parent.ingest_marks(frags, wall_offset=-99.5)     # child wall -> 0.5
    # runtime binds the tick, then tick-granular stages complete it
    for s, t in hits:
        parent.bind_tick(s, t, 7)
    clk[0] = 1.0
    parent.mark_tick(7, "drain")
    clk[0] = 2.0
    parent.mark_tick(7, "emit")
    done = parent.completed()
    assert len(done) == len(hits)
    for tl in done:
        stages = [s for s, _ in tl["timeline"]]
        walls = [w for _, w in tl["timeline"]]
        assert stages == ["admit", "leaf_push", "drain", "emit"]
        assert walls == sorted(walls) == [0.0, 0.5, 1.0, 2.0]
    # snapshot marks completion; equal walls fall back to stage rank
    assert all(tl["complete"] for tl in parent.snapshot())
    tie = ExemplarTimelines(rate=1.0, clock=lambda: 5.0)
    tie.mark(0, 0, "dispatch", wall=5.0)
    tie.mark(0, 0, "stage", wall=5.0)
    tie.bind_tick(0, 0, 1)
    tie.mark_tick(1, "emit", wall=5.0)
    assert [s for s, _ in tie.completed()[0]["timeline"]] == [
        "stage", "dispatch", "emit"]


def test_exemplar_timelines_end_to_end(obs_env):
    """A real tiered run with exemplar_rate on: completed per-tuple
    timelines cross admission -> leaf push -> root merge -> stage ->
    dispatch -> drain -> emit in monotone wall order and surface in the
    RunReport and the v2 snapshot."""
    from repro.io.sources import ReplaySource

    obs_env(enabled=False)          # build_runtime installs from config
    batches = agg_stream(n_ticks=6)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=64, n_sources=N_SRC,
        ingest_hosts=2, leaf_cap=32, root_cap=64,
        obs=ObsConfig(enabled=True, trace=False, exemplar_rate=0.25))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    rep = rt.run()
    o = obs.get()
    tls = rep.exemplar_timelines
    assert tls, "no exemplar timelines completed"
    seen = set()
    for tl in tls:
        assert o.timeline.is_exemplar(tl["src"], tl["tau"])
        walls = [w for _, w in tl["timeline"]]
        assert walls == sorted(walls)
        seen |= {s for s, _ in tl["timeline"]}
    assert {"admit", "leaf_push", "root_merge", "stage", "dispatch",
            "drain", "emit"} <= seen
    snap = o.snapshot()
    validate_snapshot(snap)
    assert any(e.get("complete") for e in snap["exemplars"])


# ----------------------------------------------------------- SLO engine ---

def test_slo_threshold_rule_breach_and_cooldown():
    reg = MetricsRegistry()
    eng = SloEngine([SloRule(name="p99", metric="lat", threshold=1e-3,
                             quantile=0.99, window_s=30.0, min_count=8,
                             cooldown_s=5.0)])
    # under min_count: no evaluation at all
    for _ in range(4):
        reg.observe("lat", 0.5)
    assert eng.evaluate(reg, now=1000.0) == []
    for _ in range(8):
        reg.observe("lat", 0.5)
    b = eng.evaluate(reg, now=1001.0)
    assert len(b) == 1 and b[0].rule == "p99" and b[0].value > 1e-3
    assert b[0].to_dict()["metric"] == "lat"
    # still breaching, but inside the cooldown window
    for _ in range(8):
        reg.observe("lat", 0.5)
    assert eng.evaluate(reg, now=1002.0) == []
    # past the cooldown it fires again
    for _ in range(8):
        reg.observe("lat", 0.5)
    assert len(eng.evaluate(reg, now=1010.0)) == 1
    assert eng.total_breaches == 2
    assert eng.snapshot()["p99"]["breaches"] == 2


def test_slo_burn_rate_rule_and_healthy_metric():
    reg = MetricsRegistry()
    eng = SloEngine([
        SloRule(name="burn", metric="lat", threshold=1e-2,
                kind="burn_rate", budget=0.10, burn_limit=1.0,
                window_s=30.0, min_count=10, cooldown_s=0.0),
        SloRule(name="quiet", metric="lat", threshold=10.0,
                quantile=0.99, min_count=10, cooldown_s=0.0)])
    # 50% of observations violate a 10% budget: burn rate 5 >= limit 1;
    # the healthy threshold rule on the same metric stays silent
    for i in range(20):
        reg.observe("lat", 1.0 if i % 2 else 1e-4)
    b = eng.evaluate(reg, now=2000.0)
    assert [x.rule for x in b] == ["burn"]
    assert b[0].kind == "burn_rate" and b[0].value >= 1.0
    # all-healthy observations: no breach even past cooldown
    reg2 = MetricsRegistry()
    for _ in range(20):
        reg2.observe("lat", 1e-4)
    eng2 = SloEngine([SloRule(name="burn", metric="lat", threshold=1e-2,
                              kind="burn_rate", budget=0.10,
                              min_count=10, cooldown_s=0.0)])
    assert eng2.evaluate(reg2, now=2000.0) == []


def test_slo_breach_reaches_controller_report_and_dump(tmp_path, obs_env):
    """End-to-end acceptance: an unmeetable tick-latency SLO breaches
    during a controller run; the breach reaches observe_live (counted +
    pressure applied), lands in RunReport.slo_breaches, is mirrored as an
    unsampled flight event + counters, and triggers a flight-slo dump."""
    from repro.io.sources import ReplaySource

    obs_env(enabled=False)
    dump_dir = tmp_path / "dump"
    batches = agg_stream(n_ticks=10)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=64, n_sources=N_SRC,
        controller="threshold", capacity_per_instance=5000.0,
        obs=ObsConfig(enabled=True, trace=False, dump_dir=str(dump_dir),
                      event_sample=0.5,   # breach events are never sampled
                      slo_rules=[dict(name="tick_p99",
                                      metric="bus.tick_latency_s",
                                      threshold=1e-9, quantile=0.99,
                                      window_s=30.0, min_count=2,
                                      cooldown_s=0.0)]))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    rep = rt.run()
    o = obs.get()
    assert rt.runtime.controller.slo_breaches_seen >= 1
    assert rep.slo_breaches and rep.slo_breaches[0]["rule"] == "tick_p99"
    assert o.registry.counters["slo.breaches"].value >= 1
    assert o.registry.counters["slo.breach.tick_p99"].value >= 1
    n_breach_events = len([e for e in o.flight.events
                           if e["kind"] == "slo_breach"])
    assert n_breach_events == int(
        o.registry.counters["slo.breaches"].value)    # unsampled
    dumps = glob.glob(str(dump_dir / "flight-slo-*.json"))
    assert dumps, "SLO breach produced no flight dump"
    d = json.loads(open(dumps[0]).read())
    assert d["reason"].startswith("slo_breach:tick_p99")
    assert any(e["kind"] == "slo_breach" for e in d["events"])


# ------------------------------------------------------- scrape endpoint --

def test_scrape_endpoint_serves_prom_and_v2_snapshot(obs_env):
    o = obs_env(enabled=True, trace=True, event_sample=0.5,
                exemplar_rate=1.0)
    o.registry.inc("bus.ticks", 5)
    with obs.span("root.merge"):
        pass
    obs.event("tick", tick_id=0)
    o.start_server(port=0)
    assert o.server is not None and o.server.port != 0
    url = o.server.url
    status, ctype, body = _get(url + "/metrics")
    text = body.decode()
    assert status == 200 and "version=0.0.4" in ctype
    assert "bus_ticks 5" in text and "# TYPE bus_ticks counter" in text
    assert "obs_sampled_total{" in text               # sampler metadata
    assert text.endswith("\n")
    status, ctype, body = _get(url + "/snapshot")
    assert status == 200 and "application/json" in ctype
    snap = json.loads(body)
    validate_snapshot(snap)
    assert snap["schema_version"] == 2
    assert snap["counters"]["bus.ticks"] == 5
    assert snap["sampling"]["events"]["tick"]["attempts"] == 1
    assert _get(url + "/metrics.json")[0] == 200      # alias
    assert _get(url + "/healthz")[2] == b"ok\n"
    with pytest.raises(urllib.error.HTTPError):
        _get(url + "/nope")
    # the served port is itself a gauge, and start is idempotent
    assert o.registry.gauges["obs.serve_port"].value == o.server.port
    assert o.start_server(port=0) is o.server
    o.stop_server()
    assert o.server is None


def test_concurrent_scrapes_mid_run_are_consistent(obs_env):
    """Thread hammering /snapshot while a run mutates the registry: every
    response is schema-valid and per-thread bus.ticks never decreases
    (the snapshot is taken under the registry lock)."""
    from repro.io.sources import ReplaySource

    obs_env(enabled=False)
    batches = agg_stream(n_ticks=10, tick=32)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=64, n_sources=N_SRC,
        controller="threshold", capacity_per_instance=50.0,
        obs=ObsConfig(enabled=True, trace=True, serve_port=0))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    o = obs.get()
    url = o.server.url
    stop = threading.Event()
    errors, series = [], [[] for _ in range(3)]

    def scraper(idx):
        while not stop.is_set():
            try:
                snap = json.loads(_get(url + "/snapshot")[2])
                validate_snapshot(snap)
                series[idx].append(snap["counters"].get("bus.ticks", 0))
                prom = _get(url + "/metrics")[2].decode()
                assert prom.endswith("\n")
            except Exception as e:                    # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=scraper, args=(i,), daemon=True)
               for i in range(3)]
    for th in threads:
        th.start()
    rep = rt.run()
    time.sleep(0.05)                # a few post-run scrapes
    stop.set()
    for th in threads:
        th.join(timeout=10)
    assert not errors, errors
    scraped = [v for s in series for v in s]
    assert scraped, "no scrape completed during the run"
    for s in series:
        assert s == sorted(s), "bus.ticks went backwards mid-scrape"
    assert max(scraped) <= rep.ticks
    o.stop_server()


def test_scrape_survives_sigkilled_leaf_chaos(tmp_path, obs_env):
    """Chaos case: an ingest leaf is SIGKILLed mid-run while a scraper
    hammers the endpoint.  The runtime crashes (as designed), but every
    scrape that completed is schema-valid and the endpoint still serves
    consistent output after the crash."""
    from repro.ingest import LeafFailure
    from repro.io.sources import ReplaySource
    from repro.launch.recovery import _kill_leaf_when

    obs_env(enabled=False)
    batches = agg_stream(n_ticks=12, tick=32)
    cfg = api.RuntimeConfig(
        op="count", wa=50, ws=100, wt="multi", k_virt=K, out_cap=512,
        n_max=8, n_active=2, stash_cap=256, n_sources=N_SRC,
        ingest_hosts=2, ingest_worker="process", chan_cap=2,
        leaf_cap=128, root_cap=256,
        obs=ObsConfig(enabled=True, trace=True, serve_port=0,
                      dump_dir=str(tmp_path / "dump")))
    rt = api.build_runtime(cfg, ReplaySource(batches, n_inputs=N_SRC))
    o = obs.get()
    url = o.server.url
    stop = threading.Event()
    snaps, errors = [], []

    def scraper():
        while not stop.is_set():
            try:
                snap = json.loads(_get(url + "/snapshot")[2])
                validate_snapshot(snap)
                snaps.append(snap)
            except Exception as e:                    # pragma: no cover
                errors.append(e)
                return

    th = threading.Thread(target=scraper, daemon=True)
    th.start()
    wd = threading.Thread(target=_kill_leaf_when, args=(rt.tier, 6),
                          daemon=True)
    wd.start()
    with pytest.raises(LeafFailure):
        rt.run()
    stop.set()
    th.join(timeout=10)
    assert not errors, errors
    assert snaps, "no scrape completed"
    # the endpoint outlives the crashed run: one more consistent scrape
    snap = json.loads(_get(url + "/snapshot")[2])
    validate_snapshot(snap)
    assert snap["counters"]["bus.ticks"] >= snaps[-1]["counters"].get(
        "bus.ticks", 0)
    o.stop_server()
