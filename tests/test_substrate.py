"""Substrate tests: checkpoint fault tolerance, serving slot pool elasticity,
MoE dispatch equivalence (the paper's technique on the LM side), optimizer.
"""

import dataclasses
import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import checkpoint as C
from repro.configs import get_config, reduced
from repro.models import model as M, moe as moe_mod, transformer
from repro.models.config import ModelConfig, MoEConfig
from repro.optim import adamw, compress


# ------------------------------------------------------------- checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    C.save(d, 3, tree, async_=False)
    C.save(d, 7, jax.tree.map(lambda x: x * 2, tree), async_=False)
    assert C.latest_step(d) == 7
    got = C.restore(d, 7, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(tree["a"]) * 2)


def test_checkpoint_crash_drill(tmp_path):
    """A save that dies before the manifest commit is invisible: restart
    resumes from the last complete step (node-failure recovery)."""
    d = str(tmp_path)
    tree = {"w": jnp.ones(8)}
    C.save(d, 1, tree, async_=False)
    # simulate a crash mid-save of step 2: leaf written, no manifest
    broken = os.path.join(d, "step_00000002")
    os.makedirs(broken)
    np.save(os.path.join(broken, "leaf_00000.npy"), np.zeros(8))
    assert C.latest_step(d) == 1
    step, got = C.restore_latest(d, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(8))


def test_checkpoint_async(tmp_path):
    d = str(tmp_path)
    tree = {"w": jnp.full((32,), 5.0)}
    C.save(d, 1, tree, async_=True)
    C.wait(d)
    assert C.latest_step(d) == 1


def test_train_resume_equivalence(tmp_path):
    """Kill-and-resume == uninterrupted training (fault tolerance e2e)."""
    cfg = reduced(get_config("stablelm_12b"))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    opt = adamw.init_opt(params)
    ocfg = adamw.AdamWConfig(total_steps=10)
    step = jax.jit(lambda p, o, b: M.train_step(p, o, b, cfg=cfg,
                                                opt_cfg=ocfg, chunk=8))

    def batch(i):
        k = jax.random.PRNGKey(100 + i)
        return {"inputs": jax.random.randint(k, (2, 16), 0, cfg.vocab),
                "labels": jax.random.randint(k, (2, 16), 0, cfg.vocab),
                "mask": jnp.ones((2, 16), jnp.float32)}

    # uninterrupted: 4 steps
    p, o = params, opt
    for i in range(4):
        p, o, _ = step(p, o, batch(i))
    ref = np.asarray(jax.tree.leaves(p)[0], np.float32)

    # interrupted at step 2 + resume from checkpoint
    d = str(tmp_path)
    p2, o2 = params, opt
    for i in range(2):
        p2, o2, _ = step(p2, o2, batch(i))
    C.save(d, 2, (p2, o2), async_=False)
    del p2, o2                           # "crash"
    s, (p3, o3) = C.restore_latest(d, (params, opt))
    assert s == 2
    for i in range(2, 4):
        p3, o3, _ = step(p3, o3, batch(i))
    got = np.asarray(jax.tree.leaves(p3)[0], np.float32)
    np.testing.assert_allclose(got, ref, atol=1e-6)


# ---------------------------------------------------------------- serving --
def test_serving_engine_and_elasticity():
    from repro.serving.kv_pool import Request, ServingEngine
    cfg = reduced(get_config("qwen3_14b"))
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, n_slots=4, max_seq=32, n_instances=4)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=np.asarray([1, 2, 3]),
                           max_new=4, arrived=uid))
    done = []
    for _ in range(10):
        done += eng.tick()
        if len(done) == 3:
            break
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    # VSN scaling: zero KV movement; SN baseline: per-slot KV bytes
    v = eng.pool.reconfigure_vsn(2)
    assert v < 1024
    s = eng.pool.reconfigure_sn(4)
    assert s == eng.pool.kv_bytes_moved
    # with live slots the SN path must ship whole KV slots
    eng2 = ServingEngine(cfg, params, n_slots=4, max_seq=32, n_instances=4)
    eng2.submit(Request(uid=0, prompt=np.asarray([1, 2]), max_new=8,
                        arrived=0))
    eng2.tick()
    moved = eng2.pool.reconfigure_sn(1)
    assert moved > 10 * v                # KV slot >> routing table


# -------------------------------------------------------- MoE dispatchers --
def _moe_cfg(dispatch, cf=8.0):
    return ModelConfig(
        name="moe-test", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
        d_head=8, d_ff=64, vocab=64, kind="moe", dtype="float32",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
                      dispatch=dispatch, capacity_factor=cf))


def test_moe_vsn_equals_sn_with_headroom():
    """With capacity >> load both dispatchers compute the same function —
    the paper's semantic-equivalence claim for VSN vs SN (Theorem 2/3
    transplanted to expert routing)."""
    key = jax.random.PRNGKey(1)
    cfg_v, cfg_s = _moe_cfg("vsn"), _moe_cfg("sn")
    p = moe_mod.init_moe(key, cfg_v, jnp.float32)
    x = jax.random.normal(key, (2, 8, 32), jnp.float32)
    yv, dv = moe_mod.moe_forward(p, x, cfg_v)
    ys, ds = moe_mod.moe_forward(p, x, cfg_s)
    assert int(dv) == 0 and int(ds) == 0
    # VSN reduces its partial outputs in bf16 (§Perf A1): tolerance is one
    # bf16 ulp of the activation magnitude, not f32-exact.
    np.testing.assert_allclose(np.asarray(yv), np.asarray(ys), atol=3e-2,
                               rtol=1e-2)


def test_moe_dropping_is_counted():
    cfg = _moe_cfg("vsn", cf=0.05)
    key = jax.random.PRNGKey(1)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, 32), jnp.float32)
    _, dropped = moe_mod.moe_forward(p, x, cfg)
    assert int(dropped) > 0              # overflow surfaced, never silent


def test_moe_grads_flow():
    cfg = _moe_cfg("vsn")
    key = jax.random.PRNGKey(2)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, 32), jnp.float32)

    def loss(p):
        y, _ = moe_mod.moe_forward(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw.init_opt(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_compress_error_feedback():
    """Quantization error is carried, not lost: the running sum of
    dequantized grads tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(0, 1, (64,)).astype(np.float32) for _ in range(50)]
    res = compress.init_residual({"g": jnp.zeros(64)})
    total_q = np.zeros(64)
    for g in g_true:
        q, s, res = compress.compress({"g": jnp.asarray(g)}, res)
        total_q += np.asarray(compress.decompress(q, s)["g"])
    total = np.sum(g_true, axis=0)
    # error feedback bounds the *cumulative* error by one quantization step
    max_step = max(np.abs(g).max() for g in g_true) / 127
    assert np.abs(total_q - total).max() < 2 * max_step * 1.5 + 1e-3
