"""Property-based cross-backend conformance fuzz (ISSUE 5 satellite).

Every ``<name>_op`` entry point must present *identical stream semantics*
on every backend — the Röger/Mayer elasticity-survey point: reconfiguration
parity between backends is meaningless unless the backends agree tuple-for-
tuple in the first place.  The suite drives randomized shapes (including
the padding edges the 2-D tiled rewrites introduced: non-multiple-of-128
hit blocks, non-multiple-of-8 join blocks, non-power-of-two merge ticks),
duplicate keys, all-equal and all-INF tau, single-source and all-invalid
lanes through ``xla`` ⇄ ``pallas-interpret`` and asserts *exact* parity on
integer outputs (order, readiness, watermark, counts, comparisons) and
tight-atol parity on float accumulations.

Shapes are drawn from small buckets (each distinct shape is a fresh jit
trace); runs are derandomized for a deterministic CI signal.  Works with
real hypothesis or the deterministic ``tests/_hypothesis_fallback`` shim.
Heavy sweeps live at the bottom behind ``@pytest.mark.slow``.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.watermark import INF_TIME
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.linear_scan.ops import linear_scan_op
from repro.kernels.scalegate_merge.ops import scalegate_merge_op
from repro.kernels.segment_aggregate.ops import segment_aggregate_op
from repro.kernels.window_join.ops import window_join_op

BACKENDS = ("xla", "pallas-interpret")
INF = int(INF_TIME)


# ------------------------------------------------------------------ merge --

def _merge_batch(n, n_sources, seed, mode):
    rng = np.random.default_rng(seed)
    tau = rng.integers(0, 50, n).astype(np.int32)     # heavy tau duplicates
    src = rng.integers(0, n_sources, n).astype(np.int32)
    valid = rng.random(n) < 0.8
    if mode == "ties":
        tau = rng.integers(0, 3, n).astype(np.int32)
    elif mode == "all_equal":
        tau[:] = 7
        valid[:] = True
    elif mode == "all_inf":
        tau[:] = INF
        valid[:] = True
    elif mode == "single_source":
        src[:] = 0                  # other frontiers stay empty -> W = -1
    elif mode == "all_invalid":
        valid[:] = False
    return tau, src, valid


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.sampled_from([5, 32, 48, 128]),      # incl. non-power-of-two
       st.sampled_from([1, 2, 4]),
       st.integers(0, 10 ** 6),
       st.sampled_from(["random", "ties", "all_equal", "all_inf",
                        "single_source", "all_invalid"]))
def test_scalegate_merge_conformance(n, n_sources, seed, mode):
    tau, src, valid = _merge_batch(n, n_sources, seed, mode)
    got = {b: scalegate_merge_op(tau, src, valid, n_sources=n_sources,
                                 backend=b) for b in BACKENDS}
    o_x, r_x, w_x = (np.asarray(a) for a in got["xla"])
    o_p, r_p, w_p = (np.asarray(a) for a in got["pallas-interpret"])
    # (tau, lane) keys are unique: the total order itself is exact
    np.testing.assert_array_equal(o_x, o_p)
    np.testing.assert_array_equal(r_x, r_p)
    assert int(w_x[0]) == int(w_p[0])
    # independent oracle: the documented (tau, arrival) lexicographic order
    key = np.where(valid, tau.astype(np.int64), INF)
    np.testing.assert_array_equal(o_x, np.lexsort((np.arange(n), key)))
    # readiness = valid and tau <= W, in sorted positions
    np.testing.assert_array_equal(
        r_x, (valid[o_x] & (tau[o_x].astype(np.int64) <= int(w_x[0]))))


# -------------------------------------------------------------- aggregate --

@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.sampled_from([1, 16, 33, 128]),      # incl. lane-padding edges
       st.sampled_from([8, 32]),
       st.sampled_from([1, 4]),
       st.sampled_from([1, 3]),
       st.integers(0, 10 ** 6))
def test_segment_aggregate_conformance(n, k, s, w, seed):
    rng = np.random.default_rng(seed)
    # keys out of range on both sides + duplicates; integer-valued floats
    # keep every partial sum exactly representable -> exact parity
    keys = rng.integers(-2, k + 3, n).astype(np.int32)
    slots = rng.integers(0, s, n).astype(np.int32)
    vals = rng.integers(0, 3, (n, w)).astype(np.float32)
    acc = rng.integers(0, 5, (k, s, w)).astype(np.float32)
    outs = [np.asarray(segment_aggregate_op(keys, slots, vals, acc,
                                            tile_k=tile, backend=b))
            for b in BACKENDS for tile in (k, 8)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    # conservation: in-range hits land exactly once
    in_range = (keys >= 0) & (keys < k)
    assert outs[0].sum() == acc.sum() + vals[in_range].sum()


# ------------------------------------------------------------------- join --

def _join_case(b, k, r, seed, mode):
    rng = np.random.default_rng(seed)
    new_tau = np.sort(rng.integers(50, 120, b)).astype(np.int32)
    new_src = rng.integers(0, 2, b).astype(np.int32)
    # integer payloads: the |d| <= band boundary is exact on every backend
    new_pay = rng.integers(0, 12, (b, 2)).astype(np.float32)
    st_tau = rng.integers(0, 110, (k, r)).astype(np.int32)
    st_tau[rng.random((k, r)) < 0.3] = -1
    st_src = rng.integers(0, 2, (k, r)).astype(np.int32)
    st_pay = rng.integers(0, 12, (k, r, 2)).astype(np.float32)
    if mode == "all_invalid":                  # static-batch padding lanes
        new_tau[:] = INF
    elif mode == "empty_store":
        st_tau[:] = -1
    elif mode == "single_stream":
        new_src[:] = 0
        st_src[:] = 0                          # no opposite pairs at all
    return new_tau, new_src, new_pay, st_tau, st_src, st_pay


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.sampled_from([1, 7, 8, 30]),         # incl. non-multiple-of-8
       st.sampled_from([16, 64]),
       st.sampled_from([2, 5]),
       st.integers(0, 10 ** 6),
       st.sampled_from(["random", "all_invalid", "empty_store",
                        "single_stream"]))
def test_window_join_conformance(b, k, r, seed, mode):
    args = _join_case(b, k, r, seed, mode)
    got = {bk: window_join_op(*args, ws=40, band=4.0, tile_k=16, backend=bk)
           for bk in BACKENDS}
    c_x, n_x = got["xla"]
    c_p, n_p = got["pallas-interpret"]
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_p))
    assert int(n_x) == int(n_p)
    if mode in ("all_invalid", "empty_store", "single_stream"):
        assert int(n_x) == 0 and not np.asarray(c_x).any()


# -------------------------------------------------------------- attention --

@settings(max_examples=6, deadline=None, derandomize=True)
@given(st.sampled_from([(16, 16, 1), (1, 64, 1), (16, 32, 2)]),  # decode+GQA
       st.booleans(),
       st.sampled_from([None, 8]),
       st.integers(0, 10 ** 6))
def test_flash_attention_conformance(shape, causal, window, seed):
    sq, skv, n_rep = shape
    rng = np.random.default_rng(seed)
    bh_kv, d = 2, 16
    q = rng.normal(0, 1, (bh_kv * n_rep, sq, d)).astype(np.float32)
    k = rng.normal(0, 1, (bh_kv, skv, d)).astype(np.float32)
    v = rng.normal(0, 1, (bh_kv, skv, d)).astype(np.float32)
    outs = [np.asarray(flash_attention_op(
        q, k, v, causal=causal, window=window, n_rep=n_rep,
        blk_q=min(16, sq), blk_k=16, backend=b)) for b in BACKENDS]
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-5)
    assert np.isfinite(outs[0]).all()


# ------------------------------------------------------------------- scan --

@settings(max_examples=6, deadline=None, derandomize=True)
@given(st.sampled_from([(1, 16, 4, 4), (2, 64, 8, 16)]),
       st.booleans(),
       st.integers(0, 10 ** 6))
def test_linear_scan_conformance(shape, bonus, seed):
    bh, t, dk, dv = shape
    rng = np.random.default_rng(seed)
    r = rng.normal(0, 1, (bh, t, dk)).astype(np.float32)
    k = rng.normal(0, 1, (bh, t, dk)).astype(np.float32)
    v = rng.normal(0, 1, (bh, t, dv)).astype(np.float32)
    w = rng.uniform(0.5, 0.99, (bh, t, dk)).astype(np.float32)
    u = rng.normal(0, 1, (bh, dk)).astype(np.float32) if bonus else None
    outs = [np.asarray(linear_scan_op(r, k, v, w, u, chunk=16, backend=b))
            for b in BACKENDS]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4)


# ---------------------------------------------------- TIE_BREAK contract --

@settings(max_examples=8, deadline=None, derandomize=True)
@given(st.integers(0, 10 ** 6))
def test_merge_order_tie_break_contract(seed):
    """Equal-tau batches: each backend's ``merge_order`` emission matches
    its *documented* ``TIE_BREAK`` sort key exactly, and the two orders
    always agree on the ready set (same lanes, possibly reordered ties)."""
    import jax.numpy as jnp

    from repro.core import scalegate

    rng = np.random.default_rng(seed)
    n, n_sources = 16, 3
    tau = rng.integers(0, 2, n).astype(np.int32)       # massive ties
    src = rng.integers(0, n_sources, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    fields = {"tau": np.where(valid, tau.astype(np.int64), INF),
              "source": src.astype(np.int64),
              "arrival": np.arange(n)}
    perms = {}
    for backend in BACKENDS:
        order = np.asarray(scalegate.merge_order(
            jnp.asarray(tau), jnp.asarray(src), jnp.asarray(valid),
            n_sources, backend=backend))
        key = scalegate.tie_break(backend)
        # np.lexsort keys are least-significant first
        expect = np.lexsort(tuple(fields[f] for f in reversed(key)))
        np.testing.assert_array_equal(order, expect, err_msg=backend)
        perms[backend] = order
    # both contracts deliver the same lanes in every tau class
    for t in np.unique(tau):
        sel = valid & (tau == t)
        for p in perms.values():
            pos = np.isin(p, np.nonzero(sel)[0])
            assert set(p[pos]) == set(np.nonzero(sel)[0])


# ------------------------------------------------------------ heavy @slow --

@pytest.mark.slow
@pytest.mark.parametrize("n,n_sources", [(512, 3), (1024, 6)])
def test_scalegate_merge_conformance_heavy(n, n_sources):
    tau, src, valid = _merge_batch(n, n_sources, seed=n, mode="ties")
    o_x, r_x, w_x = scalegate_merge_op(tau, src, valid,
                                       n_sources=n_sources, backend="xla")
    o_p, r_p, w_p = scalegate_merge_op(tau, src, valid,
                                       n_sources=n_sources,
                                       backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(o_x), np.asarray(o_p))
    np.testing.assert_array_equal(np.asarray(r_x), np.asarray(r_p))
    assert int(w_x[0]) == int(w_p[0])


@pytest.mark.slow
@pytest.mark.parametrize("b,k,r", [(256, 512, 16), (63, 128, 32)])
def test_window_join_conformance_heavy(b, k, r):
    args = _join_case(b, k, r, seed=b + k, mode="random")
    c_x, n_x = window_join_op(*args, ws=40, band=4.0, backend="xla")
    c_p, n_p = window_join_op(*args, ws=40, band=4.0,
                              backend="pallas-interpret")
    np.testing.assert_array_equal(np.asarray(c_x), np.asarray(c_p))
    assert int(n_x) == int(n_p)


@pytest.mark.slow
@pytest.mark.parametrize("n,k", [(1000, 256), (4096, 128)])
def test_segment_aggregate_conformance_heavy(n, k):
    rng = np.random.default_rng(n)
    keys = rng.integers(-2, k + 3, n).astype(np.int32)
    slots = rng.integers(0, 4, n).astype(np.int32)
    vals = rng.integers(0, 3, (n, 2)).astype(np.float32)
    acc = np.zeros((k, 4, 2), np.float32)
    a = segment_aggregate_op(keys, slots, vals, acc, tile_k=128,
                             backend="pallas-interpret")
    b = segment_aggregate_op(keys, slots, vals, acc, backend="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
